//! The live front door: a hand-rolled HTTP/1.1 server over the sharded
//! engine fleet, with SLO-aware admission control, structured overload
//! shedding, and graceful drain.
//!
//! No HTTP library — the workspace's only dependency is `anyhow`, so
//! requests are parsed and responses framed directly over
//! [`std::net::TcpStream`] (bounded header/body reads, chunked
//! transfer-encoding for token streams). The protocol surface is a
//! minimal OpenAI-style dialect:
//!
//! * `POST /v1/completions` — one online request. Body
//!   `{"prompt": [tokens] | "text", "max_tokens": N, "stream": bool}`.
//!   With `stream: true` the response is chunked NDJSON: one
//!   `{"token": t}` line per sampled token and a final
//!   `{"done": true, ...}` line. Shed requests get a structured
//!   `429` with a `Retry-After` header and a machine-readable reason.
//! * `POST /v1/batches` — submit an offline job. The deadline-
//!   feasibility gate ([`AdmissionController::admit_job`]) accepts,
//!   down-tiers (deadline stripped, tier demoted) or rejects it; a
//!   rejected job still carries a correlatable id in its `429` body,
//!   and its board entry is retired immediately so the long-running
//!   server's board stays bounded.
//! * `GET /v1/batches/{id}` — poll job progress (completed jobs are
//!   garbage-collected from the board and eventually answer `404`).
//! * `GET /healthz` — liveness + fleet occupancy snapshot.
//! * `POST /drain` — graceful shutdown: stop admitting, flush accepted
//!   online work, checkpoint in-flight offline work to the
//!   [`JobStore`], exit with zero accepted-request loss.
//!
//! ## Backpressure and loss accounting
//!
//! Every accepted online request is tracked in a per-server stream hub
//! keyed by submission ticket. Token buffers are bounded
//! ([`STREAM_BUF_CAP`]): a slow reader stops accumulating tokens (the
//! final frame reports `lagged: true`) instead of growing the buffer.
//! A disconnected or timed-out client pushes its ticket onto the
//! engine's cancellation inbox, freeing the slot and its KV. The serve
//! summary proves the drain invariant arithmetically:
//! `lost_online = accepted - completed - cancelled - failed` must be 0.
//!
//! ## Drain state machine
//!
//! `accepting -> draining -> flushing -> checkpoint -> exit`:
//! `POST /drain` (or the `--duration` timer) closes the admission door
//! (every new request sheds with `reason: "draining"`); the accept
//! loop waits for in-flight connections to finish (their accepted work
//! is already in the engines); then the engine drain flag is raised —
//! each engine finishes its admitted *online* work, breaks, and
//! flushes unfinished offline work to the store via
//! [`ServingEngine::drain_to_store`]. A later `conserve serve` on the
//! same state dir resumes those jobs byte-identically (keyed synthetic
//! sampling, [`crate::backend::SimBackend::set_synth_tokens`]).

use crate::backend::{CostModel, SimBackend};
use crate::batch::{tier_weight, urgency_score, JobStore, ResumeState};
use crate::clock::Clock;
use crate::config::EngineConfig;
use crate::metrics::Recorder;
use crate::profiler::LatencyProfile;
use crate::report::Report;
use crate::request::{Class, Request, TokenId};
use crate::server::admission::{
    AdmissionConfig, AdmissionController, AdmissionCounters, Decision, FleetView, JobVerdict,
    ShedReason,
};
use crate::server::api::CLIENT_TICKET_BIT;
use crate::server::{ServingEngine, StreamEvent, StreamSink};
use crate::shard::{sharded_channel, Placement, ShardedClient};
use crate::trace::prometheus::{write_family, write_sample, MetricsHub};
use crate::trace::{flight_dump, perfetto, EventKind, FleetTracer, DEFAULT_DUMP_LAST};
use crate::util::json::{arr, num, obj, Json};
use crate::TimeUs;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-request token-stream buffer bound: a reader this far behind is
/// "lagged" — the hub stops buffering (the stream stays live, the
/// final frame reports the gap) rather than growing without bound.
pub const STREAM_BUF_CAP: usize = 256;

/// Handler poll interval against the stream hub (ms).
const POLL_MS: u64 = 2;

/// Per-socket read/write timeout. A peer that stalls longer is treated
/// as disconnected (its request is cancelled, not buffered).
const SOCKET_TIMEOUT: Duration = Duration::from_secs(2);

// ---------------------------------------------------------------------------
// Options / summary
// ---------------------------------------------------------------------------

/// Front-door configuration (`conserve serve` flags map 1:1).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address; port 0 picks a free port (tests).
    pub addr: String,
    pub shards: usize,
    /// Wall-clock serving duration in seconds; 0 = run until `/drain`.
    pub duration_s: f64,
    /// Durable job store directory. `None` disables checkpointing (a
    /// drain then still flushes online work, but offline progress is
    /// not persisted).
    pub state_dir: Option<PathBuf>,
    /// Engine iterations between durable checkpoint flushes.
    pub ckpt_every: u64,
    pub admission: AdmissionConfig,
    /// Execution cost model. Tests substitute a sped-up model so
    /// real-clock pacing stays in the milliseconds.
    pub cost: CostModel,
    pub max_header_bytes: usize,
    pub max_body_bytes: usize,
    /// Cap on how long a connection may wait for its completion before
    /// the server cancels the request and answers `504`.
    pub request_timeout_ms: u64,
    /// Write a Perfetto/Chrome trace-event JSON of the run here at
    /// shutdown (`--trace-out`). Tracing itself is always on (the ring
    /// is a fixed-size flight recorder feeding `/metrics` and
    /// post-mortem dumps); this only controls the export.
    pub trace_out: Option<PathBuf>,
    /// Per-track flight-recorder ring capacity (events).
    pub trace_cap: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:8077".to_string(),
            shards: 2,
            duration_s: 0.0,
            state_dir: None,
            ckpt_every: 50,
            admission: AdmissionConfig::default(),
            cost: CostModel::a100_llama2_7b(),
            max_header_bytes: 8 << 10,
            max_body_bytes: 256 << 10,
            request_timeout_ms: 120_000,
            trace_out: None,
            trace_cap: crate::trace::DEFAULT_RING_EVENTS,
        }
    }
}

/// End-of-serve accounting returned by [`HttpServer::run`].
#[derive(Debug)]
pub struct ServeSummary {
    pub report: Report,
    pub admission: AdmissionCounters,
    /// Online requests accepted past admission (submitted to engines).
    pub accepted_online: u64,
    /// ... of which finished and were delivered (or were deliverable).
    pub completed_online: u64,
    /// ... of which were cancelled (client disconnect / timeout).
    pub cancelled_online: u64,
    /// Accepted online tickets stranded by a shard death, each answered
    /// with a structured `503` carrying the request id.
    pub failed_online: Vec<u64>,
    /// The drain invariant: `accepted - completed - cancelled - failed`.
    /// Zero on a clean run; anything else is silent loss.
    pub lost_online: u64,
    /// Offline outputs / cold checkpoints flushed by the final drain.
    pub drain_outputs: u64,
    pub drain_checkpoints: u64,
    pub shard_deaths: usize,
    /// Offline requests re-dispatched from the durable store at boot.
    pub resumed_requests: usize,
    /// HTTP requests handled (any route, any outcome).
    pub requests_served: u64,
}

// ---------------------------------------------------------------------------
// Stream hub
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct DoneInfo {
    generated: u64,
    output: Vec<TokenId>,
}

/// Per-accepted-request mailbox between the engine's stream sink and
/// the connection handler, keyed by submission ticket.
#[derive(Debug, Default)]
struct StreamSlot {
    shard: usize,
    buf: VecDeque<TokenId>,
    /// Reader fell behind `STREAM_BUF_CAP`; buffering stopped.
    lagged: bool,
    done: Option<DoneInfo>,
    aborted: bool,
    /// Stranded by a shard death (answered with a structured 503).
    failed: bool,
    /// The handler is gone (disconnect/timeout); the sink removes the
    /// slot itself on the terminal event.
    orphaned: bool,
}

enum Terminal {
    Done(DoneInfo, bool),
    Aborted,
    Failed,
}

// ---------------------------------------------------------------------------
// Shared server state
// ---------------------------------------------------------------------------

struct ServeState {
    client: ShardedClient,
    admission: AdmissionController,
    clock: Clock,
    hub: Mutex<HashMap<u64, StreamSlot>>,
    /// Per-shard cancellation inboxes (wired to the engines).
    cancels: Vec<Arc<Mutex<Vec<u64>>>>,
    /// Raised only after the accept loop settles — engines then finish
    /// online work and break.
    engine_drain: Arc<AtomicBool>,
    /// Raised by `POST /drain` or the duration timer.
    drain_requested: AtomicBool,
    /// Open connections currently being handled.
    inflight: AtomicU64,
    accepted_online: AtomicU64,
    completed_online: AtomicU64,
    cancelled_online: AtomicU64,
    failed_count: AtomicU64,
    failed_online: Mutex<Vec<u64>>,
    shard_dead: Vec<AtomicBool>,
    requests_served: AtomicU64,
    store: Option<Arc<Mutex<JobStore>>>,
    /// Fleet flight recorder: one ring per shard plus a front-door
    /// track for admission verdicts. Always on (fixed memory).
    tracer: Arc<FleetTracer>,
    /// Live per-shard metric cells behind `GET /metrics`.
    metrics: Arc<MetricsHub>,
    /// One-shot latch per post-mortem dump trigger, so a TTFT-violation
    /// burst or a run of shard deaths writes one dump, not thousands.
    dumped_ttft_burst: AtomicBool,
    opts: ServeOptions,
}

/// Trace payload code for a shed/reject reason (`a` word of
/// `ShedOnline` / `JobReject` events).
fn shed_code(r: ShedReason) -> u64 {
    match r {
        ShedReason::RateLimit => 0,
        ShedReason::QueueFull => 1,
        ShedReason::Occupancy => 2,
        ShedReason::Draining => 3,
    }
}

impl ServeState {
    fn fleet_view(&self) -> FleetView {
        FleetView::from(self.client.loads().fleet_occupancy())
    }

    /// Emit an admission-side event on the front-door trace track.
    /// Timestamped off the serve clock (real time), like every engine
    /// event in this deployment mode.
    fn front_emit(&self, kind: EventKind, sid: u64, a: u64, b: u64) {
        if let Some(front) = self.tracer.front() {
            front.emit(self.clock.now(), kind, sid, a, b);
        }
    }

    /// Write a post-mortem flight-recorder dump (`flight-{tag}.jsonl`
    /// under the state dir): the newest events of every track. Quiet
    /// no-op without a state dir.
    fn dump_flight(&self, tag: &str) {
        if let Some(dir) = &self.opts.state_dir {
            if let Err(e) = flight_dump(dir, tag, &self.tracer, DEFAULT_DUMP_LAST) {
                eprintln!("flight dump {tag} failed: {e}");
            }
        }
    }

    fn dead_shards(&self) -> usize {
        self.shard_dead
            .iter()
            .filter(|d| d.load(Ordering::Relaxed))
            .count()
    }

    /// A shard died: every accepted online ticket routed to it is
    /// marked failed so its waiting handler can answer a structured
    /// 503 instead of hanging until the request timeout.
    fn fail_shard(&self, shard: usize) {
        self.shard_dead[shard].store(true, Ordering::Release);
        // post-mortem first: the dump captures the dead shard's final
        // ring (including its ShardDeath event) before the hub churns
        self.dump_flight(&format!("shard{shard}-death"));
        let mut hub = self.hub.lock().unwrap();
        let mut failed = self.failed_online.lock().unwrap();
        hub.retain(|&sid, slot| {
            if slot.shard != shard || slot.done.is_some() || slot.aborted || slot.failed {
                return true;
            }
            slot.failed = true;
            failed.push(sid);
            self.failed_count.fetch_add(1, Ordering::Relaxed);
            // an orphaned slot has no reader left to deliver the 503 to
            !slot.orphaned
        });
    }

    /// Handler gave up on `sid` (disconnect or timeout): cancel it on
    /// its shard and leave the slot for the sink to reap on the
    /// terminal event (so the loss accounting still sees it).
    fn orphan(&self, sid: u64, shard: usize) {
        let mut hub = self.hub.lock().unwrap();
        if let Some(slot) = hub.get_mut(&sid) {
            if slot.done.is_some() || slot.aborted || slot.failed {
                // terminal already counted — nothing left to cancel
                hub.remove(&sid);
                return;
            }
            if self.shard_dead[shard].load(Ordering::Relaxed) {
                // no terminal event will ever come: account it as
                // failed here so the loss arithmetic stays closed
                hub.remove(&sid);
                self.failed_online.lock().unwrap().push(sid);
                self.failed_count.fetch_add(1, Ordering::Relaxed);
                return;
            }
            slot.orphaned = true;
        }
        drop(hub);
        self.cancels[shard].lock().unwrap().push(sid);
    }
}

/// The engine-side stream sink for one shard: routes lifecycle events
/// into the hub. Only *online* events materialize slots (offline job
/// members account through the job board and the durable store).
fn make_sink(state: Arc<ServeState>, shard: usize) -> StreamSink {
    Box::new(move |ev| match ev {
        StreamEvent::Token {
            sid, class, token, ..
        } => {
            if class != Class::Online {
                return;
            }
            let mut hub = state.hub.lock().unwrap();
            let slot = hub.entry(sid).or_insert_with(|| StreamSlot {
                shard,
                ..StreamSlot::default()
            });
            if slot.buf.len() >= STREAM_BUF_CAP {
                slot.lagged = true;
            } else {
                slot.buf.push_back(token);
            }
        }
        StreamEvent::Done {
            sid,
            class,
            generated,
            output,
            ..
        } => {
            if class != Class::Online {
                return;
            }
            let mut hub = state.hub.lock().unwrap();
            let slot = hub.entry(sid).or_insert_with(|| StreamSlot {
                shard,
                ..StreamSlot::default()
            });
            if slot.failed {
                return; // already accounted as failed (shard death race)
            }
            state.completed_online.fetch_add(1, Ordering::Relaxed);
            if slot.orphaned {
                hub.remove(&sid);
            } else {
                slot.done = Some(DoneInfo { generated, output });
            }
        }
        StreamEvent::Aborted { sid, class, .. } => {
            if class != Class::Online {
                return;
            }
            let mut hub = state.hub.lock().unwrap();
            let slot = hub.entry(sid).or_insert_with(|| StreamSlot {
                shard,
                ..StreamSlot::default()
            });
            if slot.failed {
                return;
            }
            state.cancelled_online.fetch_add(1, Ordering::Relaxed);
            if slot.orphaned {
                hub.remove(&sid);
            } else {
                slot.aborted = true;
            }
        }
    })
}

// ---------------------------------------------------------------------------
// HTTP plumbing (hand-rolled; no dependencies)
// ---------------------------------------------------------------------------

struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
}

enum HttpFail {
    Malformed,
    HeaderTooLarge,
    BodyTooLarge,
    Disconnected,
}

fn find_subslice(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}

/// Read and frame one request: bounded header scan, `Content-Length`
/// body read. Any torn, oversized or non-HTTP input maps to a
/// structured 4xx via [`HttpFail`].
fn read_request(
    stream: &mut TcpStream,
    max_header: usize,
    max_body: usize,
) -> std::result::Result<HttpRequest, HttpFail> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 2048];
    let header_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > max_header {
            return Err(HttpFail::HeaderTooLarge);
        }
        match stream.read(&mut tmp) {
            Ok(0) => return Err(HttpFail::Disconnected),
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return Err(HttpFail::Disconnected),
        }
    };
    let head = std::str::from_utf8(&buf[..header_end]).map_err(|_| HttpFail::Malformed)?;
    let mut lines = head.split("\r\n");
    let req_line = lines.next().ok_or(HttpFail::Malformed)?;
    let mut parts = req_line.split(' ');
    let method = parts.next().ok_or(HttpFail::Malformed)?;
    let path = parts.next().ok_or(HttpFail::Malformed)?;
    let version = parts.next().ok_or(HttpFail::Malformed)?;
    if !version.starts_with("HTTP/1.") || parts.next().is_some() || path.is_empty() {
        return Err(HttpFail::Malformed);
    }
    let mut content_len = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().map_err(|_| HttpFail::Malformed)?;
            }
        }
    }
    if content_len > max_body {
        return Err(HttpFail::BodyTooLarge);
    }
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_len {
        match stream.read(&mut tmp) {
            Ok(0) => return Err(HttpFail::Disconnected),
            Ok(n) => body.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return Err(HttpFail::Disconnected),
        }
    }
    body.truncate(content_len);
    Ok(HttpRequest {
        method: method.to_string(),
        path: path.to_string(),
        body,
    })
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Internal Server Error",
    }
}

fn respond(
    stream: &mut TcpStream,
    status: u16,
    extra_headers: &[(&str, String)],
    body: &Json,
) -> std::io::Result<()> {
    let body = body.to_string();
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n",
        status,
        status_reason(status),
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Plain-text response (the Prometheus exposition format is not JSON,
/// so `/metrics` cannot ride on [`respond`]).
fn respond_text(stream: &mut TcpStream, status: u16, content_type: &str, body: &str) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status,
        status_reason(status),
        content_type,
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

fn error_body(kind: &str, fields: Vec<(&str, Json)>) -> Json {
    let mut inner = vec![("type", Json::Str(kind.to_string()))];
    inner.extend(fields);
    obj(vec![("error", obj(inner))])
}

fn respond_fail(stream: &mut TcpStream, fail: HttpFail) {
    let (status, kind) = match fail {
        HttpFail::Malformed | HttpFail::Disconnected => (400, "malformed"),
        HttpFail::HeaderTooLarge => (431, "header_too_large"),
        HttpFail::BodyTooLarge => (413, "body_too_large"),
    };
    let _ = respond(stream, status, &[], &error_body(kind, vec![]));
}

/// One chunk of a `Transfer-Encoding: chunked` NDJSON stream.
fn write_chunk(stream: &mut TcpStream, line: &str) -> std::io::Result<()> {
    // +1 for the trailing newline that makes the body NDJSON
    let chunk = format!("{:x}\r\n{}\n\r\n", line.len() + 1, line);
    stream.write_all(chunk.as_bytes())?;
    stream.flush()
}

fn finish_chunked(stream: &mut TcpStream) -> std::io::Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()
}

/// Shed response: structured 429 (503 while draining) with both a
/// `Retry-After` header (whole seconds, ceiling) and a millisecond
/// hint in the body.
fn respond_shed(stream: &mut TcpStream, retry_after_ms: u64, reason: ShedReason) {
    let status = if reason == ShedReason::Draining { 503 } else { 429 };
    let secs = retry_after_ms.div_ceil(1000).max(1);
    let _ = respond(
        stream,
        status,
        &[("Retry-After", secs.to_string())],
        &error_body(
            "shed",
            vec![
                ("reason", Json::Str(reason.as_str().to_string())),
                ("retry_after_ms", num(retry_after_ms as f64)),
            ],
        ),
    );
}

// ---------------------------------------------------------------------------
// Request parsing helpers
// ---------------------------------------------------------------------------

const MAX_PROMPT_TOKENS: usize = 8192;
const MAX_NEW_TOKENS: usize = 8192;
const MAX_BATCH_REQUESTS: usize = 4096;

/// Prompt tokens from a request object: an int array, a UTF-8 string
/// (bytes as tokens — the sim path only needs lengths), or a
/// `prompt_len` with synthesized content.
fn parse_prompt(j: &Json) -> Option<Vec<TokenId>> {
    if let Some(p) = j.get("prompt") {
        if let Some(a) = p.as_arr() {
            if a.len() > MAX_PROMPT_TOKENS {
                return None;
            }
            return a
                .iter()
                .map(|t| t.as_f64().map(|n| n as TokenId))
                .collect::<Option<Vec<_>>>()
                .filter(|v| !v.is_empty());
        }
        if let Some(s) = p.as_str() {
            let b: Vec<TokenId> = s.bytes().map(|b| b as TokenId).collect();
            return (!b.is_empty() && b.len() <= MAX_PROMPT_TOKENS).then_some(b);
        }
        return None;
    }
    let n = j.get("prompt_len")?.as_usize()?;
    if n == 0 || n > MAX_PROMPT_TOKENS {
        return None;
    }
    Some((0..n).map(|i| (i & 0xFF) as TokenId).collect())
}

fn parse_max_tokens(j: &Json) -> Option<usize> {
    match j.get("max_tokens") {
        None => Some(16),
        Some(v) => v.as_usize().filter(|&n| n >= 1 && n <= MAX_NEW_TOKENS),
    }
}

/// Batch member list: explicit `requests: [{prompt, max_tokens}, ...]`
/// or the shorthand `{n_requests, prompt_len, max_tokens}`.
fn parse_batch_members(j: &Json) -> Option<Vec<(Vec<TokenId>, usize)>> {
    if let Some(reqs) = j.get("requests") {
        let reqs = reqs.as_arr()?;
        if reqs.is_empty() || reqs.len() > MAX_BATCH_REQUESTS {
            return None;
        }
        return reqs
            .iter()
            .map(|r| Some((parse_prompt(r)?, parse_max_tokens(r)?)))
            .collect();
    }
    let n = j.get("n_requests")?.as_usize()?;
    if n == 0 || n > MAX_BATCH_REQUESTS {
        return None;
    }
    let prompt = parse_prompt(j)?;
    let max_new = parse_max_tokens(j)?;
    Some((0..n).map(|_| (prompt.clone(), max_new)).collect())
}

// ---------------------------------------------------------------------------
// Route handlers
// ---------------------------------------------------------------------------

fn handle_healthz(stream: &mut TcpStream, state: &ServeState) {
    let v = state.fleet_view();
    let draining = state.admission.is_draining();
    // prefix-cache effectiveness straight off the load board (the
    // FleetView used for admission doesn't carry it): 0.0 both when the
    // cache is off and before the first lookup
    let occ = state.client.loads().fleet_occupancy();
    let prefix_hit_rate = if occ.prefix_lookups == 0 {
        0.0
    } else {
        occ.prefix_hits as f64 / occ.prefix_lookups as f64
    };
    // per-tenant deadline attainment off the live metric cells, keyed
    // by tenant id (deterministic order: merged_tenants sorts)
    let tenant_pairs: Vec<(String, Json)> = state
        .metrics
        .merged_tenants()
        .iter()
        .map(|t| (t.tenant.to_string(), num(t.attainment())))
        .collect();
    let tenants = Json::Obj(tenant_pairs.into_iter().collect());
    let body = obj(vec![
        (
            "status",
            Json::Str(if draining { "draining" } else { "ok" }.to_string()),
        ),
        ("draining", Json::Bool(draining)),
        ("shards", num(v.n_shards as f64)),
        ("dead_shards", num(state.dead_shards() as f64)),
        ("online_blocks", num(v.online_blocks as f64)),
        ("capacity_blocks", num((v.n_shards * v.capacity_blocks) as f64)),
        ("waiting_online", num(v.waiting_online as f64)),
        ("waiting_offline", num(v.offline_waiting as f64)),
        ("prefix_hits", num(occ.prefix_hits as f64)),
        ("prefix_hit_rate", num(prefix_hit_rate)),
        // live harvest posture: mean offline token budget across
        // shards, permille of the static budget (1000 = wide open)
        ("harvest_budget_permille", num(occ.budget_permille as f64)),
        ("deadline_attainment", num(state.metrics.deadline_attainment())),
        ("tenant_deadline_attainment", tenants),
    ]);
    let _ = respond(stream, 200, &[], &body);
}

/// `GET /metrics`: Prometheus text exposition — the engines' live cells
/// ([`MetricsHub::render_into`]) plus the front door's own families.
fn handle_metrics(stream: &mut TcpStream, state: &ServeState) {
    let mut out = String::with_capacity(8 << 10);
    state.metrics.render_into(&mut out);
    let occ = state.client.loads().fleet_occupancy();
    write_family(
        &mut out,
        "conserve_harvest_budget_permille",
        "Mean live offline token budget across shards (permille of static)",
        "gauge",
    );
    write_sample(&mut out, "conserve_harvest_budget_permille", "", occ.budget_permille as f64);
    let hit_rate = if occ.prefix_lookups == 0 {
        0.0
    } else {
        occ.prefix_hits as f64 / occ.prefix_lookups as f64
    };
    write_family(
        &mut out,
        "conserve_prefix_hit_rate",
        "Fleet prefix-cache attach hit rate",
        "gauge",
    );
    write_sample(&mut out, "conserve_prefix_hit_rate", "", hit_rate);
    let c = state.admission.counters();
    let front: &[(&str, &str, &str, u64)] = &[
        ("conserve_http_requests_total", "counter", "HTTP requests handled (any route)", state.requests_served.load(Ordering::Relaxed)),
        ("conserve_accepted_online_total", "counter", "Online requests accepted past admission", state.accepted_online.load(Ordering::Relaxed)),
        ("conserve_completed_online_total", "counter", "Accepted online requests completed", state.completed_online.load(Ordering::Relaxed)),
        ("conserve_cancelled_online_total", "counter", "Accepted online requests cancelled", state.cancelled_online.load(Ordering::Relaxed)),
        ("conserve_failed_online_total", "counter", "Accepted online requests stranded by shard deaths", state.failed_count.load(Ordering::Relaxed)),
        ("conserve_shed_online_total", "counter", "Online requests shed at admission", c.shed_online),
        ("conserve_jobs_accepted_total", "counter", "Batch jobs accepted", c.jobs_accepted),
        ("conserve_jobs_downtiered_total", "counter", "Batch jobs admitted best-effort (deadline infeasible)", c.jobs_downtiered),
        ("conserve_jobs_rejected_total", "counter", "Batch jobs rejected", c.jobs_rejected),
        ("conserve_inflight_connections", "gauge", "Open HTTP connections", state.inflight.load(Ordering::Relaxed)),
        ("conserve_dead_shards", "gauge", "Shards currently dead", state.dead_shards() as u64),
        ("conserve_trace_events_total", "counter", "Trace events emitted (all tracks)", state.tracer.total_events()),
        ("conserve_trace_dropped_total", "counter", "Trace events overwritten in the rings", state.tracer.dropped()),
    ];
    for (name, typ, help, v) in front {
        write_family(&mut out, name, help, typ);
        write_sample(&mut out, name, "", *v as f64);
    }
    let _ = respond_text(stream, 200, "text/plain; version=0.0.4", &out);
}

fn handle_drain(stream: &mut TcpStream, state: &ServeState) {
    state.admission.begin_drain();
    state.drain_requested.store(true, Ordering::Release);
    let _ = respond(
        stream,
        202,
        &[],
        &obj(vec![("status", Json::Str("draining".to_string()))]),
    );
}

fn handle_completions(mut stream: TcpStream, state: &Arc<ServeState>, body: &[u8]) {
    let Ok(text) = std::str::from_utf8(body) else {
        let _ = respond(&mut stream, 400, &[], &error_body("malformed", vec![]));
        return;
    };
    let Ok(j) = Json::parse(text) else {
        let _ = respond(&mut stream, 400, &[], &error_body("malformed", vec![]));
        return;
    };
    let (Some(prompt), Some(max_tokens)) = (parse_prompt(&j), parse_max_tokens(&j)) else {
        let _ = respond(&mut stream, 400, &[], &error_body("invalid_request", vec![]));
        return;
    };
    let streaming = j.get("stream").and_then(Json::as_bool).unwrap_or(false);

    let view = state.fleet_view();
    if let Decision::Shed {
        retry_after_ms,
        reason,
    } = state.admission.admit_online(&view, state.clock.now())
    {
        state.front_emit(EventKind::ShedOnline, 0, shed_code(reason), retry_after_ms);
        respond_shed(&mut stream, retry_after_ms, reason);
        return;
    }
    let ticket = match state.client.try_submit_online(prompt, max_tokens) {
        Ok(t) => t,
        Err(_) => {
            // bounded submission channel at capacity — shed rather
            // than block the accept path
            state.front_emit(EventKind::ShedOnline, 0, shed_code(ShedReason::QueueFull), 100);
            let _ = respond(
                &mut stream,
                503,
                &[("Retry-After", "1".to_string())],
                &error_body("backpressure", vec![("retry_after_ms", num(100.0))]),
            );
            return;
        }
    };
    state.accepted_online.fetch_add(1, Ordering::Relaxed);
    let sid = ticket.ticket;
    state.front_emit(EventKind::AdmitOnline, sid, ticket.shard as u64, 0);
    {
        // adopt the slot (the sink may already have created it)
        let mut hub = state.hub.lock().unwrap();
        hub.entry(sid).or_default().shard = ticket.shard;
    }
    if streaming {
        stream_completion(stream, state, sid, ticket.shard);
    } else {
        wait_completion(stream, state, sid, ticket.shard);
    }
}

/// Take whatever the slot holds right now: buffered tokens plus, if
/// present, the terminal state (which also removes the slot).
fn poll_slot(state: &ServeState, sid: u64) -> (Vec<TokenId>, Option<Terminal>) {
    let mut hub = state.hub.lock().unwrap();
    let Some(slot) = hub.get_mut(&sid) else {
        // only terminal paths remove slots, so a vanished slot means
        // the request is gone — report it as failed
        return (Vec::new(), Some(Terminal::Failed));
    };
    let tokens: Vec<TokenId> = slot.buf.drain(..).collect();
    let term = if let Some(d) = slot.done.clone() {
        Some(Terminal::Done(d, slot.lagged))
    } else if slot.failed {
        Some(Terminal::Failed)
    } else if slot.aborted {
        Some(Terminal::Aborted)
    } else {
        None
    };
    if term.is_some() {
        hub.remove(&sid);
    }
    (tokens, term)
}

fn shard_failed_body(sid: u64) -> Json {
    error_body(
        "shard_failed",
        vec![
            ("request_ids", arr([Json::Str(sid.to_string())])),
            ("retry_after_ms", num(1000.0)),
            (
                "hint",
                Json::Str("resubmit: a retry mints a fresh ticket on a live shard".to_string()),
            ),
        ],
    )
}

fn wait_completion(mut stream: TcpStream, state: &Arc<ServeState>, sid: u64, shard: usize) {
    let deadline = Instant::now() + Duration::from_millis(state.opts.request_timeout_ms);
    let mut tokens: Vec<TokenId> = Vec::new();
    loop {
        let (mut fresh, term) = poll_slot(state, sid);
        tokens.append(&mut fresh);
        match term {
            Some(Terminal::Done(d, lagged)) => {
                // Done carries the full output — authoritative even if
                // the incremental buffer lagged
                let out = if d.output.is_empty() { tokens } else { d.output };
                let body = obj(vec![
                    ("id", Json::Str(sid.to_string())),
                    ("generated", num(d.generated as f64)),
                    ("tokens", arr(out.iter().map(|&t| num(t as f64)))),
                    ("lagged", Json::Bool(lagged)),
                ]);
                let _ = respond(&mut stream, 200, &[], &body);
                return;
            }
            Some(Terminal::Failed) => {
                let _ = respond(
                    &mut stream,
                    503,
                    &[("Retry-After", "1".to_string())],
                    &shard_failed_body(sid),
                );
                return;
            }
            Some(Terminal::Aborted) => {
                let _ = respond(&mut stream, 503, &[], &error_body("cancelled", vec![]));
                return;
            }
            None => {
                if Instant::now() >= deadline {
                    state.orphan(sid, shard);
                    let _ = respond(&mut stream, 504, &[], &error_body("timeout", vec![]));
                    return;
                }
                std::thread::sleep(Duration::from_millis(POLL_MS));
            }
        }
    }
}

fn stream_completion(mut stream: TcpStream, state: &Arc<ServeState>, sid: u64, shard: usize) {
    let head = "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n";
    if stream.write_all(head.as_bytes()).is_err() {
        state.orphan(sid, shard);
        return;
    }
    let deadline = Instant::now() + Duration::from_millis(state.opts.request_timeout_ms);
    loop {
        let (tokens, term) = poll_slot(state, sid);
        for t in tokens {
            let line = obj(vec![("token", num(t as f64))]).to_string();
            if write_chunk(&mut stream, &line).is_err() {
                // reader went away mid-stream: cancel, free the slot
                state.orphan(sid, shard);
                return;
            }
        }
        match term {
            Some(Terminal::Done(d, lagged)) => {
                let line = obj(vec![
                    ("done", Json::Bool(true)),
                    ("id", Json::Str(sid.to_string())),
                    ("generated", num(d.generated as f64)),
                    ("lagged", Json::Bool(lagged)),
                ])
                .to_string();
                let _ = write_chunk(&mut stream, &line).and_then(|_| finish_chunked(&mut stream));
                return;
            }
            Some(Terminal::Failed) => {
                let line = shard_failed_body(sid).to_string();
                let _ = write_chunk(&mut stream, &line).and_then(|_| finish_chunked(&mut stream));
                return;
            }
            Some(Terminal::Aborted) => {
                let line = error_body("cancelled", vec![]).to_string();
                let _ = write_chunk(&mut stream, &line).and_then(|_| finish_chunked(&mut stream));
                return;
            }
            None => {
                if Instant::now() >= deadline {
                    state.orphan(sid, shard);
                    let line = error_body("timeout", vec![]).to_string();
                    let _ =
                        write_chunk(&mut stream, &line).and_then(|_| finish_chunked(&mut stream));
                    return;
                }
                std::thread::sleep(Duration::from_millis(POLL_MS));
            }
        }
    }
}

fn handle_batch_submit(stream: &mut TcpStream, state: &ServeState, body: &[u8]) {
    let parsed = std::str::from_utf8(body).ok().and_then(|t| Json::parse(t).ok());
    let Some(j) = parsed else {
        let _ = respond(stream, 400, &[], &error_body("malformed", vec![]));
        return;
    };
    let Some(members) = parse_batch_members(&j) else {
        let _ = respond(stream, 400, &[], &error_body("invalid_request", vec![]));
        return;
    };
    let tenant = j.get("tenant").and_then(Json::as_usize).unwrap_or(0) as u32;
    let tier = j.get("tier").and_then(Json::as_usize).unwrap_or(1).min(255) as u8;
    let deadline_ms = j
        .get("deadline_ms")
        .and_then(Json::as_f64)
        .map(|n| n.max(0.0) as u64)
        .unwrap_or(0);
    let now = state.clock.now();
    let deadline: TimeUs = if deadline_ms > 0 {
        now + deadline_ms * 1000
    } else {
        0
    };
    let job_tokens: u64 = members.iter().map(|(p, m)| (p.len() + m) as u64).sum();
    let n_requests = members.len() as u64;

    let view = state.fleet_view();
    match state
        .admission
        .admit_job(&view, tenant, job_tokens, deadline, now)
    {
        JobVerdict::Reject {
            retry_after_ms,
            reason,
        } => {
            // mint + immediately retire a board id so even a rejected
            // job is correlatable in the tenant's logs
            let job = state.client.reserve_job(n_requests, tenant, deadline);
            state.client.retire_job(job);
            state.front_emit(EventKind::JobReject, job, shed_code(reason), retry_after_ms);
            let status = if reason == ShedReason::Draining { 503 } else { 429 };
            let secs = retry_after_ms.div_ceil(1000).max(1);
            let mut body = error_body(
                "job_rejected",
                vec![
                    ("reason", Json::Str(reason.as_str().to_string())),
                    ("retry_after_ms", num(retry_after_ms as f64)),
                ],
            );
            if let Json::Obj(m) = &mut body {
                m.insert("id".to_string(), num(job as f64));
            }
            let _ = respond(stream, status, &[("Retry-After", secs.to_string())], &body);
        }
        verdict @ (JobVerdict::Accept { .. } | JobVerdict::DownTier { .. }) => {
            let (eff_deadline, eff_tier, urgency, status_str, est_ms) = match verdict {
                JobVerdict::Accept { est_finish_ms } => {
                    let urg = urgency_score(
                        deadline,
                        now,
                        job_tokens,
                        state.admission.config().svc_tok_per_s,
                    );
                    (deadline, tier, urg, "accepted", est_finish_ms)
                }
                // infeasible deadline: run best-effort — deadline
                // stripped, urgency zeroed, tier demoted
                JobVerdict::DownTier { est_finish_ms } => (0, 2u8, 0u32, "downtiered", est_finish_ms),
                JobVerdict::Reject { .. } => unreachable!(),
            };
            let prepared =
                state
                    .client
                    .prepare_job(members, tenant, eff_tier, urgency, eff_deadline, now);
            let job = prepared.spec.job;
            if let Some(store) = &state.store {
                if let Err(e) = store
                    .lock()
                    .unwrap()
                    .record_spec(&prepared.spec, &prepared.members)
                {
                    state.client.retire_job(job);
                    let _ = respond(
                        stream,
                        500,
                        &[],
                        &error_body(
                            "store_error",
                            vec![("detail", Json::Str(format!("{e:#}")))],
                        ),
                    );
                    return;
                }
            }
            state.client.dispatch_job(prepared);
            state.front_emit(
                if status_str == "accepted" {
                    EventKind::JobAccept
                } else {
                    EventKind::JobDownTier
                },
                job,
                est_ms,
                n_requests,
            );
            let body = obj(vec![
                ("id", num(job as f64)),
                ("status", Json::Str(status_str.to_string())),
                ("n_requests", num(n_requests as f64)),
                ("est_finish_ms", num(est_ms as f64)),
            ]);
            let _ = respond(stream, 202, &[], &body);
        }
    }
}

fn handle_batch_status(stream: &mut TcpStream, state: &ServeState, path: &str) {
    let id = path
        .strip_prefix("/v1/batches/")
        .and_then(|s| s.parse::<u64>().ok());
    let Some(id) = id else {
        let _ = respond(stream, 400, &[], &error_body("invalid_job_id", vec![]));
        return;
    };
    match state.client.job_board().progress(id) {
        Some(p) => {
            let body = obj(vec![
                ("id", num(id as f64)),
                ("total", num(p.total as f64)),
                ("finished", num(p.finished as f64)),
                ("gen_tokens", num(p.gen_tokens as f64)),
                ("done", Json::Bool(p.done())),
                ("tenant", num(p.tenant as f64)),
            ]);
            let _ = respond(stream, 200, &[], &body);
        }
        None => {
            let _ = respond(
                stream,
                404,
                &[],
                &error_body(
                    "unknown_job",
                    vec![(
                        "hint",
                        Json::Str("completed jobs are garbage-collected from the board".to_string()),
                    )],
                ),
            );
        }
    }
}

fn handle_connection(mut stream: TcpStream, state: &Arc<ServeState>) {
    let _ = stream.set_read_timeout(Some(SOCKET_TIMEOUT));
    let _ = stream.set_write_timeout(Some(SOCKET_TIMEOUT));
    let _ = stream.set_nodelay(true);
    state.requests_served.fetch_add(1, Ordering::Relaxed);
    let req = match read_request(
        &mut stream,
        state.opts.max_header_bytes,
        state.opts.max_body_bytes,
    ) {
        Ok(r) => r,
        Err(f) => {
            respond_fail(&mut stream, f);
            return;
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => handle_healthz(&mut stream, state),
        ("GET", "/metrics") => handle_metrics(&mut stream, state),
        ("POST", "/drain") => handle_drain(&mut stream, state),
        ("POST", "/v1/completions") => handle_completions(stream, state, &req.body),
        ("POST", "/v1/batches") => handle_batch_submit(&mut stream, state, &req.body),
        ("GET", p) if p.starts_with("/v1/batches/") => handle_batch_status(&mut stream, state, p),
        (_, "/healthz" | "/metrics" | "/drain" | "/v1/completions" | "/v1/batches") => {
            let _ = respond(&mut stream, 405, &[], &error_body("method_not_allowed", vec![]));
        }
        _ => {
            let _ = respond(&mut stream, 404, &[], &error_body("not_found", vec![]));
        }
    }
}

// ---------------------------------------------------------------------------
// Resume
// ---------------------------------------------------------------------------

/// Rebuild unfinished offline work from the durable store and
/// re-dispatch it round-robin over the live shard clients. Per
/// request, the newest checkpoint wins (arrival reset so waiting time
/// does not predate the restart); a request without one restarts from
/// its recorded spec under the *same* sid — keyed sampling then makes
/// its resumed output byte-identical. Finally the shared ticket
/// counter is seeded past every stored id so fresh tickets cannot
/// collide with resumed submission ids.
fn resume_jobs(client: &ShardedClient, rs: &ResumeState) -> usize {
    let n = client.n_shards();
    let mut max_id = 0u64;
    let mut resumed = 0usize;
    let mut rr = 0usize;
    for sj in &rs.jobs {
        let spec = &sj.spec;
        max_id = max_id.max(spec.job);
        let mut done = 0u64;
        let mut done_tokens = 0u64;
        let mut pending: Vec<Request> = Vec::new();
        for sr in &sj.requests {
            max_id = max_id.max(sr.sid & !CLIENT_TICKET_BIT);
            if let Some(out) = rs.outputs.get(&sr.sid) {
                done += 1;
                done_tokens += out.generated;
                continue;
            }
            let mut r = if let Some(ck) = rs.checkpoints.get(&sr.sid) {
                let mut r = ck.clone().into_request();
                r.arrival = 0;
                r
            } else {
                let mut r = Request::new(
                    sr.sid,
                    Class::Offline,
                    sr.prompt.clone(),
                    sr.prompt_len,
                    sr.max_new_tokens,
                    0,
                );
                r.job = spec.job;
                r.tenant = spec.tenant;
                r.fair_weight = tier_weight(spec.tier);
                r.deadline = spec.deadline;
                r
            };
            r.urgency = 0; // the restamp hook re-scores queued urgency
            pending.push(r);
        }
        if done >= spec.n_requests && pending.is_empty() {
            continue; // job fully finished before the restart
        }
        client.job_board().register_resumed(
            spec.job,
            spec.n_requests,
            done,
            done_tokens,
            spec.deadline,
            spec.tenant,
        );
        for r in pending {
            client.client(rr % n).send(r);
            rr += 1;
            resumed += 1;
        }
    }
    client.seed_tickets(max_id + 1);
    resumed
}

// ---------------------------------------------------------------------------
// The server
// ---------------------------------------------------------------------------

struct ShardOutcome {
    rec: Option<Recorder>,
    end: TimeUs,
    outs: u64,
    ckpts: u64,
}

/// Decrements the in-flight connection gauge even if a handler panics
/// (a stuck gauge would deadlock the drain sequence).
struct InflightGuard(Arc<ServeState>);
impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

/// The bound-but-not-yet-serving front door. Splitting bind from
/// [`run`](Self::run) lets tests bind port 0 and read the real
/// address before traffic starts.
pub struct HttpServer {
    listener: TcpListener,
    cfg: EngineConfig,
    opts: ServeOptions,
}

impl HttpServer {
    pub fn bind(cfg: EngineConfig, opts: ServeOptions) -> Result<Self> {
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding front door to {}", opts.addr))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        Ok(HttpServer { listener, cfg, opts })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Serve until drained (via `POST /drain` or the configured
    /// duration), then flush, checkpoint, and account for every
    /// accepted request.
    pub fn run(self) -> Result<ServeSummary> {
        let HttpServer { listener, cfg, opts } = self;
        let n_shards = opts.shards.max(1);
        let (client, _loads, sources) = sharded_channel(n_shards, Placement::affinity(), &cfg);

        let store = match &opts.state_dir {
            Some(dir) => Some((
                Arc::new(Mutex::new(JobStore::open(dir).context("opening job store")?)),
                JobStore::load(dir).context("loading job store")?,
            )),
            None => None,
        };
        let (store, resume_state) = match store {
            Some((s, rs)) => (Some(s), Some(rs)),
            None => (None, None),
        };

        // one offline profiling pass shared by all (identical) shards
        let profile = {
            let pclock = Clock::virtual_at(0);
            let mut pb = SimBackend::new(opts.cost, pclock, cfg.sched.safepoint_layers);
            LatencyProfile::profile(&mut pb, 4096, 128, 2048).context("offline profiling pass")?
        };

        let clock = Clock::real();
        let cancels: Vec<Arc<Mutex<Vec<u64>>>> = (0..n_shards)
            .map(|_| Arc::new(Mutex::new(Vec::new())))
            .collect();
        let tracer = FleetTracer::with_front(n_shards, opts.trace_cap);
        let metrics = MetricsHub::new(n_shards);
        let state = Arc::new(ServeState {
            client,
            admission: AdmissionController::new(opts.admission.clone()),
            clock: clock.clone(),
            hub: Mutex::new(HashMap::new()),
            cancels,
            engine_drain: Arc::new(AtomicBool::new(false)),
            drain_requested: AtomicBool::new(false),
            inflight: AtomicU64::new(0),
            accepted_online: AtomicU64::new(0),
            completed_online: AtomicU64::new(0),
            cancelled_online: AtomicU64::new(0),
            failed_count: AtomicU64::new(0),
            failed_online: Mutex::new(Vec::new()),
            shard_dead: (0..n_shards).map(|_| AtomicBool::new(false)).collect(),
            requests_served: AtomicU64::new(0),
            store: store.clone(),
            tracer,
            metrics,
            dumped_ttft_burst: AtomicBool::new(false),
            opts,
        });

        // ---- shard engines (constructed inside their threads) ----
        let (outcome_tx, outcome_rx) = mpsc::channel::<ShardOutcome>();
        let mut shard_threads = Vec::with_capacity(n_shards);
        for (shard, arrivals) in sources.into_iter().enumerate() {
            let st = state.clone();
            let cfg = cfg.clone();
            let clock = clock.clone();
            let tx = outcome_tx.clone();
            shard_threads.push(std::thread::spawn(move || {
                let cost = st.opts.cost;
                let ckpt_every = st.opts.ckpt_every;
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut backend = SimBackend::new(cost, clock.clone(), cfg.sched.safepoint_layers);
                    backend.set_synth_tokens(true);
                    let mut engine =
                        ServingEngine::for_shard(shard, cfg, backend, clock, profile, arrivals);
                    engine.set_retain_finished(false);
                    engine.set_shard_loads(st.client.loads().clone());
                    engine.set_job_board(st.client.job_board().clone());
                    engine.set_job_gc(512);
                    engine.set_stream_sink(make_sink(st.clone(), shard));
                    engine.set_cancel_queue(st.cancels[shard].clone());
                    engine.set_drain_flag(st.engine_drain.clone());
                    engine.set_tracer(st.tracer.shard(shard));
                    engine.set_live_stats(st.metrics.shard(shard));
                    if let Some(store) = &st.store {
                        engine.set_ckpt_sink(store.clone(), ckpt_every);
                    }
                    let end = engine.run(TimeUs::MAX);
                    let (outs, ckpts) = engine.drain_to_store();
                    // exact final scrape (the in-loop publish is
                    // one iteration behind by construction)
                    st.metrics.shard(shard).publish_all(&engine.rec);
                    (std::mem::take(&mut engine.rec), end, outs, ckpts)
                }));
                match result {
                    Ok((rec, end, outs, ckpts)) => {
                        let _ = tx.send(ShardOutcome {
                            rec: Some(rec),
                            end,
                            outs,
                            ckpts,
                        });
                    }
                    Err(_) => {
                        st.fail_shard(shard);
                        let _ = tx.send(ShardOutcome {
                            rec: None,
                            end: 0,
                            outs: 0,
                            ckpts: 0,
                        });
                    }
                }
            }));
        }
        drop(outcome_tx);

        // ---- resume after the engines are live (sends drain as the
        // engines pull arrivals, so a large backlog cannot deadlock the
        // bounded channels) ----
        let resumed_requests = match &resume_state {
            Some(rs) => resume_jobs(&state.client, rs),
            None => 0,
        };

        // ---- accept loop ----
        let serve_deadline = (state.opts.duration_s > 0.0)
            .then(|| Instant::now() + Duration::from_secs_f64(state.opts.duration_s));
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    state.inflight.fetch_add(1, Ordering::AcqRel);
                    let st = state.clone();
                    std::thread::spawn(move || {
                        let _guard = InflightGuard(st.clone());
                        handle_connection(stream, &st);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e).context("accepting connection"),
            }
            if let Some(d) = serve_deadline {
                if Instant::now() >= d && !state.drain_requested.load(Ordering::Acquire) {
                    state.admission.begin_drain();
                    state.drain_requested.store(true, Ordering::Release);
                }
            }
            // TTFT-violation burst: any shard's published online P99
            // far past the SLO latches one post-mortem flight dump (the
            // incident's ring, not an ever-growing series of them)
            if !state.dumped_ttft_burst.load(Ordering::Relaxed) {
                let burst_us = (cfg.sched.slo.ttft_ms * 1_000.0 * 5.0) as u64;
                let violated = state
                    .metrics
                    .cells()
                    .iter()
                    .any(|s| s.p99_ttft_us.load(Ordering::Relaxed) > burst_us);
                if violated && !state.dumped_ttft_burst.swap(true, Ordering::Relaxed) {
                    state.dump_flight("ttft-burst");
                }
            }
            if state.drain_requested.load(Ordering::Acquire)
                && state.inflight.load(Ordering::Acquire) == 0
            {
                break;
            }
        }
        drop(listener);

        // ---- drain: every accepted submission has reached its engine
        // (its handler finished), so the flag can go up ----
        state.engine_drain.store(true, Ordering::Release);
        let mut merged = Recorder::new();
        let mut end: TimeUs = 0;
        let (mut drain_outputs, mut drain_checkpoints) = (0u64, 0u64);
        let mut shard_deaths = 0usize;
        for _ in 0..n_shards {
            match outcome_rx.recv_timeout(Duration::from_secs(120)) {
                Ok(o) => {
                    end = end.max(o.end);
                    drain_outputs += o.outs;
                    drain_checkpoints += o.ckpts;
                    match o.rec {
                        Some(rec) => merged.merge(&rec),
                        None => shard_deaths += 1,
                    }
                }
                Err(_) => shard_deaths += 1,
            }
        }
        for t in shard_threads {
            let _ = t.join();
        }

        // flight record of the whole run at drain (the serve analogue
        // of a black box readout), and the optional Perfetto export —
        // both after the join, so every ring is final and tear-free
        state.dump_flight("drain");
        if let Some(path) = &state.opts.trace_out {
            if let Err(e) = std::fs::write(path, perfetto::export_perfetto(&state.tracer)) {
                eprintln!("writing trace to {} failed: {e}", path.display());
            }
        }

        // admission outcomes ride on the merged recorder so the serve
        // report carries them alongside the engine counters
        let counters = state.admission.counters();
        merged.shed_online = counters.shed_online;
        merged.shed_offline = counters.shed_offline;
        merged.jobs_admitted = counters.jobs_accepted;
        merged.jobs_downtiered = counters.jobs_downtiered;
        merged.jobs_rejected = counters.jobs_rejected;
        let report = Report::from_engine(&merged, cfg.sched.policy, end.max(1));

        let accepted = state.accepted_online.load(Ordering::Relaxed);
        let completed = state.completed_online.load(Ordering::Relaxed);
        let cancelled = state.cancelled_online.load(Ordering::Relaxed);
        let failed = state.failed_count.load(Ordering::Relaxed);
        let failed_online = state.failed_online.lock().unwrap().clone();
        Ok(ServeSummary {
            report,
            admission: counters,
            accepted_online: accepted,
            completed_online: completed,
            cancelled_online: cancelled,
            failed_online,
            lost_online: accepted
                .saturating_sub(completed)
                .saturating_sub(cancelled)
                .saturating_sub(failed),
            drain_outputs,
            drain_checkpoints,
            shard_deaths,
            resumed_requests,
            requests_served: state.requests_served.load(Ordering::Relaxed),
        })
    }
}

impl ServeSummary {
    /// JSON rendering for operator tooling and the CI smoke job.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("accepted_online", num(self.accepted_online as f64)),
            ("completed_online", num(self.completed_online as f64)),
            ("cancelled_online", num(self.cancelled_online as f64)),
            ("failed_online", num(self.failed_online.len() as f64)),
            ("lost_online", num(self.lost_online as f64)),
            ("shed_online", num(self.admission.shed_online as f64)),
            ("shed_offline", num(self.admission.shed_offline as f64)),
            ("jobs_accepted", num(self.admission.jobs_accepted as f64)),
            ("jobs_downtiered", num(self.admission.jobs_downtiered as f64)),
            ("jobs_rejected", num(self.admission.jobs_rejected as f64)),
            ("drain_outputs", num(self.drain_outputs as f64)),
            ("drain_checkpoints", num(self.drain_checkpoints as f64)),
            ("shard_deaths", num(self.shard_deaths as f64)),
            ("resumed_requests", num(self.resumed_requests as f64)),
            ("requests_served", num(self.requests_served as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Tests (pure plumbing; the loopback integration tests live in
// rust/tests/admission_props.rs)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_framing_round_trip() {
        let (a, b) = loopback_pair();
        let mut client = a;
        let mut server = b;
        let body = br#"{"x":1}"#;
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        client.write_all(req.as_bytes()).unwrap();
        client.write_all(body).unwrap();
        let parsed = read_request(&mut server, 8192, 65536).ok().unwrap();
        assert_eq!(parsed.method, "POST");
        assert_eq!(parsed.path, "/v1/completions");
        assert_eq!(parsed.body, body);
    }

    #[test]
    fn oversized_header_and_body_are_rejected() {
        let (mut client, mut server) = loopback_pair();
        let req = format!("GET /x HTTP/1.1\r\nPad: {}\r\n\r\n", "y".repeat(9000));
        client.write_all(req.as_bytes()).unwrap();
        assert!(matches!(
            read_request(&mut server, 8192, 65536),
            Err(HttpFail::HeaderTooLarge)
        ));

        let (mut client, mut server) = loopback_pair();
        client
            .write_all(b"POST /x HTTP/1.1\r\nContent-Length: 999999\r\n\r\n")
            .unwrap();
        assert!(matches!(
            read_request(&mut server, 8192, 65536),
            Err(HttpFail::BodyTooLarge)
        ));
    }

    #[test]
    fn torn_request_is_malformed_or_disconnect() {
        let (client, mut server) = loopback_pair();
        {
            let mut c = client;
            c.write_all(b"POST /v1/comp").unwrap();
            // dropped here: torn mid-request-line
        }
        assert!(matches!(
            read_request(&mut server, 8192, 65536),
            Err(HttpFail::Disconnected)
        ));
    }

    #[test]
    fn prompt_parsing_accepts_tokens_text_and_length() {
        let j = Json::parse(r#"{"prompt": [1, 2, 3]}"#).unwrap();
        assert_eq!(parse_prompt(&j), Some(vec![1, 2, 3]));
        let j = Json::parse(r#"{"prompt": "hi"}"#).unwrap();
        assert_eq!(parse_prompt(&j), Some(vec![b'h' as TokenId, b'i' as TokenId]));
        let j = Json::parse(r#"{"prompt_len": 4}"#).unwrap();
        assert_eq!(parse_prompt(&j).map(|p| p.len()), Some(4));
        let j = Json::parse(r#"{"prompt": []}"#).unwrap();
        assert_eq!(parse_prompt(&j), None);
        let j = Json::parse(r#"{}"#).unwrap();
        assert_eq!(parse_prompt(&j), None);
    }

    #[test]
    fn batch_member_shorthand_expands() {
        let j = Json::parse(r#"{"n_requests": 3, "prompt_len": 8, "max_tokens": 4}"#).unwrap();
        let m = parse_batch_members(&j).unwrap();
        assert_eq!(m.len(), 3);
        assert!(m.iter().all(|(p, mx)| p.len() == 8 && *mx == 4));
        let j = Json::parse(r#"{"requests": [{"prompt": [5], "max_tokens": 2}]}"#).unwrap();
        let m = parse_batch_members(&j).unwrap();
        assert_eq!(m, vec![(vec![5], 2)]);
    }

    /// A connected TcpStream pair over an ephemeral loopback listener.
    fn loopback_pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = l.accept().unwrap();
        server
            .set_read_timeout(Some(Duration::from_secs(2)))
            .unwrap();
        (client, server)
    }
}
