use conserve::config::EngineConfig;
use conserve::report::compare_policies;
use conserve::scheduler::Policy;
use conserve::workload::trace::burstgpt_like_arrivals;
use conserve::workload::Lengths;
fn main() {
    let base: f64 = std::env::var("BASE").map(|v| v.parse().unwrap()).unwrap_or(1.2);
    let dur: f64 = std::env::var("DUR").map(|v| v.parse().unwrap()).unwrap_or(450.0);
    let cfg = EngineConfig::sim_a100_7b();
    let arrivals = burstgpt_like_arrivals(42, dur, base, 1.0);
    let rs = compare_policies(&cfg,
        &[Policy::OnlineOnly, Policy::VllmPP, Policy::ConServe], &arrivals,
        Lengths::online_paper(), |p| if p == Policy::OnlineOnly {0} else {1500}, Lengths::offline_paper(), dur);
    for r in &rs { println!("{}", r.row()); }
}
