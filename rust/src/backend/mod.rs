//! Execution backends: layer-group-stepped model execution with
//! preemption **safepoints** between groups (paper §4.3).
//!
//! The serving engine is generic over [`ExecBackend`]:
//!
//! * `PjrtBackend` (cargo feature `pjrt`) — the real path: AOT HLO
//!   artifacts executed through the PJRT CPU client; per-layer
//!   executables give natural safepoints.
//! * [`SimBackend`] — a discrete-event model of the paper's testbed
//!   (A100-40G, Llama-2-7B) driven by [`costmodel::CostModel`]; advances
//!   a virtual clock instead of computing.
//!
//! A safepoint callback runs between layer groups of *preemptible* (pure
//! offline, §4.3) iterations; returning [`SafepointAction::Abort`]
//! models the worker observing the preemption flag: remaining layers are
//! skipped, partial results discarded, and nothing is committed.

pub mod costmodel;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod sim;

use crate::request::{Class, Phase, RequestId, TokenId};
use crate::TimeUs;

pub use costmodel::CostModel;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use sim::SimBackend;

/// One request's work within an iteration.
#[derive(Debug, Clone)]
pub struct WorkItem {
    pub req: RequestId,
    pub class: Class,
    pub phase: Phase,
    /// Committed context length before this iteration.
    pub ctx_len: usize,
    /// New tokens computed this iteration (prefill chunk size, or 1).
    pub n_tokens: usize,
    /// Concrete token ids for this chunk (real path; empty in sim).
    pub tokens: Vec<TokenId>,
}

/// An iteration of continuous batching handed to the backend.
#[derive(Debug, Clone, Default)]
pub struct IterationPlan {
    pub items: Vec<WorkItem>,
    /// Safepoints active: true only for pure-offline batches (§4.3
    /// "restrict layer-wise preemption to the offline batching mode").
    pub preemptible: bool,
}

impl IterationPlan {
    pub fn prefill_tokens(&self) -> usize {
        self.items
            .iter()
            .filter(|i| i.phase == Phase::Prefill)
            .map(|i| i.n_tokens)
            .sum()
    }

    pub fn decode_seqs(&self) -> usize {
        self.items
            .iter()
            .filter(|i| i.phase == Phase::Decode)
            .count()
    }

    pub fn total_new_tokens(&self) -> usize {
        self.items.iter().map(|i| i.n_tokens).sum()
    }

    /// Context tokens whose KV is re-read by attention this iteration.
    pub fn ctx_tokens(&self) -> usize {
        self.items.iter().map(|i| i.ctx_len).sum()
    }

    /// Shape summary in a single pass over the items (computed at least
    /// twice per engine iteration — estimate + execute).
    pub fn summary(&self) -> PlanSummary {
        let mut s = PlanSummary {
            n_seqs: self.items.len(),
            ..PlanSummary::default()
        };
        for i in &self.items {
            match i.phase {
                Phase::Prefill => s.prefill_tokens += i.n_tokens,
                Phase::Decode => s.decode_seqs += 1,
            }
            s.ctx_tokens += i.ctx_len;
        }
        s
    }
}

/// Shape-only view of a plan (profiler estimation input, §4.5).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PlanSummary {
    pub prefill_tokens: usize,
    pub decode_seqs: usize,
    /// Total committed context across items (KV re-read volume).
    pub ctx_tokens: usize,
    pub n_seqs: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SafepointAction {
    Continue,
    /// Abort remaining layers; discard partial work (worker preemption).
    Abort,
}

#[derive(Debug)]
pub struct ExecOutcome {
    /// False if the iteration was aborted at a safepoint.
    pub completed: bool,
    /// Per item (plan order): sampled next token for items that finished
    /// a phase step. The simulator returns an *empty* vec (it samples
    /// nothing) so the steady-state loop allocates nothing; consumers
    /// index with `.get(i)`.
    pub new_tokens: Vec<Option<TokenId>>,
    pub elapsed_us: u64,
    /// Safepoint checks performed (for §6.4.2 accounting).
    pub safepoint_checks: usize,
}

pub trait ExecBackend {
    /// Execute one iteration. `safepoint` is invoked between layer
    /// groups when `plan.preemptible`; it receives the current time.
    fn execute(
        &mut self,
        plan: &IterationPlan,
        safepoint: &mut dyn FnMut(TimeUs) -> SafepointAction,
    ) -> anyhow::Result<ExecOutcome>;

    /// Ground-truth iteration time for a hypothetical plan shape, used to
    /// build the offline profile (§4.5). The simulator answers from its
    /// cost model; the real backend measures probe executions.
    fn probe_us(&mut self, summary: &PlanSummary) -> u64;

    /// Forget a request's device state (discard preemption / finish).
    fn drop_request(&mut self, req: RequestId);

    /// Drop only the *device* copy of a request's KV (checkpoint-backed
    /// eviction, §4.4): host mirrors survive for later prefetch.
    fn evict_device(&mut self, _req: RequestId) {}

    /// Copy one KV block D2H (checkpoint commit). Real backend memcpys
    /// slab -> host mirror; sim is accounting-only.
    fn copy_block_d2h(&mut self, req: RequestId, block_idx: usize, block_tokens: usize);

    /// Copy one KV block H2D (prefetch commit).
    fn copy_block_h2d(&mut self, req: RequestId, block_idx: usize, block_tokens: usize);

    /// KV bytes per block (drives the swap engine).
    fn block_bytes(&self) -> u64;

    /// Host<->device link bandwidth in bytes/s.
    fn link_bandwidth(&self) -> u64;

    /// Safepoint synchronization cost in µs (§6.4.2: 988 µs measured).
    fn safepoint_cost_us(&self) -> u64;

    /// Layer groups per iteration (n_layers / safepoint_layers).
    fn n_layer_groups(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_summary_counts() {
        let plan = IterationPlan {
            items: vec![
                WorkItem {
                    req: 1,
                    class: Class::Online,
                    phase: Phase::Prefill,
                    ctx_len: 0,
                    n_tokens: 512,
                    tokens: vec![],
                },
                WorkItem {
                    req: 2,
                    class: Class::Offline,
                    phase: Phase::Decode,
                    ctx_len: 1024,
                    n_tokens: 1,
                    tokens: vec![],
                },
            ],
            preemptible: false,
        };
        let s = plan.summary();
        assert_eq!(s.prefill_tokens, 512);
        assert_eq!(s.decode_seqs, 1);
        assert_eq!(s.ctx_tokens, 1024);
        assert_eq!(plan.total_new_tokens(), 513);
    }
}
