"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/context lengths; assert_allclose against
ref.py. This is the core numerical signal for the artifact pipeline: the
same kernel code is lowered into every layer_fwd HLO module.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention
from compile.kernels.rmsnorm import rmsnorm
from compile.kernels.ref import attention_ref, rmsnorm_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- attention

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    h_pairs=st.sampled_from([(1, 1), (2, 1), (4, 2), (4, 4), (8, 2)]),
    t=st.sampled_from([1, 2, 8, 16]),
    s_mult=st.integers(1, 3),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(b, h_pairs, t, s_mult, dh, seed):
    h, hkv = h_pairs
    s = 64 * s_mult
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, h, t, dh), dtype=np.float32))
    kc = jnp.asarray(rng.standard_normal((b, hkv, s, dh), dtype=np.float32))
    vc = jnp.asarray(rng.standard_normal((b, hkv, s, dh), dtype=np.float32))
    ctx = jnp.asarray(rng.integers(0, s - t + 1, size=b), dtype=jnp.int32)

    out = attention(q, kc, vc, ctx, block_k=64)
    ref = attention_ref(q, kc, vc, ctx)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_attention_decode_t1():
    q = rand(0, (8, 4, 1, 32))
    kc = rand(1, (8, 2, 256, 32))
    vc = rand(2, (8, 2, 256, 32))
    ctx = jnp.arange(8, dtype=jnp.int32) * 30
    np.testing.assert_allclose(
        attention(q, kc, vc, ctx), attention_ref(q, kc, vc, ctx),
        rtol=3e-5, atol=3e-5,
    )


def test_attention_zero_context():
    """First prefill chunk: ctx=0, queries only attend within the chunk."""
    q = rand(3, (2, 4, 16, 32))
    kc = rand(4, (2, 2, 128, 32))
    vc = rand(5, (2, 2, 128, 32))
    ctx = jnp.zeros(2, jnp.int32)
    np.testing.assert_allclose(
        attention(q, kc, vc, ctx), attention_ref(q, kc, vc, ctx),
        rtol=3e-5, atol=3e-5,
    )


def test_attention_full_cache_edge():
    """Last decode slot: ctx = S - 1."""
    q = rand(6, (1, 4, 1, 32))
    kc = rand(7, (1, 2, 128, 32))
    vc = rand(8, (1, 2, 128, 32))
    ctx = jnp.array([127], jnp.int32)
    np.testing.assert_allclose(
        attention(q, kc, vc, ctx), attention_ref(q, kc, vc, ctx),
        rtol=3e-5, atol=3e-5,
    )


def test_attention_causality():
    """Future cache slots must not influence the output: perturbing slots
    beyond the causal frontier leaves the result bit-identical."""
    q = rand(9, (1, 2, 4, 16))
    kc = rand(10, (1, 1, 64, 16))
    vc = rand(11, (1, 1, 64, 16))
    ctx = jnp.array([10], jnp.int32)  # frontier: positions 10..13
    out1 = attention(q, kc, vc, ctx)
    kc2 = kc.at[:, :, 20:, :].set(99.0)
    vc2 = vc.at[:, :, 20:, :].set(-99.0)
    out2 = attention(q, kc2, vc2, ctx)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_attention_gqa_head_mapping():
    """With Hkv=H (MHA) and KV heads duplicated, GQA must agree with MHA."""
    q = rand(12, (2, 4, 8, 16))
    kc = rand(13, (2, 2, 64, 16))
    vc = rand(14, (2, 2, 64, 16))
    ctx = jnp.array([3, 40], jnp.int32)
    out_gqa = attention(q, kc, vc, ctx)
    kc_mha = jnp.repeat(kc, 2, axis=1)
    vc_mha = jnp.repeat(vc, 2, axis=1)
    out_mha = attention(q, kc_mha, vc_mha, ctx)
    np.testing.assert_allclose(out_gqa, out_mha, rtol=1e-6, atol=1e-6)


def test_attention_block_k_invariance():
    """Streaming chunk size must not change numerics."""
    q = rand(15, (2, 4, 8, 32))
    kc = rand(16, (2, 2, 256, 32))
    vc = rand(17, (2, 2, 256, 32))
    ctx = jnp.array([100, 7], jnp.int32)
    outs = [attention(q, kc, vc, ctx, block_k=bk) for bk in (32, 64, 128, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-5, atol=2e-5)


def test_attention_softmax_rowsum():
    """With V = all-ones, attention output must be exactly 1 (softmax sums
    to 1 regardless of mask width)."""
    q = rand(18, (2, 4, 4, 16))
    kc = rand(19, (2, 2, 64, 16))
    vc = jnp.ones((2, 2, 64, 16), jnp.float32)
    ctx = jnp.array([0, 33], jnp.int32)
    out = attention(q, kc, vc, ctx)
    np.testing.assert_allclose(out, jnp.ones_like(out), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ rmsnorm

@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 4, 8, 32, 64]),
    d=st.sampled_from([16, 128, 256]),
    scale=st.floats(0.01, 100.0),
    seed=st.integers(0, 2**16),
)
def test_rmsnorm_matches_ref(n, d, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, d), dtype=np.float32)) * scale
    w = jnp.asarray(rng.standard_normal(d, dtype=np.float32))
    bn = 1 if n % 8 else 8
    np.testing.assert_allclose(
        rmsnorm(x, w, block_n=bn), rmsnorm_ref(x, w), rtol=2e-5, atol=2e-5
    )


def test_rmsnorm_unit_weight_norm():
    """With w=1 the output rows have RMS ~= 1."""
    x = rand(20, (16, 128)) * 7.0
    out = rmsnorm(x, jnp.ones(128))
    rms = jnp.sqrt(jnp.mean(out**2, axis=-1))
    np.testing.assert_allclose(rms, jnp.ones(16), rtol=1e-3)


def test_rmsnorm_scale_invariance():
    """RMSNorm(c*x) == RMSNorm(x) for c > 0 (up to eps)."""
    x = rand(21, (8, 64))
    w = rand(22, (64,))
    np.testing.assert_allclose(
        rmsnorm(x, w), rmsnorm(x * 1000.0, w), rtol=1e-4, atol=1e-4
    )
