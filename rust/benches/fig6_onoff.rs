//! Figure 6 — "Overall serving performance on ON/OFF phased workloads."
//!
//! Online load alternates between the system's max sustainable rate (ON)
//! and zero (OFF) every 180 s; requests are the paper's representative
//! 1024-input / 128-output. A good reproduction shows: (1) online tail
//! latency below SLO during ON phases under ConServe, (2) offline
//! throughput surging during OFF phases (harvest), (3) fast scale-down at
//! the OFF->ON edge without latency spikes, while vLLM++ violates SLOs
//! during ON.

use conserve::config::EngineConfig;
use conserve::report::compare_policies;
use conserve::scheduler::Policy;
use conserve::workload::trace::onoff_trace;
use conserve::workload::Lengths;

fn main() {
    let cfg = EngineConfig::sim_a100_7b();
    let duration = 720.0;
    let phase = 180.0;
    let on_rate = 3.0; // near max capacity for 1024/128 requests (see EXPERIMENTS.md)
    let arrivals = onoff_trace(42, duration, phase, on_rate, 1.0);
    println!(
        "ON/OFF load: {} req, {phase}s phases, ON rate {on_rate}/s, input 1024 / output 128\n",
        arrivals.len()
    );

    let reports = compare_policies(
        &cfg,
        &[Policy::OnlineOnly, Policy::VllmPP, Policy::ConServe],
        &arrivals,
        Lengths::Fixed {
            input: 1024,
            output: 128,
        },
        |p| if p == Policy::OnlineOnly { 0 } else { 4000 },
        Lengths::offline_paper(),
        duration,
    );

    println!("--- aggregates ---");
    for r in &reports {
        println!("{}", r.row());
    }

    let cs = &reports[2];
    let vpp = &reports[1];

    println!("\n--- ConServe timeseries (15 s windows) ---");
    println!(
        "{:>6} {:>7} {:>12} {:>12} {:>14} {:>14}",
        "t_s", "phase", "p99TTFT_ms", "p99TPOT_ms", "online_proc/s", "offl_proc/s"
    );
    let mut on_ttfts: Vec<f64> = Vec::new();
    let mut off_offline_tput: Vec<f64> = Vec::new();
    for (w_on, w_all) in cs.online_timeseries.iter().zip(&cs.all_timeseries) {
        let in_on = ((w_on.start_s / phase) as u64) % 2 == 0;
        let offl = w_all.processed_per_s - w_on.processed_per_s;
        println!(
            "{:>6.0} {:>7} {:>12.0} {:>12.0} {:>14.0} {:>14.0}",
            w_on.start_s,
            if in_on { "ON" } else { "OFF" },
            w_on.p99_ttft_ms,
            w_on.p99_tpot_ms,
            w_on.processed_per_s,
            offl
        );
        if in_on && w_on.n_ttft > 3 {
            on_ttfts.push(w_on.p99_ttft_ms);
        }
        if !in_on {
            off_offline_tput.push(offl);
        }
    }

    let worst_on_ttft = on_ttfts.iter().cloned().fold(0.0, f64::max);
    let avg_off_harvest =
        off_offline_tput.iter().sum::<f64>() / off_offline_tput.len().max(1) as f64;
    println!("\nConServe worst ON-phase windowed P99 TTFT: {worst_on_ttft:.0} ms (SLO 1500, paper <350)");
    println!("ConServe avg OFF-phase offline throughput: {avg_off_harvest:.0} tok/s (paper 5868)");
    println!(
        "vLLM++ P99 TTFT {:.0} ms vs ConServe {:.0} ms ({:.1}x, paper 1.4-11x)",
        vpp.online_p99_ttft_ms,
        cs.online_p99_ttft_ms,
        vpp.online_p99_ttft_ms / cs.online_p99_ttft_ms.max(1.0)
    );

    // worst window is the OFF->ON transition (queue behind the aborted
    // offline batch + evictions); steady ON windows sit near/below SLO.
    assert!(
        worst_on_ttft < cfg.sched.slo.ttft_ms * 2.0,
        "ConServe must hold TTFT through ON phases (got {worst_on_ttft:.0}ms)"
    );
    assert!(
        avg_off_harvest > 3000.0,
        "OFF phases must be harvested (got {avg_off_harvest:.0} tok/s)"
    );
    assert!(vpp.online_p99_ttft_ms > 1.3 * cs.online_p99_ttft_ms);
    println!("\nfig6 shape OK");
}
