//! Figure 8 — "All ConServe's optimizations work in tandem to improve
//! performance."
//!
//! Incremental ablation at CV=1, 2 req/s (the Fig.-7 midpoint):
//!   1. vLLM++ (naive priority co-serving)
//!   2. + preemptive SLO-aware scheduler        (TTFT drops sharply,
//!      offline throughput dips — discard preemptions waste work)
//!   3. + incremental checkpointing             (recovers part of the loss)
//!   4. + background prefetching = full ConServe (recovers the rest)
//!
//! Paper numbers: 3674 tok/s @ 1346 ms -> 2951 @ 446 -> +14.0% -> +13.6%
//! ending at 3818 tok/s with TTFT down 76.5%.

use conserve::config::EngineConfig;
use conserve::report::SimExperiment;
use conserve::scheduler::Policy;
use conserve::workload::{LoadGen, Lengths};

struct Step {
    name: &'static str,
    policy: Policy,
    slo_aware: bool,
    ckpt: bool,
    prefetch: bool,
}

fn main() {
    let steps = [
        Step {
            name: "vLLM++",
            policy: Policy::VllmPP,
            slo_aware: false,
            ckpt: false,
            prefetch: false,
        },
        Step {
            name: "+sched",
            policy: Policy::ConServe,
            slo_aware: true,
            ckpt: false,
            prefetch: false,
        },
        Step {
            name: "+incr-ckpt",
            policy: Policy::ConServe,
            slo_aware: true,
            ckpt: true,
            prefetch: false,
        },
        Step {
            name: "+prefetch",
            policy: Policy::ConServe,
            slo_aware: true,
            ckpt: true,
            prefetch: true,
        },
    ];

    let duration = 300.0;
    let base = EngineConfig::sim_a100_7b();
    let mut lg = LoadGen::new(base.seed, 2.0, 1.0);
    let arrivals = lg.arrivals_until(duration);

    let mut rows = Vec::new();
    println!(
        "{:<12} {:>12} {:>12} {:>14} {:>12} {:>10}",
        "config", "p99TTFT_ms", "p99TPOT_ms", "offl_proc/s", "preempts", "ckpt_blks"
    );
    for s in &steps {
        let mut cfg = base.clone();
        cfg.sched.policy = s.policy;
        cfg.sched.slo_aware = s.slo_aware;
        cfg.sched.incremental_ckpt = s.ckpt;
        cfg.sched.prefetch = s.prefetch;
        if s.policy == Policy::VllmPP {
            cfg.sched.layerwise_preempt = false;
        }
        let r = SimExperiment {
            cfg,
            online_arrivals: arrivals.clone(),
            online_lengths: Lengths::Fixed {
                input: 1024,
                output: 128,
            },
            offline_pool: 1200,
            offline_lengths: Lengths::offline_paper(),
            duration_s: duration,
        }
        .run();
        println!(
            "{:<12} {:>12.0} {:>12.0} {:>14.0} {:>12} {:>10}",
            s.name,
            r.online_p99_ttft_ms,
            r.online_p99_tpot_ms,
            r.offline_processed_tput,
            r.preemptions,
            r.ckpt_blocks
        );
        rows.push(r);
    }

    let ttft_drop =
        1.0 - rows[3].online_p99_ttft_ms / rows[0].online_p99_ttft_ms.max(1.0);
    let ckpt_gain = rows[2].offline_processed_tput / rows[1].offline_processed_tput.max(1.0);
    let pf_gain = rows[3].offline_processed_tput / rows[2].offline_processed_tput.max(1.0);
    println!("\nTTFT reduction vLLM++ -> full ConServe: {:.1}% (paper 76.5%)", ttft_drop * 100.0);
    println!("incremental-ckpt throughput gain: {:.1}% (paper +14.0%)", (ckpt_gain - 1.0) * 100.0);
    println!("prefetch throughput gain:         {:.1}% (paper +13.6%)", (pf_gain - 1.0) * 100.0);

    // shape assertions
    assert!(
        rows[1].online_p99_ttft_ms < 0.6 * rows[0].online_p99_ttft_ms,
        "SLO-aware scheduling must cut TTFT sharply"
    );
    // Deviation (EXPERIMENTS.md): with a deep always-available offline
    // pool, fresh admissions substitute for resumed work, so the +14%
    // / +13.6% throughput recoveries the paper measured show up here as
    // mechanism counters instead of aggregate throughput: checkpointing
    // converts discard-preemptions into free evictions, and prefetching
    // removes blocking swap-ins.
    assert!(
        rows[2].offline_processed_tput >= rows[1].offline_processed_tput * 0.95,
        "incremental checkpointing must not cost meaningful throughput"
    );
    assert!(rows[2].ckpt_blocks > 0, "checkpointing must be active");
    assert!(
        rows[3].offline_processed_tput >= rows[2].offline_processed_tput * 0.95,
        "prefetching must not cost meaningful throughput"
    );
    assert!(
        rows[3].blocking_swap_ms <= rows[2].blocking_swap_ms,
        "prefetching must not add blocking I/O"
    );
    assert!(
        rows[3].online_p99_ttft_ms < 0.6 * rows[0].online_p99_ttft_ms,
        "full ConServe keeps the latency win"
    );
    println!("\nfig8 shape OK");
}
