//! Placement policies: which worker shard admits the next request.
//!
//! Placement runs at *submission* time (the router or the sharded
//! client), never inside a shard's scheduling loop, and works from
//! [`LoadSnapshot`]s — cheap per-shard load summaries that the trace
//! router maintains as admission-time estimates and the live engines
//! publish through [`ShardLoads`](super::ShardLoads) as relaxed atomics.
//! Nothing here takes a lock.
//!
//! Five policies (mirroring the global admission layers of HyGen and
//! Echo, which route hybrid online/offline load across replicas):
//!
//! * [`Placement::RoundRobin`] — stateless rotation; the baseline.
//! * [`Placement::LeastKv`] — least resident KV blocks: balances memory
//!   footprint, which on this engine is the binding resource.
//! * [`Placement::Affinity`] — the paper's SLO model applied across
//!   shards: online requests spread by *online* KV footprint (keeping
//!   every shard's latency-critical reserve small and even); offline
//!   requests score shards by an online-weighted footprint (an online
//!   block is charged 3x: its resident charge plus twice more, so
//!   offline drifts away from online-heavy shards in proportion to
//!   their SLO-critical load) and avoid shards that would cross the
//!   absolute `headroom` reserve line.
//! * [`Placement::PrefixAffinity`] — prefix-aware routing for shared-
//!   prompt traffic (`kvcache::prefix`): shards publish a compact
//!   membership digest of their prefix-cache contents through
//!   [`LoadSnapshot::prefix_digest`], the router hashes the incoming
//!   prompt's block prefixes ([`crate::kvcache::prefix_probes`]) via
//!   [`Placement::pick_prefix`], and the shard whose digest may hold
//!   the longest leading run of those hashes wins — so repeat prompts
//!   land where their KV already lives. Load scoring (affinity-style)
//!   breaks ties, and a shard that cannot fit the request never wins
//!   on digest hits alone.
//! * [`Placement::Deadline`] — job-aware offline placement
//!   (crate::batch): affinity's scoring plus a queue-delay penalty that
//!   scales with the request's EDF urgency, so an urgent job request
//!   lands where it *starts soonest* (shallow offline backlog) while a
//!   lax one still balances footprint.
//!
//! Offline scoring under `Affinity`/`Deadline` is additionally
//! *steal-aware*: each shard's published [`LoadSnapshot::steal_score`]
//! (a decaying count of recently adopted steals) earns a discount —
//! a shard that recently acted as a thief is demonstrably under-loaded,
//! and routing fresh offline work straight there saves the migration
//! the steal coordinator would otherwise perform.
//!
//! Placement is also the layer crash recovery leans on: after a shard
//! death, the recovery driver ([`crate::batch::run_jobs_with_recovery`])
//! re-routes the dead shard's resumed work through a fresh router over
//! the *survivor* fleet. Because load estimates accumulate as requests
//! are placed, a recovery burst — many checkpointed requests arriving
//! at once at t=0 — spreads across the survivors instead of piling onto
//! one shard (asserted by `recovery_burst_spreads_across_survivors`
//! below).

use crate::kvcache::prefix::digest_contains;
use crate::kvcache::PREFIX_DIGEST_WORDS;
use crate::request::{Class, URGENCY_MAX};

/// Per-shard load summary consumed by [`Placement::pick`] and the
/// work-stealing imbalance detector ([`crate::shard::steal`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LoadSnapshot {
    /// KV blocks resident (or, for the trace router, cumulatively
    /// admitted) on this shard.
    pub resident_blocks: u64,
    /// Portion of `resident_blocks` that belongs to online requests.
    pub online_blocks: u64,
    /// Requests waiting in this shard's admission queues.
    pub waiting: u64,
    /// Portion of `waiting` that is offline backlog — the signal the
    /// steal coordinator balances (deep offline tails migrate to shards
    /// reporting zero here).
    pub offline_waiting: u64,
    /// Decaying count of offline requests this shard recently adopted
    /// via work stealing, in 1/16ths (one fresh steal publishes as 16
    /// and decays by x7/8 per engine iteration). Placement discounts
    /// offline scores by [`STEAL_BIAS_BLOCKS`] per fresh steal (score
    /// 16) — recent thieves attract fresh offline work directly.
    pub steal_score: u64,
    /// The shard's GPU KV pool size in blocks.
    pub capacity_blocks: u64,
    /// Membership digest of the shard's prefix cache
    /// ([`crate::kvcache::PrefixIndex::digest`]): one-sided, so a zero
    /// word pattern means "definitely not resident". All-zero when the
    /// shard runs with the prefix cache off.
    pub prefix_digest: [u64; PREFIX_DIGEST_WORDS],
}

/// Offline-score discount, in blocks, per *freshly adopted steal*: a
/// steal publishes as 16 units of [`LoadSnapshot::steal_score`] (which
/// then decay x7/8 per iteration), and each fresh steal is worth this
/// many blocks of head start in the offline placement argmin.
pub const STEAL_BIAS_BLOCKS: u64 = 8;

/// Queue-delay penalty (blocks-equivalent per queued offline request)
/// applied by [`Placement::Deadline`] at full urgency; scales linearly
/// down to 0 for urgency-0 requests, where the policy degenerates to
/// affinity scoring.
pub const QUEUE_PENALTY_BLOCKS: u64 = 32;

/// Pluggable shard-placement policy. See the module docs for the
/// semantics of each variant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Placement {
    /// Rotate over shards regardless of load.
    RoundRobin,
    /// Fewest resident KV blocks (ties: fewest waiting, lowest index).
    LeastKv,
    /// Online/offline affinity: spread online work by online footprint;
    /// steer offline work away from online-heavy shards (an online
    /// block weighs 3x an offline one in its score) and keep `headroom`
    /// (a fraction of each shard's KV capacity) clear of offline
    /// placements so online bursts always find room.
    Affinity {
        /// Fraction of per-shard KV capacity reserved for online work
        /// (offline placement avoids shards that would cross it).
        headroom: f64,
    },
    /// Prefix-affinity: among shards that fit the request, prefer the
    /// one whose published prefix digest may hold the longest leading
    /// run of the prompt's block-prefix hashes (the request's KV is
    /// already resident there); affinity-style load scores break ties.
    /// Without probes (no prompt, or prefix cache off) this degenerates
    /// to [`Placement::Affinity`] scoring.
    PrefixAffinity {
        /// Online reserve fraction, as in [`Placement::Affinity`].
        headroom: f64,
    },
    /// Deadline-aware job placement: affinity scoring plus an
    /// urgency-scaled queue-delay penalty per queued offline request
    /// ([`QUEUE_PENALTY_BLOCKS`]), so urgent job requests land on the
    /// shard where they start soonest. Online requests place exactly as
    /// under [`Placement::Affinity`].
    Deadline {
        /// Online reserve fraction, as in [`Placement::Affinity`].
        headroom: f64,
    },
}

impl Placement {
    /// The default affinity policy (10% online reserve per shard).
    pub fn affinity() -> Self {
        Placement::Affinity { headroom: 0.1 }
    }

    /// The default deadline-aware policy (10% online reserve per shard).
    pub fn deadline() -> Self {
        Placement::Deadline { headroom: 0.1 }
    }

    /// The default prefix-affinity policy (10% online reserve per shard).
    pub fn prefix_affinity() -> Self {
        Placement::PrefixAffinity { headroom: 0.1 }
    }

    /// Choose a shard for a request of `class` needing `need_blocks` KV
    /// blocks at full length. `urgency` is the request's EDF score
    /// (0 for standalone requests; only [`Placement::Deadline`] reads
    /// it). `loads` has one entry per shard; `tick` is a
    /// caller-maintained monotone counter (drives round-robin).
    /// Deterministic: ties always resolve to the lowest shard index.
    pub fn pick(
        &self,
        class: Class,
        need_blocks: u64,
        urgency: u32,
        loads: &[LoadSnapshot],
        tick: usize,
    ) -> usize {
        self.pick_prefix(class, need_blocks, urgency, loads, tick, &[])
    }

    /// [`pick`](Self::pick) with the prompt's block-prefix hashes
    /// ([`crate::kvcache::prefix_probes`]). Only
    /// [`Placement::PrefixAffinity`] reads `probes`; every other policy
    /// (and an empty slice) behaves exactly as `pick`.
    pub fn pick_prefix(
        &self,
        class: Class,
        need_blocks: u64,
        urgency: u32,
        loads: &[LoadSnapshot],
        tick: usize,
        probes: &[u64],
    ) -> usize {
        assert!(!loads.is_empty(), "placement over zero shards");
        match *self {
            Placement::RoundRobin => tick % loads.len(),
            Placement::LeastKv => argmin(loads, |l| (l.resident_blocks, l.waiting)),
            Placement::PrefixAffinity { headroom } => {
                use std::cmp::Reverse;
                // resident-prefix estimate: leading probes the shard's
                // digest may contain. One-sided (no false negatives), so
                // a zero score means the prefix is definitely cold there.
                let hit_len = |l: &LoadSnapshot| {
                    probes
                        .iter()
                        .take_while(|&&h| digest_contains(&l.prefix_digest, h))
                        .count()
                };
                match class {
                    Class::Online => {
                        let fits = |l: &LoadSnapshot| {
                            l.resident_blocks + need_blocks <= l.capacity_blocks
                        };
                        argmin(loads, |l| {
                            (
                                u8::from(!fits(l)),
                                Reverse(hit_len(l)),
                                l.online_blocks,
                                l.resident_blocks,
                            )
                        })
                    }
                    Class::Offline => {
                        let fits = |l: &LoadSnapshot| {
                            let limit =
                                (l.capacity_blocks as f64 * (1.0 - headroom)) as u64;
                            l.resident_blocks + need_blocks <= limit
                        };
                        argmin(loads, |l| {
                            let weighted = l
                                .resident_blocks
                                .saturating_add(l.online_blocks.saturating_mul(2));
                            (u8::from(!fits(l)), Reverse(hit_len(l)), weighted, l.waiting)
                        })
                    }
                }
            }
            Placement::Affinity { headroom } | Placement::Deadline { headroom } => {
                match class {
                    Class::Online => {
                        // spread by online footprint, but never route onto a
                        // shard whose pool can't fit the request while an
                        // alternative can — a packed shard would have to
                        // preempt offline work (recompute churn) where an
                        // emptier one starts instantly. Online may use the
                        // reserve, so the fit check is against full capacity.
                        let fits = |l: &LoadSnapshot| {
                            l.resident_blocks + need_blocks <= l.capacity_blocks
                        };
                        argmin(loads, |l| {
                            (u8::from(!fits(l)), l.online_blocks, l.resident_blocks)
                        })
                    }
                    Class::Offline => {
                        // prefer shards that can take this request and still
                        // keep the absolute online reserve clear; among them
                        // (or among all, when none fits — e.g. the cumulative
                        // estimates of a long trace) score by the
                        // online-weighted footprint: an online block counts
                        // 3x an offline one (resident charge + 2x on top),
                        // so offline load drifts away from online-heavy
                        // shards in proportion to their latency-critical
                        // demand. Recent thieves earn a steal-score
                        // discount, and the Deadline policy adds an
                        // urgency-scaled penalty per queued offline
                        // request so urgent jobs start soonest.
                        let queue_penalty = match self {
                            Placement::Deadline { .. } => {
                                QUEUE_PENALTY_BLOCKS * u64::from(urgency)
                                    / u64::from(URGENCY_MAX)
                            }
                            _ => 0,
                        };
                        let fits = |l: &LoadSnapshot| {
                            let limit =
                                (l.capacity_blocks as f64 * (1.0 - headroom)) as u64;
                            l.resident_blocks + need_blocks <= limit
                        };
                        argmin(loads, |l| {
                            let weighted = l
                                .resident_blocks
                                .saturating_add(l.online_blocks.saturating_mul(2))
                                .saturating_add(
                                    l.offline_waiting.saturating_mul(queue_penalty),
                                )
                                .saturating_sub(
                                    l.steal_score.saturating_mul(STEAL_BIAS_BLOCKS) / 16,
                                );
                            (u8::from(!fits(l)), weighted, l.waiting)
                        })
                    }
                }
            }
        }
    }
}

/// Index of the minimal key; ties resolve to the lowest index.
fn argmin<K: Ord>(loads: &[LoadSnapshot], key: impl Fn(&LoadSnapshot) -> K) -> usize {
    let mut best = 0;
    let mut best_key = key(&loads[0]);
    for (i, l) in loads.iter().enumerate().skip(1) {
        let k = key(l);
        if k < best_key {
            best = i;
            best_key = k;
        }
    }
    best
}

impl std::str::FromStr for Placement {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" | "round_robin" => {
                Ok(Placement::RoundRobin)
            }
            "least-kv" | "leastkv" | "least_kv" | "least-loaded" => {
                Ok(Placement::LeastKv)
            }
            "affinity" | "online-affinity" | "online_affinity" => {
                Ok(Placement::affinity())
            }
            "deadline" | "edf" | "deadline-aware" => Ok(Placement::deadline()),
            "prefix" | "prefix-affinity" | "prefix_affinity" => {
                Ok(Placement::prefix_affinity())
            }
            other => {
                // "affinity:H" / "deadline:H" carry an explicit headroom
                // fraction, the form Display emits so round-trips are
                // lossless
                fn headroom_of(h: &str) -> anyhow::Result<f64> {
                    let headroom: f64 = h
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad headroom `{h}`: {e}"))?;
                    if !(0.0..1.0).contains(&headroom) {
                        anyhow::bail!("headroom must be in [0, 1): `{h}`");
                    }
                    Ok(headroom)
                }
                if let Some(h) = other.strip_prefix("affinity:") {
                    Ok(Placement::Affinity {
                        headroom: headroom_of(h)?,
                    })
                } else if let Some(h) = other.strip_prefix("deadline:") {
                    Ok(Placement::Deadline {
                        headroom: headroom_of(h)?,
                    })
                } else if let Some(h) = other.strip_prefix("prefix-affinity:") {
                    Ok(Placement::PrefixAffinity {
                        headroom: headroom_of(h)?,
                    })
                } else {
                    Err(anyhow::anyhow!("unknown placement policy `{other}`"))
                }
            }
        }
    }
}

impl std::fmt::Display for Placement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Placement::RoundRobin => f.write_str("round-robin"),
            Placement::LeastKv => f.write_str("least-kv"),
            // explicit headroom so Display/FromStr round-trip losslessly
            Placement::Affinity { headroom } => write!(f, "affinity:{headroom}"),
            Placement::Deadline { headroom } => write!(f, "deadline:{headroom}"),
            Placement::PrefixAffinity { headroom } => {
                write!(f, "prefix-affinity:{headroom}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(resident: u64, online: u64, waiting: u64) -> LoadSnapshot {
        LoadSnapshot {
            resident_blocks: resident,
            online_blocks: online,
            waiting,
            capacity_blocks: 100,
            ..LoadSnapshot::default()
        }
    }

    #[test]
    fn round_robin_cycles() {
        let loads = vec![snap(9, 0, 0), snap(0, 0, 0), snap(5, 0, 0)];
        let p = Placement::RoundRobin;
        let picks: Vec<usize> = (0..6)
            .map(|t| p.pick(Class::Online, 1, 0, &loads, t))
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_kv_picks_min_resident_then_waiting() {
        let p = Placement::LeastKv;
        let loads = vec![snap(30, 0, 0), snap(10, 0, 5), snap(10, 0, 1)];
        assert_eq!(p.pick(Class::Offline, 1, 0, &loads, 0), 2);
        // ties resolve to the lowest index
        let even = vec![snap(10, 0, 1), snap(10, 0, 1)];
        assert_eq!(p.pick(Class::Online, 1, 0, &even, 7), 0);
    }

    #[test]
    fn affinity_spreads_online_by_online_footprint() {
        let p = Placement::affinity();
        // shard 0 has less total KV but more *online* KV than shard 1
        let loads = vec![snap(20, 18, 0), snap(40, 2, 0)];
        assert_eq!(p.pick(Class::Online, 1, 0, &loads, 0), 1);
        // offline also dodges the online-heavy shard: weighted scores
        // 20 + 2*18 = 56 vs 40 + 2*2 = 44
        assert_eq!(p.pick(Class::Offline, 1, 0, &loads, 0), 1);
        // with equal online load, offline goes to the emptier shard
        let even_online = vec![snap(20, 5, 0), snap(40, 5, 0)];
        assert_eq!(p.pick(Class::Offline, 1, 0, &even_online, 0), 0);
    }

    #[test]
    fn affinity_offline_respects_online_reserve() {
        let p = Placement::Affinity { headroom: 0.2 };
        // capacity 100, reserve line at 80 with need 10: shard 1 has the
        // lower weighted score (75 vs 60 + 2*30 = 120) but would cross
        // the reserve line (75 + 10 > 80); shard 0 still fits (70 <= 80)
        let loads = vec![snap(60, 30, 0), snap(75, 0, 0)];
        assert_eq!(p.pick(Class::Offline, 10, 0, &loads, 0), 0);
        // when nothing fits, fall back to weighted least-loaded
        let full = vec![snap(95, 60, 0), snap(99, 0, 0)];
        assert_eq!(p.pick(Class::Offline, 10, 0, &full, 0), 1);
    }

    #[test]
    fn affinity_online_avoids_full_shards() {
        let p = Placement::affinity();
        // shard 0 has fewer online blocks but its pool can't fit the
        // request (95 + 8 > 100); shard 1 can and must win
        let loads = vec![snap(95, 5, 0), snap(10, 6, 0)];
        assert_eq!(p.pick(Class::Online, 8, 0, &loads, 0), 1);
        // with room everywhere, least-online still wins
        assert_eq!(p.pick(Class::Online, 1, 0, &loads, 0), 0);
    }

    #[test]
    fn deadline_policy_sends_urgent_work_to_shallow_queues() {
        let p = Placement::deadline();
        // shard 0: lighter footprint but a deep offline backlog;
        // shard 1: heavier footprint, empty queue
        let mut loads = vec![snap(20, 0, 10), snap(50, 0, 0)];
        loads[0].offline_waiting = 10;
        // a lax request (urgency 0) balances footprint: shard 0
        assert_eq!(p.pick(Class::Offline, 1, 0, &loads, 0), 0);
        // an urgent one pays 32 blocks per queued request at full
        // urgency: 20 + 10*32 >> 50, so it starts on the empty shard
        assert_eq!(p.pick(Class::Offline, 1, URGENCY_MAX, &loads, 0), 1);
        // online placement is unchanged affinity behavior
        assert_eq!(p.pick(Class::Online, 1, URGENCY_MAX, &loads, 0), 0);
    }

    #[test]
    fn offline_placement_prefers_recent_thieves() {
        let p = Placement::affinity();
        // equal footprints; shard 1 recently adopted a steal (score 16
        // => 8-block discount) and must win the offline argmin
        let mut loads = vec![snap(40, 0, 0), snap(40, 0, 0)];
        loads[1].steal_score = 16;
        assert_eq!(p.pick(Class::Offline, 1, 0, &loads, 0), 1);
        // the discount is bounded: a clearly lighter shard still wins
        let mut uneven = vec![snap(10, 0, 0), snap(40, 0, 0)];
        uneven[1].steal_score = 16;
        assert_eq!(p.pick(Class::Offline, 1, 0, &uneven, 0), 0);
        // online placement ignores the steal signal
        assert_eq!(p.pick(Class::Online, 1, 0, &loads, 0), 0);
    }

    #[test]
    fn recovery_burst_spreads_across_survivors() {
        // a recovery round re-places a burst of resumed offline
        // requests onto the survivor fleet at t=0: with cumulative
        // admission-time estimates (what ShardRouter maintains), the
        // argmin must rotate across survivors, not dogpile shard 0
        let p = Placement::deadline();
        let mut loads = vec![LoadSnapshot::default(); 3];
        for l in &mut loads {
            l.capacity_blocks = 100;
        }
        let need = 4u64;
        let mut per_shard = [0usize; 3];
        for _ in 0..24 {
            let s = p.pick(Class::Offline, need, 0, &loads, 0);
            per_shard[s] += 1;
            // what the router's estimate update does on admission
            loads[s].resident_blocks += need;
            loads[s].waiting += 1;
            loads[s].offline_waiting += 1;
        }
        assert!(
            per_shard.iter().all(|&n| n == 8),
            "24 uniform resumed requests over 3 survivors must land 8/8/8, got {per_shard:?}"
        );
        // an uneven start self-corrects: the lighter survivors absorb
        // the burst first
        let mut uneven = loads.clone();
        uneven[0].resident_blocks += 40;
        let s = p.pick(Class::Offline, need, 0, &uneven, 0);
        assert_ne!(s, 0, "the pre-loaded survivor must not take the first resumed request");
    }

    #[test]
    fn prefix_affinity_prefers_resident_prefixes() {
        use crate::kvcache::prefix::digest_insert;
        let p = Placement::prefix_affinity();
        let probes = [111u64, 222, 333];
        // shard 1 holds the first two prefix blocks, shard 0 none; shard
        // 1 is heavier but the resident prefix must win
        let mut loads = vec![snap(10, 2, 0), snap(40, 20, 0)];
        for h in [111u64, 222] {
            digest_insert(&mut loads[1].prefix_digest, h);
        }
        assert_eq!(p.pick_prefix(Class::Online, 1, 0, &loads, 0, &probes), 1);
        assert_eq!(p.pick_prefix(Class::Offline, 1, 0, &loads, 0, &probes), 1);
        // only the *leading* run counts: a shard holding probe 1 but not
        // probe 0 cannot serve any prefix blocks and scores zero
        let mut gap = vec![snap(10, 2, 0), snap(10, 2, 0)];
        digest_insert(&mut gap[1].prefix_digest, 222);
        assert_eq!(p.pick_prefix(Class::Online, 1, 0, &gap, 0, &probes), 0);
        // without probes the policy degenerates to affinity scoring
        assert_eq!(p.pick(Class::Online, 1, 0, &loads, 0), 0);
        // digest hits never beat a shard that cannot fit the request
        let mut full = vec![snap(5, 0, 0), snap(98, 0, 0)];
        for h in probes {
            digest_insert(&mut full[1].prefix_digest, h);
        }
        assert_eq!(p.pick_prefix(Class::Online, 8, 0, &full, 0, &probes), 0);
    }

    #[test]
    fn parse_and_display_round_trip() {
        for s in [
            "rr",
            "least-kv",
            "affinity",
            "affinity:0.25",
            "deadline",
            "deadline:0.2",
            "prefix",
            "prefix-affinity:0.25",
        ] {
            let p: Placement = s.parse().unwrap();
            let back: Placement = p.to_string().parse().unwrap();
            assert_eq!(p, back);
        }
        assert_eq!(
            "affinity:0.25".parse::<Placement>().unwrap(),
            Placement::Affinity { headroom: 0.25 }
        );
        assert_eq!(
            "deadline:0.2".parse::<Placement>().unwrap(),
            Placement::Deadline { headroom: 0.2 }
        );
        assert!("nope".parse::<Placement>().is_err());
        assert!("affinity:1.5".parse::<Placement>().is_err());
        assert!("affinity:x".parse::<Placement>().is_err());
        assert!("deadline:2".parse::<Placement>().is_err());
    }
}
