//! Front-door admission properties and loopback integration tests.
//!
//! * the deadline-feasibility estimator is **monotone**: adding load
//!   (KV occupancy, online queueing, offline backlog) never flips a
//!   job from infeasible to feasible (randomized property);
//! * hostile clients — torn requests, oversized headers/bodies, bad
//!   JSON, disconnects mid-stream — get structured errors and never
//!   strand engine-side work;
//! * a live serve loop under mixed traffic drains with **zero
//!   accepted-request loss**, checkpoints unfinished offline work, and
//!   resumes it after a restart.
//!
//! The HTTP tests run real sockets and real threads against the
//! simulated backend under a sped-up cost model (real-clock pacing in
//! the hundreds of microseconds per iteration).

use conserve::backend::CostModel;
use conserve::config::EngineConfig;
use conserve::server::admission::{
    deadline_feasible, estimate_finish_us, AdmissionConfig, FleetView,
};
use conserve::server::http::{HttpServer, ServeOptions, ServeSummary};
use conserve::util::json::Json;
use conserve::util::rng::Rng;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------------

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "conserve-admission-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Sped-up cost model: same structure as the A100 model, ~50x faster,
/// so real-clock loopback tests finish in milliseconds-to-seconds.
fn fast_cost() -> CostModel {
    CostModel {
        fixed_us: 50.0,
        us_per_token: 1.0,
        weights_load_us: 200.0,
        us_per_ctx_token: 0.01,
        us_per_seq: 1.0,
        ..CostModel::a100_llama2_7b()
    }
}

fn serve_opts(shards: usize) -> ServeOptions {
    ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        shards,
        cost: fast_cost(),
        request_timeout_ms: 60_000,
        ..ServeOptions::default()
    }
}

fn start(opts: ServeOptions) -> (SocketAddr, std::thread::JoinHandle<ServeSummary>) {
    let server = HttpServer::bind(EngineConfig::sim_a100_7b(), opts).expect("bind");
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().expect("serve run"));
    (addr, handle)
}

/// Minimal HTTP/1.1 client: one request, read to EOF (the server
/// closes every connection), return (status, full body text).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(90))).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    read_response(&mut s)
}

fn read_response(s: &mut TcpStream) -> (u16, String) {
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf);
    let text = String::from_utf8_lossy(&buf).to_string();
    let status: u16 = text
        .split(' ')
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("unparseable response: {text:?}"));
    let body = match text.find("\r\n\r\n") {
        Some(i) => text[i + 4..].to_string(),
        None => String::new(),
    };
    (status, body)
}

/// Parse a (non-chunked) JSON response body.
fn json_body(body: &str) -> Json {
    Json::parse(body.trim()).unwrap_or_else(|e| panic!("bad json {body:?}: {e:?}"))
}

fn drain_and_join(
    addr: SocketAddr,
    handle: std::thread::JoinHandle<ServeSummary>,
) -> ServeSummary {
    let (status, _) = http(addr, "POST", "/drain", "");
    assert_eq!(status, 202);
    handle.join().expect("serve thread")
}

fn assert_no_loss(summary: &ServeSummary) {
    assert_eq!(
        summary.lost_online, 0,
        "accepted-request loss: accepted {} completed {} cancelled {} failed {}",
        summary.accepted_online,
        summary.completed_online,
        summary.cancelled_online,
        summary.failed_online.len()
    );
}

// ---------------------------------------------------------------------------
// Feasibility-estimator monotonicity (satellite: property test)
// ---------------------------------------------------------------------------

#[test]
fn estimator_is_monotone_under_added_load() {
    let cfg = AdmissionConfig::default();
    let mut rng = Rng::new(0xFEA51B1E);
    for _ in 0..400 {
        let n_shards = rng.range(1, 9);
        let capacity_blocks = rng.range(64, 4096);
        let mut v = FleetView {
            n_shards,
            capacity_blocks,
            online_blocks: rng.range(0, n_shards * capacity_blocks + 1),
            waiting_online: rng.range(0, 64),
            offline_waiting: rng.range(0, 128),
            budget_permille: rng.range(0, 1001),
        };
        let job_tokens = rng.range(0, 1 << 20);
        let slack = rng.range(1, 1 << 22);
        let mut est = estimate_finish_us(&v, &cfg, job_tokens);
        for _ in 0..6 {
            let mut w = v;
            match rng.range(0, 3) {
                0 => w.online_blocks += rng.range(1, 512),
                1 => w.waiting_online += rng.range(1, 32),
                _ => w.offline_waiting += rng.range(1, 64),
            }
            let est2 = estimate_finish_us(&w, &cfg, job_tokens);
            assert!(
                est2 >= est,
                "estimate decreased when load grew: {est} -> {est2} ({v:?} -> {w:?})"
            );
            // the headline property: added load never flips a job from
            // infeasible to feasible
            if !deadline_feasible(&v, &cfg, job_tokens, slack) {
                assert!(
                    !deadline_feasible(&w, &cfg, job_tokens, slack),
                    "added load made an infeasible deadline feasible ({v:?} -> {w:?})"
                );
            }
            v = w;
            est = est2;
        }
    }
}

#[test]
fn estimator_also_monotone_in_job_size() {
    let cfg = AdmissionConfig::default();
    let v = FleetView {
        n_shards: 2,
        capacity_blocks: 1024,
        online_blocks: 700,
        waiting_online: 5,
        offline_waiting: 10,
        budget_permille: 1000,
    };
    let mut last = 0;
    for toks in [0u64, 10, 1_000, 100_000, 10_000_000] {
        let est = estimate_finish_us(&v, &cfg, toks);
        assert!(est >= last, "estimate not monotone in job tokens");
        last = est;
    }
}

/// Regression (harvest satellite): the estimator reads the *live*
/// published offline budget. Tightening the budget (lower permille)
/// never shortens the estimate, never flips an infeasible deadline
/// feasible, and the no-controller default of 1000 permille reproduces
/// the pre-harvest estimate exactly.
#[test]
fn estimator_tracks_published_budget_tightening() {
    let cfg = AdmissionConfig::default();
    let mut rng = Rng::new(0xB0D6E7);
    for _ in 0..200 {
        let n_shards = rng.range(1, 9);
        let capacity_blocks = rng.range(64, 4096);
        let base = FleetView {
            n_shards,
            capacity_blocks,
            online_blocks: rng.range(0, n_shards * capacity_blocks + 1),
            waiting_online: rng.range(0, 64),
            offline_waiting: rng.range(0, 128),
            budget_permille: 1000,
        };
        let job_tokens = rng.range(1, 1 << 20);
        let slack = rng.range(1, 1 << 22);
        let mut prev = estimate_finish_us(&base, &cfg, job_tokens);
        let mut prev_view = base;
        // walk the budget down from wide open to fully tightened
        for permille in [800u64, 500, 250, 100, 50, 0] {
            let v = FleetView { budget_permille: permille, ..base };
            let est = estimate_finish_us(&v, &cfg, job_tokens);
            assert!(
                est >= prev,
                "tightening the budget shortened the estimate: \
                 {prev} -> {est} ({prev_view:?} -> {v:?})"
            );
            if !deadline_feasible(&prev_view, &cfg, job_tokens, slack) {
                assert!(
                    !deadline_feasible(&v, &cfg, job_tokens, slack),
                    "budget tightening flipped infeasible -> feasible"
                );
            }
            prev = est;
            prev_view = v;
        }
        // the 5 % floor keeps the estimate finite: a fully-tightened
        // budget (0) estimates the same as the floor (50 permille)
        let floored = FleetView { budget_permille: 50, ..base };
        let zeroed = FleetView { budget_permille: 0, ..base };
        assert_eq!(
            estimate_finish_us(&floored, &cfg, job_tokens),
            estimate_finish_us(&zeroed, &cfg, job_tokens),
            "budget floor not applied"
        );
    }
}

// ---------------------------------------------------------------------------
// Hostile clients (satellite: torn/partial HTTP, oversized bodies)
// ---------------------------------------------------------------------------

#[test]
fn hostile_clients_get_structured_errors() {
    let (addr, handle) = start(serve_opts(1));

    // torn request: half a request line, then half-close
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(b"POST /v1/comp").unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let (status, body) = read_response(&mut s);
        assert_eq!(status, 400, "torn request: {body}");
    }
    // not HTTP at all
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(b"garbage\r\n\r\n").unwrap();
        let (status, _) = read_response(&mut s);
        assert_eq!(status, 400);
    }
    // oversized declared body
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(b"POST /v1/completions HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n")
            .unwrap();
        let (status, body) = read_response(&mut s);
        assert_eq!(status, 413, "{body}");
        assert!(body.contains("body_too_large"), "{body}");
    }
    // oversized header block
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let huge = format!("GET /healthz HTTP/1.1\r\nPad: {}\r\n\r\n", "x".repeat(16384));
        s.write_all(huge.as_bytes()).unwrap();
        let (status, _) = read_response(&mut s);
        assert_eq!(status, 431);
    }
    // bad JSON, unknown route, wrong method
    let (status, body) = http(addr, "POST", "/v1/completions", "{not json");
    assert_eq!(status, 400, "{body}");
    let (status, _) = http(addr, "GET", "/nope", "");
    assert_eq!(status, 404);
    let (status, _) = http(addr, "GET", "/v1/completions", "");
    assert_eq!(status, 405);
    // valid JSON, invalid shape
    let (status, body) = http(addr, "POST", "/v1/completions", r#"{"prompt": []}"#);
    assert_eq!(status, 400, "{body}");

    let summary = drain_and_join(addr, handle);
    assert_no_loss(&summary);
    assert_eq!(summary.accepted_online, 0);
    assert!(summary.requests_served >= 8);
}

// ---------------------------------------------------------------------------
// Live traffic, streaming, disconnect, drain (the tentpole invariants)
// ---------------------------------------------------------------------------

#[test]
fn completions_round_trip_and_drain_cleanly() {
    let (addr, handle) = start(serve_opts(2));

    let (status, body) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert!(body.contains("\"ok\""), "{body}");

    for _ in 0..3 {
        let (status, body) = http(
            addr,
            "POST",
            "/v1/completions",
            r#"{"prompt_len": 8, "max_tokens": 4}"#,
        );
        assert_eq!(status, 200, "{body}");
        let j = json_body(&body);
        assert_eq!(j.req("generated").as_usize(), Some(4), "{body}");
        assert_eq!(j.req("tokens").as_arr().map(<[Json]>::len), Some(4));
    }

    // streaming: chunked NDJSON with per-token lines and a final done
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let body = r#"{"prompt_len": 8, "max_tokens": 6, "stream": true}"#;
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        s.write_all(req.as_bytes()).unwrap();
        let (status, raw) = read_response(&mut s);
        assert_eq!(status, 200);
        assert_eq!(raw.matches("\"token\"").count(), 6, "{raw}");
        assert!(raw.contains("\"done\""), "{raw}");
    }

    let summary = drain_and_join(addr, handle);
    assert_no_loss(&summary);
    assert_eq!(summary.accepted_online, 4);
    assert_eq!(summary.completed_online, 4);
    assert_eq!(summary.admission.admitted_online, 4);
}

#[test]
fn disconnect_mid_stream_cancels_and_loses_nothing() {
    let (addr, handle) = start(serve_opts(1));

    // a long streaming request we will abandon mid-flight
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let body = r#"{"prompt_len": 8, "max_tokens": 8000, "stream": true}"#;
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        s.write_all(req.as_bytes()).unwrap();
        // read a little to prove the stream is live, then vanish
        let mut first = [0u8; 64];
        let _ = s.read(&mut first).unwrap();
        drop(s);
    }
    // give the handler time to hit the broken pipe and the engine a
    // cancel tick to clamp the request
    std::thread::sleep(Duration::from_millis(500));

    let t0 = Instant::now();
    let summary = drain_and_join(addr, handle);
    assert_no_loss(&summary);
    assert_eq!(summary.accepted_online, 1);
    // a cancel caught while queued settles as cancelled; one caught
    // while running clamps max_new_tokens and settles as completed —
    // both are accounted, neither is lost
    assert_eq!(
        summary.completed_online + summary.cancelled_online,
        1,
        "abandoned request must settle as completed (clamped) or cancelled"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "drain stalled behind an abandoned request"
    );
}

#[test]
fn overload_sheds_with_retry_hints_and_drain_sheds_everything() {
    // tiny token bucket: 2 requests burst, 1/s sustained; a slightly
    // slower cost model keeps the held stream (below) alive across the
    // drain handshake
    let mut opts = serve_opts(1);
    opts.cost = CostModel {
        fixed_us: 150.0,
        ..fast_cost()
    };
    opts.admission = AdmissionConfig {
        online_rate: 1.0,
        online_burst: 2.0,
        ..AdmissionConfig::default()
    };
    let (addr, handle) = start(opts);

    let mut ok = 0u32;
    let mut shed = 0u32;
    for _ in 0..8 {
        let (status, body) = http(
            addr,
            "POST",
            "/v1/completions",
            r#"{"prompt_len": 4, "max_tokens": 2}"#,
        );
        match status {
            200 => ok += 1,
            429 => {
                shed += 1;
                let j = json_body(&body);
                let hint = j.req("error").req("retry_after_ms").as_f64().unwrap();
                assert!(hint >= 1.0, "shed without a positive retry hint: {body}");
            }
            other => panic!("unexpected status {other}: {body}"),
        }
    }
    assert!(ok >= 2, "burst capacity should admit at least 2");
    assert!(shed >= 1, "sustained overload should shed");

    // draining: hold a connection open so the accept loop stays alive
    // long enough to observe the draining shed
    let mut held = TcpStream::connect(addr).unwrap();
    held.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let body = r#"{"prompt_len": 4, "max_tokens": 8000, "stream": true}"#;
    let req = format!(
        "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    held.write_all(req.as_bytes()).unwrap();
    let mut first = [0u8; 32];
    let _ = held.read(&mut first).unwrap();

    let (status, _) = http(addr, "POST", "/drain", "");
    assert_eq!(status, 202);
    let (status, body) = http(
        addr,
        "POST",
        "/v1/completions",
        r#"{"prompt_len": 4, "max_tokens": 2}"#,
    );
    assert_eq!(status, 503, "draining server must shed: {body}");
    assert!(body.contains("draining"), "{body}");

    // the held stream still finishes: accepted work flushes on drain
    let (_, raw) = read_response(&mut held);
    assert!(raw.contains("\"done\""), "accepted stream cut off by drain: {raw}");

    let summary = handle.join().expect("serve thread");
    assert_no_loss(&summary);
    assert!(summary.admission.shed_online >= u64::from(shed + 1));
}

// ---------------------------------------------------------------------------
// Observability: /healthz gauges and the Prometheus /metrics endpoint
// ---------------------------------------------------------------------------

#[test]
fn healthz_gauges_and_metrics_endpoint_scrape() {
    let (addr, handle) = start(serve_opts(2));

    // move the counters before scraping
    for _ in 0..2 {
        let (status, body) = http(
            addr,
            "POST",
            "/v1/completions",
            r#"{"prompt_len": 8, "max_tokens": 4}"#,
        );
        assert_eq!(status, 200, "{body}");
    }

    // regression: /healthz carries the live harvest budget and
    // deadline-attainment gauges (overall + per tenant)
    let (status, body) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    let j = json_body(&body);
    let permille = j
        .req("harvest_budget_permille")
        .as_f64()
        .unwrap_or_else(|| panic!("healthz missing harvest_budget_permille: {body}"));
    assert!((0.0..=1000.0).contains(&permille), "{body}");
    let att = j
        .req("deadline_attainment")
        .as_f64()
        .unwrap_or_else(|| panic!("healthz missing deadline_attainment: {body}"));
    assert!((0.0..=1.0).contains(&att), "{body}");
    assert!(
        j.get("tenant_deadline_attainment").is_some(),
        "healthz missing tenant_deadline_attainment: {body}"
    );

    // Prometheus text exposition: the families the scrape config relies on
    let (status, metrics) = http(addr, "GET", "/metrics", "");
    assert_eq!(status, 200, "{metrics}");
    for family in [
        "conserve_engine_iterations_total",
        "conserve_finished_online_total",
        "conserve_harvest_budget_permille",
        "conserve_prefix_hit_rate",
        "conserve_deadline_attainment",
        "conserve_http_requests_total",
        "conserve_accepted_online_total",
        "conserve_trace_events_total",
    ] {
        assert!(
            metrics.contains(family),
            "missing metric family {family}:\n{metrics}"
        );
    }
    assert!(metrics.contains("# TYPE"), "{metrics}");
    assert!(
        metrics.contains("shard=\"0\"") && metrics.contains("shard=\"1\""),
        "per-shard samples must be labelled:\n{metrics}"
    );

    let summary = drain_and_join(addr, handle);
    assert_no_loss(&summary);
}

// ---------------------------------------------------------------------------
// Batches: verdicts over HTTP, drain checkpointing, restart resume
// ---------------------------------------------------------------------------

#[test]
fn batch_jobs_complete_rejects_are_retired_and_drain_resumes() {
    let dir = tmp_dir("resume");
    let mut opts = serve_opts(2);
    opts.state_dir = Some(dir.clone());
    opts.ckpt_every = 20;
    let (addr, handle) = start(opts);

    // a small feasible job: completes while we watch
    let (status, body) = http(
        addr,
        "POST",
        "/v1/batches",
        r#"{"n_requests": 2, "prompt_len": 8, "max_tokens": 4, "tenant": 7, "deadline_ms": 600000}"#,
    );
    assert_eq!(status, 202, "{body}");
    let j = json_body(&body);
    assert_eq!(j.req("status").as_str(), Some("accepted"), "{body}");
    let quick_id = j.req("id").as_usize().unwrap();

    let t0 = Instant::now();
    loop {
        let (status, body) = http(addr, "GET", &format!("/v1/batches/{quick_id}"), "");
        // completed jobs are garbage-collected from the board: both
        // "done": true and a 404-after-done are success
        if status == 404 || (status == 200 && json_body(&body).req("done").as_bool() == Some(true))
        {
            break;
        }
        assert_eq!(status, 200, "{body}");
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "job never completed: {body}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // an impossible deadline on a big job: rejected with a retry hint,
    // board entry retired
    let (status, body) = http(
        addr,
        "POST",
        "/v1/batches",
        r#"{"n_requests": 64, "prompt_len": 512, "max_tokens": 4096, "deadline_ms": 1}"#,
    );
    assert!(status == 429 || status == 202, "{status}: {body}");
    if status == 429 {
        let j = json_body(&body);
        let rejected_id = j.req("id").as_usize().unwrap();
        let (status, _) = http(addr, "GET", &format!("/v1/batches/{rejected_id}"), "");
        assert_eq!(status, 404, "rejected job's board entry must be retired");
    }

    // a big best-effort job that cannot finish before we drain: 8000
    // tokens/request needs ~450ms of paced decode under fast_cost, so
    // a 300ms head start leaves it mid-flight with progress to persist
    let (status, body) = http(
        addr,
        "POST",
        "/v1/batches",
        r#"{"n_requests": 4, "prompt_len": 64, "max_tokens": 8000}"#,
    );
    assert_eq!(status, 202, "{body}");
    let slow_id = json_body(&body).req("id").as_usize().unwrap();
    std::thread::sleep(Duration::from_millis(300));

    let summary = drain_and_join(addr, handle);
    assert_no_loss(&summary);
    assert!(summary.admission.jobs_accepted >= 2);
    assert!(
        summary.drain_checkpoints > 0,
        "drain should checkpoint the unfinished job: {summary:?}"
    );

    // restart on the same state dir: the unfinished job is resumed
    let mut opts = serve_opts(2);
    opts.state_dir = Some(dir.clone());
    let (addr, handle) = start(opts);
    let (status, body) = http(addr, "GET", &format!("/v1/batches/{slow_id}"), "");
    assert_eq!(status, 200, "resumed job missing from the board: {body}");
    let summary = drain_and_join(addr, handle);
    assert!(
        summary.resumed_requests > 0,
        "restart should re-dispatch unfinished work: {summary:?}"
    );
    assert_no_loss(&summary);
    std::fs::remove_dir_all(&dir).ok();
}
