//! Batch-job trace synthesis: mixed tenants, priority tiers, deadlines
//! and job sizes for the deadline-aware job manager ([`crate::batch`]).
//!
//! Two generators:
//!
//! * [`job_trace`] — a randomized multi-tenant mix (mega-jobs among
//!   small ones, tight and lax deadlines, deadline-free stragglers):
//!   the general-purpose workload behind `conserve jobs`.
//! * [`mega_plus_tight`] — the adversarial shape the acceptance bench
//!   keys on: one tenant's mega-job submitted first, then a stream of
//!   small tight-deadline jobs from other tenants. FIFO admission
//!   serves the mega-job's queue first and misses the tight deadlines;
//!   EDF urgency + fair share meets them while the lax mega-job still
//!   makes its generous deadline.

use crate::batch::{JobInput, JobRequest};
use crate::util::rng::Rng;
use crate::TimeUs;
use crate::US_PER_SEC;

/// Knobs for [`job_trace`].
#[derive(Debug, Clone)]
pub struct JobTraceConfig {
    pub seed: u64,
    pub n_jobs: usize,
    pub n_tenants: u32,
    /// Submission window (s): `submitted_at` is uniform over it.
    pub span_s: f64,
    /// Nominal fleet service rate (tokens/s) used to size deadlines
    /// relative to each job's work estimate.
    pub svc_tok_per_s: f64,
}

impl Default for JobTraceConfig {
    fn default() -> Self {
        Self {
            seed: 0xBA7C_4,
            n_jobs: 24,
            n_tenants: 4,
            span_s: 60.0,
            svc_tok_per_s: crate::batch::NOMINAL_TOK_PER_S,
        }
    }
}

fn requests(
    rng: &mut Rng,
    n: usize,
    in_lo: usize,
    in_hi: usize,
    out_lo: usize,
    out_hi: usize,
) -> Vec<JobRequest> {
    (0..n)
        .map(|_| JobRequest {
            prompt: Vec::new(),
            prompt_len: rng.range_usize(in_lo, in_hi),
            max_new_tokens: rng.range_usize(out_lo, out_hi),
        })
        .collect()
}

fn total_tokens(reqs: &[JobRequest]) -> u64 {
    reqs.iter()
        .map(|r| (r.prompt_len + r.max_new_tokens) as u64)
        .sum()
}

/// Randomized multi-tenant job mix (see module docs). Sorted by
/// submission time.
pub fn job_trace(cfg: &JobTraceConfig) -> Vec<JobInput> {
    let mut rng = Rng::new(cfg.seed);
    let span_us = (cfg.span_s * US_PER_SEC as f64) as TimeUs;
    let mut jobs = Vec::with_capacity(cfg.n_jobs);
    for _ in 0..cfg.n_jobs {
        let tenant = 1 + rng.range_usize(0, cfg.n_tenants.max(1) as usize) as u32;
        let tier = rng.range_usize(0, 3) as u8;
        let submitted_at = rng.range_usize(0, span_us.max(1) as usize) as TimeUs;
        let mega = rng.range_usize(0, 8) == 0;
        let reqs = if mega {
            let n = rng.range_usize(24, 48);
            requests(&mut rng, n, 1024, 4096, 64, 256)
        } else {
            let n = rng.range_usize(3, 8);
            requests(&mut rng, n, 256, 1024, 16, 64)
        };
        // deadline: 15% none; 40% tight (1.5-2.5x the work estimate);
        // the rest lax (4-10x)
        let est_us = (total_tokens(&reqs) as f64 / cfg.svc_tok_per_s * 1e6) as TimeUs;
        let roll = rng.range_usize(0, 100);
        let deadline = if roll < 15 {
            0
        } else if roll < 55 {
            submitted_at + est_us * rng.range_usize(15, 25) as TimeUs / 10
        } else {
            submitted_at + est_us * rng.range_usize(40, 100) as TimeUs / 10
        };
        jobs.push(JobInput {
            tenant,
            tier,
            submitted_at,
            deadline,
            requests: reqs,
        });
    }
    jobs.sort_by_key(|j| j.submitted_at);
    jobs
}

/// Knobs for [`mega_plus_tight`].
#[derive(Debug, Clone)]
pub struct MegaTightConfig {
    pub seed: u64,
    /// Requests in the mega-job (tenant 1, tier 2, submitted at t=0).
    /// Keep `mega_requests / n_shards` above the per-shard KV capacity
    /// (~21 concurrent mega-sized requests on the A100 preset) or FIFO
    /// admits everything immediately and nothing separates the modes.
    pub mega_requests: usize,
    /// Number of small tight-deadline jobs (tenants 2.., tier 0).
    pub tight_jobs: usize,
    /// Requests per tight job.
    pub tight_requests: usize,
    /// Nominal fleet service rate (tokens/s) for deadline sizing.
    pub svc_tok_per_s: f64,
    /// Tight-job deadline as a fraction of the mega-job's drain
    /// estimate: far below 1.0 (hopeless behind the mega backlog under
    /// FIFO) yet several times a tight job's own service time (easy
    /// when served promptly).
    pub tight_deadline_frac: f64,
    /// Mega-job deadline as a multiple of its own drain estimate
    /// (generous — it meets it under either discipline).
    pub mega_deadline_mult: f64,
}

impl Default for MegaTightConfig {
    fn default() -> Self {
        Self {
            seed: 0x71_647,
            mega_requests: 160,
            tight_jobs: 8,
            tight_requests: 4,
            svc_tok_per_s: crate::batch::NOMINAL_TOK_PER_S,
            tight_deadline_frac: 0.5,
            mega_deadline_mult: 3.0,
        }
    }
}

/// The FIFO-buster (see module docs): a mega-job at t=0 whose deadline
/// is generous even behind everything else, then tight jobs whose
/// deadlines sit at `tight_deadline_frac` of the mega-job's drain time.
/// Tight outputs are small (≤ 16 tokens) so completion is dominated by
/// *when the scheduler starts them* — the quantity FIFO vs EDF actually
/// disagree about — not by decode cadence. Deterministic per seed.
pub fn mega_plus_tight(cfg: &MegaTightConfig) -> Vec<JobInput> {
    let mut rng = Rng::new(cfg.seed);
    let mut jobs = Vec::with_capacity(1 + cfg.tight_jobs);
    let mega_reqs = requests(&mut rng, cfg.mega_requests, 1024, 3072, 32, 128);
    let mega_est_us = (total_tokens(&mega_reqs) as f64 / cfg.svc_tok_per_s * 1e6) as TimeUs;
    jobs.push(JobInput {
        tenant: 1,
        tier: 2,
        submitted_at: 0,
        deadline: (mega_est_us as f64 * cfg.mega_deadline_mult) as TimeUs,
        requests: mega_reqs,
    });
    for t in 0..cfg.tight_jobs {
        // small outputs: completion is admission-bound (what FIFO vs
        // EDF disagree about), not decode-cadence-bound
        let reqs = requests(&mut rng, cfg.tight_requests, 256, 768, 4, 8);
        // staggered shortly after the mega-job is already queued
        let submitted_at = 200_000 * (t as TimeUs + 1);
        jobs.push(JobInput {
            tenant: 2 + (t as u32 % 3),
            tier: 0,
            submitted_at,
            deadline: submitted_at
                + (mega_est_us as f64 * cfg.tight_deadline_frac) as TimeUs,
            requests: reqs,
        });
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_trace_is_mixed_and_ordered() {
        let cfg = JobTraceConfig {
            n_jobs: 64,
            ..JobTraceConfig::default()
        };
        let jobs = job_trace(&cfg);
        assert_eq!(jobs.len(), 64);
        assert!(jobs.windows(2).all(|w| w[0].submitted_at <= w[1].submitted_at));
        let tenants: std::collections::BTreeSet<u32> =
            jobs.iter().map(|j| j.tenant).collect();
        assert!(tenants.len() >= 3, "mixed tenants: {tenants:?}");
        assert!(jobs.iter().any(|j| j.deadline == 0), "some deadline-free");
        assert!(jobs.iter().any(|j| j.deadline > 0), "some with deadlines");
        assert!(jobs.iter().any(|j| j.requests.len() >= 24), "some mega");
        assert!(jobs.iter().any(|j| j.requests.len() <= 8), "some small");
        for j in &jobs {
            assert!(j.deadline == 0 || j.deadline > j.submitted_at);
            assert!(!j.requests.is_empty());
        }
        // deterministic under the seed
        let again = job_trace(&cfg);
        assert_eq!(jobs.len(), again.len());
        assert!(jobs
            .iter()
            .zip(&again)
            .all(|(a, b)| a.submitted_at == b.submitted_at && a.tenant == b.tenant));
    }

    #[test]
    fn mega_plus_tight_shapes_the_race() {
        let cfg = MegaTightConfig::default();
        let jobs = mega_plus_tight(&cfg);
        assert_eq!(jobs.len(), 1 + cfg.tight_jobs);
        let mega = &jobs[0];
        assert_eq!(mega.requests.len(), cfg.mega_requests);
        for tight in &jobs[1..] {
            assert_eq!(tight.requests.len(), cfg.tight_requests);
            assert!(tight.deadline > tight.submitted_at);
            // the race: tight deadlines expire long before the mega-job
            // could drain ahead of them under FIFO
            assert!(tight.deadline < mega.deadline / 4);
            assert_ne!(tight.tenant, mega.tenant);
            // ...but comfortably cover the tight job's own work
            let own: u64 = tight
                .requests
                .iter()
                .map(|r| (r.prompt_len + r.max_new_tokens) as u64)
                .sum();
            let own_est = (own as f64 / cfg.svc_tok_per_s * 1e6) as u64;
            assert!(tight.deadline - tight.submitted_at > own_est * 10);
        }
    }
}
