//! Cross-shard property tests for the (generation, shard, slot) id
//! layout: ids issued by one shard's arena must never resolve — let
//! alone alias — in any other shard, stale ids from recycled slots must
//! miss in *every* shard, and per-shard KV block conservation must
//! survive random admit / checkpoint / preempt / prefetch / discard /
//! finish churn with hostile cross-shard pokes mixed in.

use conserve::kvcache::manager::{KvError, KvManager};
use conserve::request::{rid_shard, Class, Request, RequestArena, RequestId};
use conserve::util::rng::Rng;
use std::collections::HashSet;

const BLOCK_TOKENS: usize = 16;
const N_SHARDS: usize = 4;

fn new_req(rng: &mut Rng) -> Request {
    let class = if rng.range(0, 4) == 0 {
        Class::Online
    } else {
        Class::Offline
    };
    let prompt = rng.range_usize(16, 200);
    let out = rng.range_usize(4, 40);
    Request::new(0, class, vec![], prompt, out, 0)
}

#[test]
fn ids_never_alias_across_shards() {
    let mut rng = Rng::new(99);
    let mut arenas: Vec<RequestArena> = (0..N_SHARDS).map(RequestArena::for_shard).collect();
    let mut live: Vec<Vec<RequestId>> = vec![Vec::new(); N_SHARDS];
    let mut ever: HashSet<RequestId> = HashSet::new();
    for step in 0..20_000 {
        let s = rng.range_usize(0, N_SHARDS);
        if rng.range(0, 3) == 0 && !live[s].is_empty() {
            let k = rng.range_usize(0, live[s].len());
            let id = live[s].swap_remove(k);
            assert!(arenas[s].remove(id).is_some());
        } else {
            let id = arenas[s].insert(new_req(&mut rng));
            assert_eq!(rid_shard(id), s, "step {step}: id carries wrong shard");
            assert!(
                ever.insert(id),
                "step {step}: id {id} issued twice across the fleet"
            );
            live[s].push(id);
        }
    }
    // every live id resolves in its own shard and misses all others
    for s in 0..N_SHARDS {
        for &id in &live[s] {
            assert!(arenas[s].get(id).is_some());
            for (o, arena) in arenas.iter().enumerate() {
                if o != s {
                    assert!(
                        arena.get(id).is_none(),
                        "id {id} of shard {s} resolved in shard {o}"
                    );
                }
            }
        }
    }
}

#[test]
fn stale_ids_from_recycled_slots_cannot_resolve_in_any_shard() {
    let mut rng = Rng::new(7);
    let mut arenas: Vec<RequestArena> = (0..N_SHARDS).map(RequestArena::for_shard).collect();
    let mut kvs: Vec<KvManager> = (0..N_SHARDS)
        .map(|s| KvManager::for_shard(s, 64, 128, BLOCK_TOKENS))
        .collect();
    let mut live: Vec<Vec<RequestId>> = vec![Vec::new(); N_SHARDS];
    let mut dead: Vec<RequestId> = Vec::new();
    for _ in 0..5_000 {
        let s = rng.range_usize(0, N_SHARDS);
        if rng.range(0, 4) == 0 && !live[s].is_empty() {
            let k = rng.range_usize(0, live[s].len());
            let id = live[s].swap_remove(k);
            kvs[s].release(id, false);
            assert!(arenas[s].remove(id).is_some());
            dead.push(id);
        } else if live[s].len() < 8 {
            let id = arenas[s].insert(new_req(&mut rng));
            kvs[s].register(id);
            let want = rng.range_usize(1, 64);
            if kvs[s].grow(id, want).is_ok() {
                kvs[s].commit(id, want).unwrap();
            }
            live[s].push(id);
        }
    }
    // a dead id (its slot possibly recycled under a newer generation in
    // its home shard) must miss everywhere: generation guard at home,
    // shard guard abroad
    for &id in &dead {
        for s in 0..N_SHARDS {
            assert!(arenas[s].get(id).is_none(), "stale id {id} resolved in shard {s}");
            assert!(kvs[s].seq(id).is_none());
            assert_eq!(kvs[s].grow(id, 16), Err(KvError::UnknownSeq(id)));
            assert_eq!(kvs[s].commit(id, 1), Err(KvError::UnknownSeq(id)));
        }
    }
    for kv in &kvs {
        assert!(kv.check_conservation());
    }
}

#[test]
fn kv_conservation_holds_per_shard_under_random_preempt_resume() {
    let mut rng = Rng::new(0xC0_5E_7E);
    let mut arenas: Vec<RequestArena> = (0..N_SHARDS).map(RequestArena::for_shard).collect();
    let mut kvs: Vec<KvManager> = (0..N_SHARDS)
        .map(|s| KvManager::for_shard(s, 96, 256, BLOCK_TOKENS))
        .collect();
    let mut live: Vec<Vec<RequestId>> = vec![Vec::new(); N_SHARDS];

    for step in 0..12_000 {
        let s = rng.range_usize(0, N_SHARDS);
        let pick = |rng: &mut Rng, ids: &[RequestId]| -> Option<RequestId> {
            if ids.is_empty() {
                None
            } else {
                Some(ids[rng.range_usize(0, ids.len())])
            }
        };
        match rng.range(0, 8) {
            // admit + grow/commit a first chunk
            0 | 1 => {
                if live[s].len() < 10 {
                    let id = arenas[s].insert(new_req(&mut rng));
                    kvs[s].register(id);
                    let want = rng.range_usize(1, 80);
                    if kvs[s].grow(id, want).is_ok() {
                        kvs[s].commit(id, want).unwrap();
                    }
                    live[s].push(id);
                }
            }
            // progress: grow + commit more tokens
            2 => {
                if let Some(id) = pick(&mut rng, &live[s]) {
                    let t = kvs[s].seq(id).map(|q| q.tokens).unwrap_or(0);
                    let add = rng.range_usize(1, 32);
                    if kvs[s].grow(id, t + add).is_ok() {
                        kvs[s].commit(id, add).unwrap();
                    }
                }
            }
            // incremental checkpoint
            3 => {
                if let Some(id) = pick(&mut rng, &live[s]) {
                    for idx in kvs[s].checkpoint_candidates(id) {
                        if kvs[s].begin_ckpt(id, idx).is_err() {
                            break; // host pool exhausted
                        }
                        kvs[s].finish_ckpt(id, idx);
                    }
                }
            }
            // preempt-evict (host checkpoints, if any, survive)
            4 => {
                if let Some(id) = pick(&mut rng, &live[s]) {
                    kvs[s].evict_gpu(id);
                }
            }
            // resume via prefetch of whatever host copies exist
            5 => {
                if let Some(id) = pick(&mut rng, &live[s]) {
                    for (idx, _hb) in kvs[s].prefetch_candidates(id) {
                        if kvs[s].begin_prefetch(id, idx).is_err() {
                            break; // GPU pool exhausted
                        }
                    }
                }
            }
            // discard-preempt (recompute path) or finish
            6 => {
                if let Some(id) = pick(&mut rng, &live[s]) {
                    if rng.range(0, 2) == 0 {
                        kvs[s].discard(id);
                    } else {
                        kvs[s].release(id, false);
                        live[s].retain(|&x| x != id);
                        assert!(arenas[s].remove(id).is_some());
                    }
                }
            }
            // hostile cross-shard poke: a live id from another shard
            // must bounce off this shard's manager without any effect
            _ => {
                let o = (s + 1 + rng.range_usize(0, N_SHARDS - 1)) % N_SHARDS;
                if let Some(foreign) = pick(&mut rng, &live[o]) {
                    assert!(kvs[s].seq(foreign).is_none());
                    assert_eq!(kvs[s].grow(foreign, 16), Err(KvError::UnknownSeq(foreign)));
                    assert_eq!(kvs[s].evict_gpu(foreign), 0);
                    kvs[s].release(foreign, false); // must be a no-op
                    kvs[s].discard(foreign); // must be a no-op, not a panic
                    assert!(
                        kvs[o].seq(foreign).is_some(),
                        "foreign poke damaged the owning shard"
                    );
                }
            }
        }
        if step % 500 == 0 {
            for (i, kv) in kvs.iter().enumerate() {
                assert!(
                    kv.check_conservation(),
                    "step {step}: conservation violated on shard {i}"
                );
            }
        }
    }
    for (i, kv) in kvs.iter().enumerate() {
        assert!(kv.check_conservation(), "final conservation on shard {i}");
    }
}
