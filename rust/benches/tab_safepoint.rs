//! §6.4.2 — "Efficiency of Preemptible Worker."
//!
//! Paper measurements on A100/Llama-2-7B: 988 µs per safepoint barrier;
//! instrumenting every 8 layers adds 3.99 ms (4%) to a 98.5 ms step;
//! preemption detected within 5.41 ms.
//!
//! Two reproductions:
//!  1. **Simulated testbed** — the cost model's numbers at safepoint
//!     granularities 1..32 (overhead % and worst-case detection time).
//!  2. **Real PJRT backend** — measured wall-clock overhead of the
//!     layered (safepointed) execution vs the monolithic `full` artifact,
//!     plus measured preemption-detection latency, on the tiny model.

use conserve::backend::{CostModel, ExecBackend, IterationPlan, SafepointAction, SimBackend};
use conserve::clock::Clock;
use conserve::request::{Class, Phase};

fn offline_plan(n_tokens: usize) -> IterationPlan {
    let toks: Vec<u16> = (0..n_tokens).map(|i| (i % 250) as u16).collect();
    let mut plan = IterationPlan {
        preemptible: true,
        ..Default::default()
    };
    plan.push_item(900_001, Class::Offline, Phase::Prefill, 0, n_tokens, &toks);
    plan
}

fn main() {
    println!("=== simulated A100/Llama-2-7B (32 layers, 988 µs barrier) ===");
    println!(
        "{:>16} {:>12} {:>12} {:>12} {:>14}",
        "safepoint_every", "step_ms", "overhead_ms", "overhead_%", "detect_ms(max)"
    );
    let cost = CostModel::a100_llama2_7b();
    let base = cost.iter_us(1024, 0, 0, 1); // ~the paper's 98.5 ms step
    for sp in [1usize, 2, 4, 8, 16, 32] {
        let mut b = SimBackend::new(cost, Clock::virtual_at(0), sp);
        let out = b
            .execute(&offline_plan(1024), &mut |_| SafepointAction::Continue)
            .unwrap();
        let overhead = out.elapsed_us - base;
        let groups = b.n_layer_groups();
        // worst-case detection: one full group + one barrier
        let detect = base / groups as u64 + cost.safepoint_us;
        println!(
            "{:>16} {:>12.1} {:>12.2} {:>12.2} {:>14.2}",
            sp,
            out.elapsed_us as f64 / 1000.0,
            overhead as f64 / 1000.0,
            100.0 * overhead as f64 / base as f64,
            detect as f64 / 1000.0
        );
        if sp == 8 {
            let pct = 100.0 * overhead as f64 / base as f64;
            assert!(
                (1.0..8.0).contains(&pct),
                "8-layer safepoints should cost a few percent (paper 4%), got {pct:.2}%"
            );
            assert!(
                detect < 35_000,
                "detection within one layer group (paper 5.41 ms at their step time)"
            );
        }
    }

    real_backend_section();
    println!("\ntab_safepoint OK");
}

/// Measured overhead on the real layered runtime — needs the `pjrt`
/// cargo feature (xla crate) and built artifacts.
#[cfg(not(feature = "pjrt"))]
fn real_backend_section() {
    println!("\n(real PJRT section skipped: built without the `pjrt` feature)");
}

#[cfg(feature = "pjrt")]
fn real_backend_section() {
    use conserve::backend::PjrtBackend;

    println!("\n=== real PJRT backend (tiny Llama, 4 layers) ===");
    match PjrtBackend::load("artifacts", 7, 1) {
        Err(e) => {
            println!("artifacts not available ({e}); run `make artifacts` first");
        }
        Ok(mut b) => {
            // warm up / compile the exact bucket the timed plans use
            for _ in 0..2 {
                let _ = b.execute(&offline_plan(64), &mut |_| SafepointAction::Continue);
                b.drop_request(900_001);
            }
            let reps = 5;

            let timed = |b: &mut PjrtBackend, preemptible: bool| -> f64 {
                let mut plan = offline_plan(64);
                plan.preemptible = preemptible;
                let t0 = std::time::Instant::now();
                for _ in 0..reps {
                    let out = b.execute(&plan, &mut |_| SafepointAction::Continue).unwrap();
                    assert!(out.completed);
                    b.drop_request(900_001);
                }
                t0.elapsed().as_secs_f64() * 1000.0 / reps as f64
            };

            let plain = timed(&mut b, false);
            let safep = timed(&mut b, true);
            println!("layered step (no safepoint checks): {plain:>8.2} ms");
            println!("layered step (safepoints active):   {safep:>8.2} ms");
            println!(
                "in-process safepoint overhead:      {:>8.3} ms ({:.2}%)",
                safep - plain,
                100.0 * (safep - plain) / plain
            );

            // preemption detection latency: abort at the first safepoint
            let mut plan = offline_plan(64);
            plan.preemptible = true;
            let t0 = std::time::Instant::now();
            let out = b
                .execute(&plan, &mut |_| SafepointAction::Abort)
                .unwrap();
            let detect_ms = t0.elapsed().as_secs_f64() * 1000.0;
            assert!(!out.completed);
            println!(
                "preemption detected + aborted in:   {detect_ms:>8.2} ms (vs {plain:.2} ms full step; paper 5.41 ms vs 98.5 ms)"
            );
            assert!(
                detect_ms < plain,
                "abort must be faster than a full step"
            );
        }
    }
}
