//! Deterministic fault injection: a parseable [`FaultPlan`] describing
//! which failures to inject into a sharded run, and the per-shard
//! [`FaultInjector`] state the engine consults on its run loop.
//!
//! Every trigger is keyed on *engine iteration counts* — never wall
//! time, never randomness — so a plan replays identically under the
//! virtual clock: the same plan over the same trace kills the same
//! shard at the same point of its schedule, every run. Four failure
//! modes (failure model in `rust/ARCHITECTURE.md` §8):
//!
//! * `kill=S@N` — shard `S` panics at the top of its `N`-th engine
//!   iteration. The panic is caught by the fleet supervisor's isolation
//!   boundary ([`crate::shard::supervisor`]); the rest of the fleet
//!   keeps serving.
//! * `delay-steals=N` — the first `N` steal-inbox polls on every shard
//!   return empty without draining (a slow mailbox). Deliveries are
//!   merely deferred, never lost.
//! * `drop-steals=N` — the first `N` outbound steal deliveries on every
//!   shard divert to the coordinator's orphan pool instead of the
//!   thief's inbox (a lost delivery). The orphan pool guarantees some
//!   live shard still adopts the migrated requests.
//! * `torn-ckpt=S` — shard `S`'s next periodic [`JobStore`] flush
//!   writes one checkpoint record torn mid-line (a crash mid-write).
//!   Recovery skips the torn line and falls back to the previous
//!   checkpoint or the job spec — bounded, not fatal, loss.
//!
//! [`JobStore`]: crate::batch::JobStore

use std::fmt;

/// Marker carried by every fault-injected kill panic. The quiet panic
/// hook ([`silence_injected_panics`]) recognizes expected deaths by it,
/// and death payloads containing it are self-describing in reports.
pub const INJECTED_PANIC_MARKER: &str = "fault-injected kill";

/// A deterministic fault-injection plan for one sharded run. Parsed
/// from the `--faults` CLI spec; see the module docs for the failure
/// modes and [`FaultPlan::parse`] for the grammar.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Shard to kill (panic) mid-run, if any.
    pub kill_shard: Option<usize>,
    /// Engine iteration (1-based, per-shard counter) at which the kill
    /// fires. Meaningless unless `kill_shard` is set.
    pub kill_at_iter: u64,
    /// Number of initial steal-inbox polls (per shard) that return
    /// empty without draining.
    pub delay_steal_polls: u64,
    /// Number of initial outbound steal deliveries (per shard) diverted
    /// to the orphan pool.
    pub drop_steal_deliveries: u64,
    /// Shard whose next periodic checkpoint flush writes one torn
    /// (truncated, unterminated) record, if any.
    pub torn_ckpt_shard: Option<usize>,
}

impl FaultPlan {
    /// Parse a comma-separated fault spec:
    /// `kill=SHARD@ITER,delay-steals=N,drop-steals=M,torn-ckpt=SHARD`.
    /// Clauses may appear in any order; each at most once (later wins).
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut plan = FaultPlan::default();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (key, val) = clause
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad fault clause `{clause}` (want key=value)"))?;
            let val = val.trim();
            match key.trim() {
                "kill" => {
                    let (shard, iter) = val.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("bad kill spec `{val}` (want SHARD@ITER)")
                    })?;
                    plan.kill_shard = Some(shard.trim().parse()?);
                    plan.kill_at_iter = iter.trim().parse()?;
                }
                "delay-steals" => plan.delay_steal_polls = val.parse()?,
                "drop-steals" => plan.drop_steal_deliveries = val.parse()?,
                "torn-ckpt" => plan.torn_ckpt_shard = Some(val.parse()?),
                other => anyhow::bail!(
                    "unknown fault kind `{other}` (know kill, delay-steals, drop-steals, torn-ckpt)"
                ),
            }
        }
        Ok(plan)
    }

    /// True when the plan injects nothing (the default).
    pub fn is_noop(&self) -> bool {
        *self == FaultPlan::default()
    }

    /// The injector state shard `shard` carries into its run. Global
    /// budgets (`delay-steals`, `drop-steals`) are handed to every
    /// shard; targeted faults (`kill`, `torn-ckpt`) arm only on theirs.
    pub fn injector_for(&self, shard: usize) -> FaultInjector {
        FaultInjector {
            kill_at_iter: (self.kill_shard == Some(shard)).then_some(self.kill_at_iter.max(1)),
            delay_polls_left: self.delay_steal_polls,
            drop_deliveries_left: self.drop_steal_deliveries,
            torn_ckpt: self.torn_ckpt_shard == Some(shard),
        }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut parts: Vec<String> = Vec::new();
        if let Some(s) = self.kill_shard {
            parts.push(format!("kill={s}@{}", self.kill_at_iter));
        }
        if self.delay_steal_polls > 0 {
            parts.push(format!("delay-steals={}", self.delay_steal_polls));
        }
        if self.drop_steal_deliveries > 0 {
            parts.push(format!("drop-steals={}", self.drop_steal_deliveries));
        }
        if let Some(s) = self.torn_ckpt_shard {
            parts.push(format!("torn-ckpt={s}"));
        }
        f.write_str(&parts.join(","))
    }
}

/// Per-shard mutable injection state (built by
/// [`FaultPlan::injector_for`]). The engine consults it at fixed points
/// of the run loop; a default injector is inert on every path.
#[derive(Debug, Clone, Default)]
pub struct FaultInjector {
    kill_at_iter: Option<u64>,
    delay_polls_left: u64,
    drop_deliveries_left: u64,
    torn_ckpt: bool,
}

impl FaultInjector {
    /// True when this shard's kill is due at iteration `iter` (checked
    /// at the top of the run loop, outside every lock, so a kill can
    /// never poison shared state).
    pub fn should_kill(&self, iter: u64) -> bool {
        self.kill_at_iter.is_some_and(|k| iter >= k)
    }

    /// Consume one delayed-poll token; true while the poll should
    /// pretend the mailbox is empty.
    pub fn delay_poll(&mut self) -> bool {
        if self.delay_polls_left > 0 {
            self.delay_polls_left -= 1;
            true
        } else {
            false
        }
    }

    /// Consume one dropped-delivery token; true while the next outbound
    /// delivery should divert to the orphan pool.
    pub fn drop_delivery(&mut self) -> bool {
        if self.drop_deliveries_left > 0 {
            self.drop_deliveries_left -= 1;
            true
        } else {
            false
        }
    }

    /// One-shot: true exactly once if a torn checkpoint write is armed
    /// for this shard.
    pub fn take_torn(&mut self) -> bool {
        std::mem::take(&mut self.torn_ckpt)
    }
}

/// Install (once, process-wide) a panic hook that suppresses the
/// default stderr spam for *injected* kills while delegating every
/// other panic to the previous hook. Tests, benches and the CLI call
/// this before a run that injects kills, so an expected death does not
/// read like a failure in the output.
pub fn silence_injected_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .is_some_and(|m| m.contains(INJECTED_PANIC_MARKER));
            if !injected {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_through_display() {
        let spec = "kill=1@40,delay-steals=3,drop-steals=2,torn-ckpt=0";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.kill_shard, Some(1));
        assert_eq!(plan.kill_at_iter, 40);
        assert_eq!(plan.delay_steal_polls, 3);
        assert_eq!(plan.drop_steal_deliveries, 2);
        assert_eq!(plan.torn_ckpt_shard, Some(0));
        assert_eq!(plan.to_string(), spec);
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);
        assert!(!plan.is_noop());
        assert!(FaultPlan::parse("").unwrap().is_noop());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultPlan::parse("kill=1").is_err(), "missing @ITER");
        assert!(FaultPlan::parse("kill=x@2").is_err(), "non-numeric shard");
        assert!(FaultPlan::parse("explode=3").is_err(), "unknown kind");
        assert!(FaultPlan::parse("delay-steals").is_err(), "missing value");
    }

    #[test]
    fn injector_targets_and_budgets() {
        let plan = FaultPlan::parse("kill=1@40,delay-steals=2,drop-steals=1,torn-ckpt=0").unwrap();
        let mut on_target = plan.injector_for(1);
        let mut bystander = plan.injector_for(0);
        assert!(!on_target.should_kill(39));
        assert!(on_target.should_kill(40));
        assert!(on_target.should_kill(41), "kill stays armed past its iter");
        assert!(!bystander.should_kill(u64::MAX - 1));
        // budgets are per shard and run dry
        assert!(on_target.delay_poll());
        assert!(on_target.delay_poll());
        assert!(!on_target.delay_poll());
        assert!(bystander.drop_delivery());
        assert!(!bystander.drop_delivery());
        // torn write only on its shard, one-shot
        assert!(bystander.take_torn());
        assert!(!bystander.take_torn());
        assert!(!on_target.take_torn());
        // a default injector is inert everywhere
        let mut inert = FaultInjector::default();
        assert!(!inert.should_kill(1));
        assert!(!inert.delay_poll());
        assert!(!inert.drop_delivery());
        assert!(!inert.take_torn());
    }

    #[test]
    fn kill_at_iter_zero_still_fires() {
        // iterations are 1-based; an `@0` spec clamps to the first one
        let plan = FaultPlan::parse("kill=0@0").unwrap();
        assert!(plan.injector_for(0).should_kill(1));
    }
}
