//! Figure 5 — "Overall serving performance on real workloads."
//!
//! Three systems on the BurstGPT-like trace with the paper's SLOs
//! (TTFT 1500 ms / TPOT 110 ms): Online-Only (optimal latency, zero
//! harvest), vLLM++ (greedy co-serving), ConServe. Prints the windowed
//! P99 TTFT / P99 TPOT / throughput timeseries the figure plots plus the
//! headline aggregates.
//!
//! Paper numbers: Online-Only 1999 tok/s; ConServe 3702 tok/s (2.35x)
//! with latency below SLO; vLLM++ 4308 tok/s but P99 TTFT 84x / TPOT 25x
//! over. Asserted shape: ConServe >= ~1.5x Online-Only throughput while
//! meeting latency; vLLM++ highest raw throughput but orders-of-magnitude
//! worse tail latency.

use conserve::config::EngineConfig;
use conserve::report::compare_policies;
use conserve::scheduler::Policy;
use conserve::workload::trace::burstgpt_like_arrivals;
use conserve::workload::Lengths;

fn main() {
    let cfg = EngineConfig::sim_a100_7b();
    let duration = 900.0;
    let arrivals = burstgpt_like_arrivals(42, duration, 1.2, 1.0);
    println!(
        "online: {} requests / {duration}s; offline pool: 3000 docs; SLO: TTFT {}ms TPOT {}ms\n",
        arrivals.len(),
        cfg.sched.slo.ttft_ms,
        cfg.sched.slo.tpot_ms
    );

    let reports = compare_policies(
        &cfg,
        &[Policy::OnlineOnly, Policy::VllmPP, Policy::ConServe],
        &arrivals,
        Lengths::online_paper(),
        |p| if p == Policy::OnlineOnly { 0 } else { 3000 },
        Lengths::offline_paper(),
        duration,
    );

    println!("--- headline aggregates ---");
    for r in &reports {
        println!("{}", r.row());
    }

    println!("\n--- timeseries: online P99 TTFT (ms) / P99 TPOT (ms) / processed tok/s per 15 s window ---");
    println!(
        "{:>6} | {:>24} | {:>24} | {:>24}",
        "t_s", "Online-Only", "vLLM++", "ConServe"
    );
    let n = reports[0].online_timeseries.len();
    for w in 0..n {
        let cell = |r: &conserve::report::Report| {
            let ts = &r.online_timeseries[w];
            let all = &r.all_timeseries[w];
            format!(
                "{:>7.0} {:>6.0} {:>8.0}",
                ts.p99_ttft_ms, ts.p99_tpot_ms, all.processed_per_s
            )
        };
        println!(
            "{:>6.0} | {} | {} | {}",
            reports[0].online_timeseries[w].start_s,
            cell(&reports[0]),
            cell(&reports[1]),
            cell(&reports[2])
        );
    }

    let (oo, vpp, cs) = (&reports[0], &reports[1], &reports[2]);
    let harvest = cs.total_processed_tput / oo.total_processed_tput.max(1.0);
    let vs_vpp_ttft = vpp.online_p99_ttft_ms / cs.online_p99_ttft_ms.max(1.0);
    println!("\nConServe / Online-Only processed throughput: {harvest:.2}x (paper: 2.35x)");
    println!("vLLM++ / ConServe P99 TTFT: {vs_vpp_ttft:.0}x (paper: 84x)");
    println!(
        "ConServe P99 TTFT {:.0} ms (SLO 1500), P99 TPOT {:.0} ms (SLO 110), violations {:.1}%",
        cs.online_p99_ttft_ms,
        cs.online_p99_tpot_ms,
        cs.ttft_violations * 100.0
    );

    assert!(harvest > 1.5, "ConServe must harvest significantly (got {harvest:.2}x)");
    assert!(
        cs.online_p99_ttft_ms < cfg.sched.slo.ttft_ms * 1.15,
        "ConServe P99 TTFT {:.0}ms must stay near SLO",
        cs.online_p99_ttft_ms
    );
    assert!(
        vpp.online_p99_ttft_ms > 4.0 * cs.online_p99_ttft_ms,
        "vLLM++ tail latency must be far worse than ConServe"
    );
    // Deviation from the paper (see EXPERIMENTS.md): on their testbed
    // vLLM++ kept the highest raw throughput (4308 tok/s); in this memory
    // model its class-blind LIFO preemption + admission stalls collapse
    // its throughput as well, so ConServe dominates on both axes. The
    // robust shape claim is the SLO violation rate:
    assert!(
        vpp.ttft_violations > 0.5,
        "vLLM++ must violate the TTFT SLO for most requests"
    );
    println!("\nfig5 shape OK");
}
