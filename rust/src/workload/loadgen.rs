//! Gamma-process load generator.
//!
//! Inter-arrival gaps follow Gamma(k = 1/CV², θ = 1/(rate·k)): mean gap is
//! 1/rate and the coefficient of variation is CV (paper §6.3.2 measures
//! burstiness as the CV of the gamma arrival process; CV = 1 is Poisson).

use crate::util::rng::Rng;
use crate::{TimeUs, US_PER_SEC};

#[derive(Debug, Clone)]
pub struct LoadGen {
    rng: Rng,
    pub rate: f64,
    pub cv: f64,
    next_at: f64, // seconds
}

impl LoadGen {
    pub fn new(seed: u64, rate: f64, cv: f64) -> Self {
        assert!(rate > 0.0 && cv > 0.0);
        let mut g = Self {
            rng: Rng::new(seed),
            rate,
            cv,
            next_at: 0.0,
        };
        g.advance();
        g
    }

    fn advance(&mut self) {
        self.next_at += self.rng.gamma_interarrival(self.rate, self.cv);
    }

    /// Next arrival timestamp (µs).
    pub fn peek(&self) -> TimeUs {
        (self.next_at * US_PER_SEC as f64) as TimeUs
    }

    /// Consume and return the next arrival timestamp (µs).
    pub fn pop(&mut self) -> TimeUs {
        let t = self.peek();
        self.advance();
        t
    }

    /// Generate all arrivals within [0, duration_s].
    pub fn arrivals_until(&mut self, duration_s: f64) -> Vec<TimeUs> {
        let mut out = Vec::new();
        while self.next_at <= duration_s {
            out.push(self.pop());
        }
        out
    }

    /// Change the rate mid-stream (ON/OFF and trace-driven loads).
    pub fn set_rate(&mut self, rate: f64) {
        assert!(rate > 0.0);
        self.rate = rate;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_rate_converges() {
        let mut g = LoadGen::new(7, 10.0, 1.0);
        let arrivals = g.arrivals_until(200.0);
        let rate = arrivals.len() as f64 / 200.0;
        assert!((rate - 10.0).abs() < 0.6, "rate={rate}");
    }

    #[test]
    fn arrivals_monotone() {
        let mut g = LoadGen::new(8, 5.0, 2.0);
        let a = g.arrivals_until(50.0);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        assert!(!a.is_empty());
    }

    #[test]
    fn higher_cv_is_burstier() {
        // burstiness proxy: variance of per-second arrival counts
        let counts = |cv: f64| {
            let mut g = LoadGen::new(9, 20.0, cv);
            let arrivals = g.arrivals_until(100.0);
            let mut c = vec![0f64; 100];
            for t in arrivals {
                let s = (t / US_PER_SEC) as usize;
                if s < 100 {
                    c[s] += 1.0;
                }
            }
            let mean = c.iter().sum::<f64>() / 100.0;
            c.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / 100.0
        };
        assert!(counts(4.0) > 2.0 * counts(0.5));
    }

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<_> = LoadGen::new(1, 3.0, 1.0).arrivals_until(10.0);
        let b: Vec<_> = LoadGen::new(1, 3.0, 1.0).arrivals_until(10.0);
        assert_eq!(a, b);
    }
}
