//! Serving metrics: per-request TTFT, per-token TPOT, throughput, and the
//! windowed-percentile timeseries the paper's figures plot.
//!
//! Online quality is P99 TTFT (prefill latency incl. queueing) and P99
//! TPOT (inter-token latency, paper footnote 2: per *decode step*, not
//! per-request average). Offline quality is generated tokens/second.

use crate::request::Class;
use crate::{TimeUs, US_PER_SEC};

/// Percentile over a sample set (nearest-rank on a sorted copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

#[derive(Debug, Clone, Copy)]
pub struct TokenEvent {
    pub t: TimeUs,
    pub class: Class,
    /// Inter-token gap for decode tokens (None for the first token).
    pub tpot_us: Option<u64>,
}

#[derive(Debug, Clone, Copy)]
pub struct TtftEvent {
    pub t: TimeUs,
    pub class: Class,
    pub ttft_us: u64,
}

/// Tokens *processed* (prefill chunk + decode) in one iteration — the
/// utilization-style throughput the harvest figures report alongside
/// generation throughput.
#[derive(Debug, Clone, Copy)]
pub struct ProcessedEvent {
    pub t: TimeUs,
    pub class: Class,
    pub n: usize,
}

/// Append-only metrics recorder; analysis happens after the run.
#[derive(Debug, Default)]
pub struct Recorder {
    pub ttfts: Vec<TtftEvent>,
    pub tokens: Vec<TokenEvent>,
    pub processed: Vec<ProcessedEvent>,
    pub preemptions: u64,
    pub layer_aborts: u64,
    pub recomputed_tokens: u64,
    pub ckpt_blocks: u64,
    pub prefetch_blocks: u64,
    pub blocking_swap_us: u64,
    pub finished: [u64; 2], // [online, offline]
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_first_token(&mut self, t: TimeUs, class: Class, ttft_us: u64) {
        self.ttfts.push(TtftEvent { t, class, ttft_us });
        self.tokens.push(TokenEvent {
            t,
            class,
            tpot_us: None,
        });
    }

    pub fn record_token(&mut self, t: TimeUs, class: Class, gap_us: u64) {
        self.tokens.push(TokenEvent {
            t,
            class,
            tpot_us: Some(gap_us),
        });
    }

    pub fn record_processed(&mut self, t: TimeUs, class: Class, n: usize) {
        if n > 0 {
            self.processed.push(ProcessedEvent { t, class, n });
        }
    }

    /// Processed tokens/second over [from, to) (prefill + decode), the
    /// "overall serving throughput" of Figures 5-8.
    pub fn processed_throughput(
        &self,
        class: Option<Class>,
        from: TimeUs,
        to: TimeUs,
    ) -> f64 {
        if to <= from {
            return 0.0;
        }
        let n: usize = self
            .processed
            .iter()
            .filter(|e| e.t >= from && e.t < to)
            .filter(|e| class.is_none_or(|c| e.class == c))
            .map(|e| e.n)
            .sum();
        n as f64 * US_PER_SEC as f64 / (to - from) as f64
    }

    pub fn record_finished(&mut self, class: Class) {
        self.finished[match class {
            Class::Online => 0,
            Class::Offline => 1,
        }] += 1;
    }

    // ------------------------------------------------------------ queries

    fn ttft_ms_of(&self, class: Option<Class>) -> Vec<f64> {
        self.ttfts
            .iter()
            .filter(|e| class.is_none_or(|c| e.class == c))
            .map(|e| e.ttft_us as f64 / 1000.0)
            .collect()
    }

    fn tpot_ms_of(&self, class: Option<Class>) -> Vec<f64> {
        self.tokens
            .iter()
            .filter(|e| class.is_none_or(|c| e.class == c))
            .filter_map(|e| e.tpot_us)
            .map(|us| us as f64 / 1000.0)
            .collect()
    }

    pub fn p99_ttft_ms(&self, class: Class) -> f64 {
        percentile(&self.ttft_ms_of(Some(class)), 99.0)
    }

    pub fn p99_tpot_ms(&self, class: Class) -> f64 {
        percentile(&self.tpot_ms_of(Some(class)), 99.0)
    }

    pub fn mean_ttft_ms(&self, class: Class) -> f64 {
        let v = self.ttft_ms_of(Some(class));
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    }

    /// Generated tokens per second over [from, to) for a class (or both).
    pub fn throughput(&self, class: Option<Class>, from: TimeUs, to: TimeUs) -> f64 {
        if to <= from {
            return 0.0;
        }
        let n = self
            .tokens
            .iter()
            .filter(|e| e.t >= from && e.t < to)
            .filter(|e| class.is_none_or(|c| e.class == c))
            .count();
        n as f64 * US_PER_SEC as f64 / (to - from) as f64
    }

    /// Windowed timeseries of (window_start_s, p99 TTFT ms, p99 TPOT ms,
    /// tokens/s) — the series Figures 5/6 plot.
    pub fn timeseries(&self, class: Option<Class>, window: TimeUs, until: TimeUs) -> Vec<WindowStats> {
        let mut out = Vec::new();
        let mut start = 0;
        while start < until {
            let end = start + window;
            let ttfts: Vec<f64> = self
                .ttfts
                .iter()
                .filter(|e| e.t >= start && e.t < end)
                .filter(|e| class.is_none_or(|c| e.class == c))
                .map(|e| e.ttft_us as f64 / 1000.0)
                .collect();
            let tpots: Vec<f64> = self
                .tokens
                .iter()
                .filter(|e| e.t >= start && e.t < end)
                .filter(|e| class.is_none_or(|c| e.class == c))
                .filter_map(|e| e.tpot_us)
                .map(|us| us as f64 / 1000.0)
                .collect();
            out.push(WindowStats {
                start_s: start as f64 / US_PER_SEC as f64,
                p99_ttft_ms: percentile(&ttfts, 99.0),
                p99_tpot_ms: percentile(&tpots, 99.0),
                tokens_per_s: self.throughput(class, start, end),
                processed_per_s: self.processed_throughput(class, start, end),
                n_ttft: ttfts.len(),
            });
            start = end;
        }
        out
    }

    /// Fraction of online TTFTs above the SLO.
    pub fn ttft_violation_rate(&self, class: Class, slo_ms: f64) -> f64 {
        let v = self.ttft_ms_of(Some(class));
        if v.is_empty() {
            return 0.0;
        }
        v.iter().filter(|&&x| x > slo_ms).count() as f64 / v.len() as f64
    }
}

#[derive(Debug, Clone, Copy)]
pub struct WindowStats {
    pub start_s: f64,
    pub p99_ttft_ms: f64,
    pub p99_tpot_ms: f64,
    pub tokens_per_s: f64,
    pub processed_per_s: f64,
    pub n_ttft: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert_eq!(percentile(&[], 99.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn ttft_and_tpot_split_by_class() {
        let mut r = Recorder::new();
        r.record_first_token(1_000_000, Class::Online, 200_000);
        r.record_first_token(2_000_000, Class::Offline, 9_000_000);
        r.record_token(2_100_000, Class::Online, 50_000);
        r.record_token(2_200_000, Class::Online, 60_000);
        assert_eq!(r.p99_ttft_ms(Class::Online), 200.0);
        assert_eq!(r.p99_ttft_ms(Class::Offline), 9000.0);
        assert_eq!(r.p99_tpot_ms(Class::Online), 60.0);
        assert_eq!(r.p99_tpot_ms(Class::Offline), 0.0);
    }

    #[test]
    fn throughput_counts_all_tokens_in_window() {
        let mut r = Recorder::new();
        for i in 0..100 {
            r.record_token(i * 10_000, Class::Offline, 10_000); // 100 tokens in 1s
        }
        let tput = r.throughput(None, 0, US_PER_SEC);
        assert!((tput - 100.0).abs() < 1.0, "tput={tput}");
        assert_eq!(r.throughput(Some(Class::Online), 0, US_PER_SEC), 0.0);
    }

    #[test]
    fn timeseries_windows() {
        let mut r = Recorder::new();
        r.record_first_token(500_000, Class::Online, 100_000);
        r.record_first_token(1_500_000, Class::Online, 300_000);
        let ts = r.timeseries(Some(Class::Online), US_PER_SEC, 2 * US_PER_SEC);
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].p99_ttft_ms, 100.0);
        assert_eq!(ts[1].p99_ttft_ms, 300.0);
    }

    #[test]
    fn violation_rate() {
        let mut r = Recorder::new();
        for ttft in [100_000u64, 200_000, 2_000_000, 90_000] {
            r.record_first_token(0, Class::Online, ttft);
        }
        assert_eq!(r.ttft_violation_rate(Class::Online, 1500.0), 0.25);
    }
}
