//! Cross-module integration tests that don't need artifacts or long
//! simulations: config -> scheduler wiring, profiler -> budget -> plan
//! consistency, swap engine <-> kv manager interplay, workload -> engine
//! plumbing, and the checkpoint controller inside the engine loop.

use conserve::backend::{CostModel, SimBackend};
use conserve::clock::Clock;
use conserve::config::EngineConfig;
use conserve::metrics::percentile;
use conserve::profiler::LatencyProfile;
use conserve::report::SimExperiment;
use conserve::request::{Class, Request};
use conserve::scheduler::Policy;
use conserve::server::{ArrivalSource, ServingEngine};
use conserve::workload::Lengths;

#[test]
fn config_policy_flags_flow_into_behaviour() {
    // disabling layerwise preemption must remove layer aborts entirely
    let mk = |layerwise: bool| {
        let mut cfg = EngineConfig::sim_a100_7b();
        cfg.sched.layerwise_preempt = layerwise;
        let online =
            conserve::workload::trace::onoff_trace(5, 120.0, 30.0, 4.0, 2.0);
        SimExperiment {
            cfg,
            online_arrivals: online,
            online_lengths: Lengths::Fixed {
                input: 1024,
                output: 128,
            },
            offline_pool: 800,
            offline_lengths: Lengths::offline_paper(),
            duration_s: 120.0,
        }
        .run()
    };
    let with = mk(true);
    let without = mk(false);
    assert!(with.layer_aborts > 0);
    assert_eq!(without.layer_aborts, 0);
}

#[test]
fn ablation_flags_change_mechanisms_not_correctness() {
    let online = conserve::workload::LoadGen::new(3, 2.0, 1.0).arrivals_until(60.0);
    for (ckpt, prefetch) in [(false, false), (true, false), (true, true)] {
        let mut cfg = EngineConfig::sim_a100_7b();
        cfg.sched.incremental_ckpt = ckpt;
        cfg.sched.prefetch = prefetch;
        let r = SimExperiment {
            cfg,
            online_arrivals: online.clone(),
            online_lengths: Lengths::online_paper(),
            offline_pool: 300,
            offline_lengths: Lengths::offline_paper(),
            duration_s: 60.0,
        }
        .run();
        if !ckpt {
            assert_eq!(r.ckpt_blocks, 0, "no checkpoints when disabled");
        }
        if !prefetch {
            assert_eq!(r.prefetch_blocks, 0, "no prefetch when disabled");
        }
        assert!(r.online_finished > 0);
    }
}

#[test]
fn engine_with_channel_source_and_sim_backend() {
    // live submission path wired through the engine (virtual clock)
    let cfg = EngineConfig::sim_a100_7b();
    let clock = Clock::virtual_at(0);
    let backend = SimBackend::new(
        CostModel::a100_llama2_7b(),
        clock.clone(),
        cfg.sched.safepoint_layers,
    );
    let profile = LatencyProfile {
        c: [1200.0, 96.0, 40.0, 0.385],
    };
    let (client, src) = ArrivalSource::channel();
    client.submit_online(vec![0; 128], 8);
    let batch = client.submit_batch(vec![(vec![0; 256], 16), (vec![0; 256], 16)]);
    assert!(!batch.done(), "nothing served yet");
    let board = client.job_board().clone();
    drop(client);
    let mut engine = ServingEngine::new(cfg, backend, clock, profile, src);
    engine.set_job_board(board);
    engine.run(60_000_000);
    assert_eq!(engine.rec.finished[0], 1);
    assert_eq!(engine.rec.finished[1], 2);
    // the engine drove the poll-able batch handle to completion
    assert!(batch.done());
    let p = batch.progress();
    assert_eq!((p.total, p.finished), (2, 2));
    assert_eq!(engine.rec.jobs_completed, 1);
}

#[test]
fn trace_arrivals_honoured_by_virtual_clock() {
    let cfg = EngineConfig::sim_a100_7b();
    let clock = Clock::virtual_at(0);
    let backend = SimBackend::new(CostModel::a100_llama2_7b(), clock.clone(), 8);
    let profile = LatencyProfile {
        c: [1200.0, 96.0, 40.0, 0.385],
    };
    let events = vec![
        Request::new(1, Class::Online, vec![], 512, 4, 10_000_000),
        Request::new(2, Class::Online, vec![], 512, 4, 30_000_000),
    ];
    let mut engine = ServingEngine::new(
        cfg,
        backend,
        clock,
        profile,
        ArrivalSource::from_trace(events),
    );
    engine.run(120_000_000);
    let r1 = &engine.table[&1];
    let r2 = &engine.table[&2];
    // first token cannot precede arrival; idle gaps are jumped, not spun
    assert!(r1.first_token_at.unwrap() >= 10_000_000);
    assert!(r2.first_token_at.unwrap() >= 30_000_000);
    assert!(r1.ttft().unwrap() < 2_000_000, "ttft {:?}", r1.ttft());
}

#[test]
fn kv_conservation_after_full_experiment() {
    let online = conserve::workload::LoadGen::new(9, 3.0, 2.0).arrivals_until(45.0);
    let cfg = EngineConfig::sim_a100_7b();
    let clock = Clock::virtual_at(0);
    let backend =
        SimBackend::new(CostModel::a100_llama2_7b(), clock.clone(), cfg.sched.safepoint_layers);
    let profile = LatencyProfile {
        c: [1200.0, 96.0, 40.0, 0.385],
    };
    let mut events: Vec<Request> = online
        .iter()
        .enumerate()
        .map(|(i, &t)| Request::new(i as u64 + 1, Class::Online, vec![], 1024, 64, t))
        .collect();
    for i in 0..200u64 {
        events.push(Request::new(10_000 + i, Class::Offline, vec![], 2048, 128, 0));
    }
    let mut engine = ServingEngine::new(
        cfg,
        backend,
        clock,
        profile,
        ArrivalSource::from_trace(events),
    );
    engine.run(45_000_000);
    assert!(engine.kv.check_conservation(), "blocks leaked during serving");
}

#[test]
fn percentile_matches_manual_p99() {
    let mut v: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
    v.reverse();
    assert_eq!(percentile(&v, 99.0), 990.0);
}

#[test]
fn policies_parse_and_compare() {
    assert_eq!("conserve".parse::<Policy>().unwrap(), Policy::ConServe);
    assert_eq!("vllm++".parse::<Policy>().unwrap(), Policy::VllmPP);
    assert_eq!("online-only".parse::<Policy>().unwrap(), Policy::OnlineOnly);
    assert!("gpt".parse::<Policy>().is_err());
    assert_eq!(Policy::ConServe.to_string(), "ConServe");
}
