"""L1 Pallas kernel: fused RMSNorm (normalize + scale in one pass).

Runs twice per layer (attention and MLP pre-norms) plus once before the LM
head. The fusion saves one full read/write of the activation tensor versus
the naive mean-square -> rsqrt -> multiply pipeline.

TPU mapping: grid over row tiles; each grid step stages a [BN, D] tile of
activations into VMEM, reduces along the lane dimension in f32, and writes
the scaled tile back — one HBM round trip per tile. interpret=True for CPU
PJRT (see attention.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 8  # rows per tile; 8 = TPU sublane width for f32


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # [BN, D]
    w = w_ref[...].astype(jnp.float32)                 # [D]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * w).astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array,  # [N, D] (callers flatten leading dims)
    w: jax.Array,  # [D]
    *,
    eps: float = 1e-5,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
) -> jax.Array:
    """Fused RMSNorm over the last axis of a 2-D tensor."""
    N, D = x.shape
    bn = min(block_n, N)
    while N % bn != 0:  # fall back to the largest divisor (worst case 1)
        bn -= 1

    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(N // bn,),
        in_specs=[
            pl.BlockSpec((bn, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        interpret=interpret,
    )(x, w)
