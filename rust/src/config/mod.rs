//! Configuration system: typed config structs with presets for the two
//! backends, a flat `key = value` config-file format, and `--key=value`
//! CLI overrides. Every tunable the paper exposes (SLOs, chunk size,
//! safepoint granularity, checkpoint watermark, pool sizes, policy /
//! ablation flags) lives here.

use crate::scheduler::Policy;
use anyhow::{bail, Context, Result};

/// Latency service-level objectives (paper §2.2: P99 TTFT / P99 TPOT).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloConfig {
    pub ttft_ms: f64,
    pub tpot_ms: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct SchedConfig {
    pub policy: Policy,
    /// Max prefill tokens admitted per iteration per request (chunked
    /// prefill, §4.2).
    pub chunk_size: usize,
    /// Hard cap on requests per iteration.
    pub max_batch_reqs: usize,
    /// Token cap per iteration in *offline batching mode* (§4.2: "ignores
    /// the budget limit and sets the largest batch size that can saturate
    /// GPU compute or memory").
    pub max_batch_tokens: usize,
    pub slo: SloConfig,
    // ---- ablation flags (Fig. 8) ----
    /// SLO-aware budget + reactive preemption (vs. greedy batching).
    pub slo_aware: bool,
    /// Incremental checkpointing (§4.4).
    pub incremental_ckpt: bool,
    /// Background prefetching / swap-in overlap (§4.4).
    pub prefetch: bool,
    /// Layer-granularity preemption of running offline batches (§4.3).
    pub layerwise_preempt: bool,
    /// Checkpointing starts when GPU free memory drops below this
    /// fraction of the pool (§4.4 adaptive policy; default 0.5).
    pub ckpt_free_watermark: f64,
    /// Layers per safepoint interval (§6.4.2: 8 balances overhead vs
    /// responsiveness).
    pub safepoint_layers: usize,
    /// Job-aware offline admission order (crate::batch): pick the next
    /// offline request by (urgency desc, weighted tenant deficit,
    /// FIFO) instead of plain FIFO. Off by default — standalone offline
    /// requests carry no job identity and see pure FIFO either way.
    pub fair_share: bool,
    // ---- closed-loop harvest controller (scheduler::harvest) ----
    /// Enable the per-shard feedback controller that retunes the
    /// offline token budget / chunk size from live TTFT/TPOT
    /// percentiles (AIMD with hysteresis). Off by default: the static
    /// `max_batch_tokens` budget applies unchanged.
    pub harvest: bool,
    /// Controller TTFT target in µs (0 = derive from `slo.ttft_ms`).
    /// The `--harvest on:SLO_US` CLI form sets this.
    pub harvest_slo_us: u64,
    /// Lower clamp of the controller's budget/chunk actuation (tokens).
    /// Also the safe initial budget a fresh (or recovered) shard's
    /// controller starts from.
    pub min_chunk: usize,
    /// Offline prefill chunk override (tokens; 0 = use `chunk_size`).
    /// Runtime-actuated by the harvest controller; online prefill
    /// chunking always uses `chunk_size`.
    pub offline_chunk: usize,
    /// Cross-request prefix KV sharing: refcounted blocks, an
    /// admission-time prefix trie, and prefill skipping over shared
    /// blocks (`kvcache::prefix`). Off by default: every path behaves
    /// exactly as before sharing existed.
    pub prefix_cache: bool,
}

/// KV memory pools, in blocks of `block_tokens` token-slots.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemConfig {
    pub gpu_blocks: usize,
    pub host_blocks: usize,
    pub block_tokens: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    pub sched: SchedConfig,
    pub mem: MemConfig,
    /// Max context (prompt + output) per sequence.
    pub max_model_len: usize,
    /// Experiment seed (workloads, sampling).
    pub seed: u64,
}

impl EngineConfig {
    /// Preset matching the paper's testbed simulation: A100-40G with
    /// Llama-2-7B (see `backend::costmodel` for the calibration).
    pub fn sim_a100_7b() -> Self {
        EngineConfig {
            sched: SchedConfig {
                policy: Policy::ConServe,
                chunk_size: 512,
                max_batch_reqs: 256,
                // offline batching mode saturates compute with this cap:
                // ~0.85 s iterations — long enough that Alg.-2 layer
                // aborts (checks every ~215 ms at 8-layer granularity)
                // are what keeps OFF->ON transitions responsive (§4.3),
                // short enough that one abort wastes < 1 GPU-second
                max_batch_tokens: 8192,
                slo: SloConfig {
                    ttft_ms: 1500.0,
                    tpot_ms: 110.0,
                },
                slo_aware: true,
                incremental_ckpt: true,
                prefetch: true,
                layerwise_preempt: true,
                ckpt_free_watermark: 0.5,
                safepoint_layers: 8,
                fair_share: false,
                harvest: false,
                harvest_slo_us: 0,
                min_chunk: 64,
                offline_chunk: 0,
                prefix_cache: false,
            },
            mem: MemConfig {
                // 40 GB - 13.5 weights - ~2.5 activations => ~24 GB KV;
                // 0.5 MB/token, 16-token blocks => 8 MB/block => 3072.
                gpu_blocks: 3072,
                // 320 GB host RAM in the paper's server; leave the same
                // 24 GB worth by default (checkpoint mirror), configurable.
                host_blocks: 3072 * 4,
                block_tokens: 16,
            },
            max_model_len: 4096,
            seed: 0xC0_5E_7E,
        }
    }

    /// Preset for the real tiny-Llama CPU-PJRT path (examples/).
    pub fn real_tiny() -> Self {
        EngineConfig {
            sched: SchedConfig {
                policy: Policy::ConServe,
                chunk_size: 64,
                max_batch_reqs: 8,
                max_batch_tokens: 512,
                slo: SloConfig {
                    ttft_ms: 1500.0,
                    tpot_ms: 150.0,
                },
                slo_aware: true,
                incremental_ckpt: true,
                prefetch: true,
                layerwise_preempt: true,
                ckpt_free_watermark: 0.5,
                safepoint_layers: 1, // 4-layer model: safepoint every layer
                fair_share: false,
                harvest: false,
                harvest_slo_us: 0,
                min_chunk: 16,
                offline_chunk: 0,
                prefix_cache: false,
            },
            mem: MemConfig {
                // Tight pool so preemption/checkpointing paths actually
                // trigger on the tiny model: 48 blocks of 16 = 768 token
                // slots on the "GPU".
                gpu_blocks: 48,
                host_blocks: 256,
                block_tokens: 16,
            },
            max_model_len: 256,
            seed: 0xC0_5E_7E,
        }
    }

    /// Apply a `key=value` override (CLI `--set key=value` / config file
    /// line). Unknown keys are an error so typos fail loudly.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let v = value.trim();
        match key.trim() {
            "policy" => self.sched.policy = v.parse()?,
            "chunk_size" => self.sched.chunk_size = parse(v)?,
            "max_batch_reqs" => self.sched.max_batch_reqs = parse(v)?,
            "max_batch_tokens" => self.sched.max_batch_tokens = parse(v)?,
            "ttft_ms" => self.sched.slo.ttft_ms = parse(v)?,
            "tpot_ms" => self.sched.slo.tpot_ms = parse(v)?,
            "slo_aware" => self.sched.slo_aware = parse_bool(v)?,
            "incremental_ckpt" => self.sched.incremental_ckpt = parse_bool(v)?,
            "prefetch" => self.sched.prefetch = parse_bool(v)?,
            "layerwise_preempt" => self.sched.layerwise_preempt = parse_bool(v)?,
            "ckpt_free_watermark" => self.sched.ckpt_free_watermark = parse(v)?,
            "safepoint_layers" => self.sched.safepoint_layers = parse(v)?,
            "fair_share" => self.sched.fair_share = parse_bool(v)?,
            "harvest" => self.sched.harvest = parse_bool(v)?,
            "harvest_slo_us" => self.sched.harvest_slo_us = parse(v)?,
            "min_chunk" => self.sched.min_chunk = parse(v)?,
            "offline_chunk" => self.sched.offline_chunk = parse(v)?,
            "prefix_cache" => self.sched.prefix_cache = parse_bool(v)?,
            "gpu_blocks" => self.mem.gpu_blocks = parse(v)?,
            "host_blocks" => self.mem.host_blocks = parse(v)?,
            "block_tokens" => self.mem.block_tokens = parse(v)?,
            "max_model_len" => self.max_model_len = parse(v)?,
            "seed" => self.seed = parse(v)?,
            other => bail!("unknown config key `{other}`"),
        }
        Ok(())
    }

    /// Load overrides from a config file: one `key = value` per line,
    /// `#` comments, blank lines ignored.
    pub fn apply_file(&mut self, text: &str) -> Result<()> {
        for (i, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", i + 1))?;
            self.set(k, v)
                .with_context(|| format!("line {}", i + 1))?;
        }
        Ok(())
    }

    /// Blocks needed to hold `tokens` cache slots.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.mem.block_tokens)
    }
}

fn parse<T: std::str::FromStr>(v: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    v.parse::<T>()
        .map_err(|e| anyhow::anyhow!("bad value `{v}`: {e}"))
}

fn parse_bool(v: &str) -> Result<bool> {
    match v {
        "true" | "1" | "on" | "yes" => Ok(true),
        "false" | "0" | "off" | "no" => Ok(false),
        _ => bail!("bad bool `{v}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_sane() {
        let sim = EngineConfig::sim_a100_7b();
        assert!(sim.mem.gpu_blocks * sim.mem.block_tokens >= 16384);
        let real = EngineConfig::real_tiny();
        assert!(real.max_model_len <= 256);
        assert_eq!(real.blocks_for(17), 2);
        assert_eq!(real.blocks_for(16), 1);
        assert_eq!(real.blocks_for(0), 0);
    }

    #[test]
    fn set_overrides() {
        let mut c = EngineConfig::sim_a100_7b();
        c.set("ttft_ms", "2000").unwrap();
        c.set("policy", "vllm++").unwrap();
        c.set("incremental_ckpt", "off").unwrap();
        assert_eq!(c.sched.slo.ttft_ms, 2000.0);
        assert_eq!(c.sched.policy, Policy::VllmPP);
        assert!(!c.sched.incremental_ckpt);
        assert!(c.set("no_such_key", "1").is_err());
        assert!(c.set("chunk_size", "abc").is_err());
    }

    #[test]
    fn apply_file_parses() {
        let mut c = EngineConfig::sim_a100_7b();
        c.apply_file("# comment\n chunk_size = 256 \n\npolicy=online-only # tail\n")
            .unwrap();
        assert_eq!(c.sched.chunk_size, 256);
        assert_eq!(c.sched.policy, Policy::OnlineOnly);
        assert!(c.apply_file("nonsense line").is_err());
    }
}
