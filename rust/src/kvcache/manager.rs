//! Block-granular KV accounting: GPU and host pools, per-sequence block
//! tables, and the GPU<->host checkpoint mapping (§5: "keeping track of
//! the mapping between each GPU KV block and its corresponding CPU KV
//! block ... recorded in an extended field of the virtual page table").
//!
//! Sequences are keyed by the *slot* field of [`RequestId`] (the same
//! dense index the request arena uses), so `grow`/`commit`/`seq` are
//! plain array accesses with a generation check — no hashing on the
//! schedule→execute→commit path. A lookup with a stale generation
//! resolves to "unknown sequence", never to another request's KV.
//!
//! Like the arena, each manager belongs to one worker shard
//! ([`KvManager::for_shard`]; default shard 0) and checks the shard bits
//! of every id, so a request id from another shard can never read or
//! mutate this shard's block tables.

use super::BlockId;
use crate::request::{rid_gen, rid_shard, rid_slot, RequestId, MAX_SHARDS};

/// A pool of fixed-size blocks; O(1) alloc/free via a free list.
#[derive(Debug)]
pub struct BlockPool {
    total: usize,
    free: Vec<BlockId>,
}

impl BlockPool {
    pub fn new(total: usize) -> Self {
        Self {
            total,
            free: (0..total as BlockId).rev().collect(),
        }
    }

    pub fn alloc(&mut self) -> Option<BlockId> {
        self.free.pop()
    }

    pub fn free(&mut self, b: BlockId) {
        debug_assert!(!self.free.contains(&b), "double free of block {b}");
        self.free.push(b);
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn used(&self) -> usize {
        self.total - self.free.len()
    }
}

/// Per-logical-block checkpoint state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockCkpt {
    /// No host copy.
    None,
    /// D2H copy in flight.
    InFlight(BlockId),
    /// Host copy valid at `BlockId`.
    Done(BlockId),
}

/// Block table for one sequence.
#[derive(Debug)]
pub struct SeqKv {
    /// Logical block i -> GPU physical block (None after GPU eviction).
    pub gpu: Vec<Option<BlockId>>,
    /// Logical block i -> host checkpoint state.
    pub host: Vec<BlockCkpt>,
    /// Committed tokens (== the owning request's ctx_len).
    pub tokens: usize,
    /// GPU-resident block count, maintained on alloc/evict so the victim
    /// scan does not rescan the block table.
    resident: usize,
    /// Completed host checkpoints, maintained on finish/invalidate so
    /// `fully_checkpointed` is O(1).
    host_done: usize,
}

impl SeqKv {
    fn new() -> Self {
        Self {
            gpu: Vec::new(),
            host: Vec::new(),
            tokens: 0,
            resident: 0,
            host_done: 0,
        }
    }

    /// GPU-resident blocks (O(1): maintained counter).
    pub fn gpu_blocks(&self) -> usize {
        self.resident
    }

    /// All logical blocks that hold committed tokens have valid host
    /// copies (the "cheap to evict" condition of §4.4). O(1): completed
    /// checkpoints can only cover blocks holding committed tokens, so
    /// counting them suffices.
    pub fn fully_checkpointed(&self, block_tokens: usize) -> bool {
        self.host_done >= self.tokens.div_ceil(block_tokens)
    }

    /// Tokens covered by completed host checkpoints (prefix).
    pub fn ckpt_tokens(&self, block_tokens: usize) -> usize {
        let mut n = 0;
        for (i, c) in self.host.iter().enumerate() {
            if matches!(c, BlockCkpt::Done(_)) {
                n = (i + 1) * block_tokens;
            } else {
                break;
            }
        }
        n.min(self.tokens)
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum KvError {
    OutOfGpu { need: usize, free: usize },
    OutOfHost,
    UnknownSeq(RequestId),
    /// The sequence is not in a migratable state: it still holds GPU
    /// blocks, has checkpoints in flight, or its committed tokens are not
    /// fully covered by completed host checkpoints (§4.4: only fully
    /// checkpointed, evicted sequences move for free).
    NotPortable(RequestId),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfGpu { need, free } => {
                write!(f, "out of GPU KV blocks (need {need}, free {free})")
            }
            KvError::OutOfHost => write!(f, "out of host KV blocks"),
            KvError::UnknownSeq(id) => write!(f, "unknown sequence {id}"),
            KvError::NotPortable(id) => {
                write!(f, "sequence {id} is not fully host-checkpointed")
            }
        }
    }
}

impl std::error::Error for KvError {}

/// One dense sequence-table entry. `generation` mirrors the request
/// arena's slot generation; a lookup only hits when both halves of the
/// id match.
#[derive(Debug, Default)]
struct SeqEntry {
    generation: u32,
    kv: Option<SeqKv>,
}

/// The KV-cache manager: pools + tables. All scheduler memory decisions
/// (admission, eviction, checkpoint selection) query this.
#[derive(Debug)]
pub struct KvManager {
    pub block_tokens: usize,
    shard: u32,
    gpu: BlockPool,
    host: BlockPool,
    seqs: Vec<SeqEntry>,
}

impl KvManager {
    /// Single-worker manager (shard 0).
    pub fn new(gpu_blocks: usize, host_blocks: usize, block_tokens: usize) -> Self {
        Self::for_shard(0, gpu_blocks, host_blocks, block_tokens)
    }

    /// Manager for worker shard `shard`: only ids carrying this shard
    /// index resolve; everything else misses as an unknown sequence.
    pub fn for_shard(
        shard: usize,
        gpu_blocks: usize,
        host_blocks: usize,
        block_tokens: usize,
    ) -> Self {
        assert!(shard < MAX_SHARDS, "shard {shard} out of range");
        Self {
            block_tokens,
            shard: shard as u32,
            gpu: BlockPool::new(gpu_blocks),
            host: BlockPool::new(host_blocks),
            seqs: Vec::new(),
        }
    }

    /// The worker shard this manager belongs to.
    pub fn shard(&self) -> usize {
        self.shard as usize
    }

    /// Does `id` carry this manager's shard index?
    #[inline]
    fn owns(&self, id: RequestId) -> bool {
        rid_shard(id) == self.shard as usize
    }

    pub fn gpu_free(&self) -> usize {
        self.gpu.available()
    }

    pub fn gpu_total(&self) -> usize {
        self.gpu.total()
    }

    pub fn gpu_free_frac(&self) -> f64 {
        self.gpu.available() as f64 / self.gpu.total() as f64
    }

    pub fn host_free(&self) -> usize {
        self.host.available()
    }

    #[inline]
    pub fn seq(&self, id: RequestId) -> Option<&SeqKv> {
        if !self.owns(id) {
            return None;
        }
        self.seqs
            .get(rid_slot(id))
            .filter(|e| e.generation == rid_gen(id))
            .and_then(|e| e.kv.as_ref())
    }

    #[inline]
    fn seq_mut(&mut self, id: RequestId) -> Option<&mut SeqKv> {
        if !self.owns(id) {
            return None;
        }
        self.seqs
            .get_mut(rid_slot(id))
            .filter(|e| e.generation == rid_gen(id))
            .and_then(|e| e.kv.as_mut())
    }

    /// Free every block a stale entry still owns (defensive: callers are
    /// expected to `release` before a slot is recycled, but a leak here
    /// would silently shrink the pools for the rest of the run).
    fn purge_entry(gpu: &mut BlockPool, host: &mut BlockPool, kv: &mut SeqKv) {
        for slot in kv.gpu.iter_mut() {
            if let Some(b) = slot.take() {
                gpu.free(b);
            }
        }
        for c in kv.host.iter_mut() {
            if let BlockCkpt::Done(hb) | BlockCkpt::InFlight(hb) = *c {
                host.free(hb);
            }
            *c = BlockCkpt::None;
        }
        kv.resident = 0;
        kv.host_done = 0;
    }

    pub fn register(&mut self, id: RequestId) {
        assert!(
            self.owns(id),
            "registering id {id} from shard {} on shard {}",
            rid_shard(id),
            self.shard
        );
        let slot = rid_slot(id);
        let generation = rid_gen(id);
        if self.seqs.len() <= slot {
            self.seqs.resize_with(slot + 1, SeqEntry::default);
        }
        let entry = &mut self.seqs[slot];
        if entry.generation != generation {
            // recycled slot: drop whatever the stale occupant left behind
            if let Some(kv) = entry.kv.as_mut() {
                debug_assert!(
                    kv.resident == 0 && kv.host_done == 0,
                    "recycled slot {slot} still owns blocks"
                );
                Self::purge_entry(&mut self.gpu, &mut self.host, kv);
            }
            entry.generation = generation;
            entry.kv = Some(SeqKv::new());
        } else if entry.kv.is_none() {
            entry.kv = Some(SeqKv::new());
        }
    }

    /// GPU blocks that must be newly allocated for `id` to hold
    /// `new_total` committed tokens.
    pub fn blocks_needed(&self, id: RequestId, new_total: usize) -> usize {
        let have = self.seq(id).map(|s| s.gpu_blocks()).unwrap_or(0);
        new_total.div_ceil(self.block_tokens).saturating_sub(have)
    }

    /// Grow the GPU block table of `id` to cover `new_total` tokens.
    /// Fails atomically (no partial allocation) if the pool is short.
    pub fn grow(&mut self, id: RequestId, new_total: usize) -> Result<(), KvError> {
        let block_tokens = self.block_tokens;
        let gpu_avail = self.gpu.available();
        let seq = self.seq(id).ok_or(KvError::UnknownSeq(id))?;
        let needed_slots = new_total.div_ceil(block_tokens);
        // Fill gaps (evicted blocks being re-fetched keep their slot) and
        // extend; count first for atomicity.
        let mut need = 0;
        for i in 0..needed_slots {
            match seq.gpu.get(i) {
                Some(Some(_)) => {}
                _ => need += 1,
            }
        }
        if need > gpu_avail {
            return Err(KvError::OutOfGpu {
                need,
                free: gpu_avail,
            });
        }
        let slot = rid_slot(id);
        let entry = &mut self.seqs[slot];
        let seq = entry.kv.as_mut().unwrap();
        for i in 0..needed_slots {
            let missing = !matches!(seq.gpu.get(i), Some(Some(_)));
            if missing {
                let b = self.gpu.alloc().unwrap();
                if i < seq.gpu.len() {
                    seq.gpu[i] = Some(b);
                } else {
                    while seq.gpu.len() < i {
                        seq.gpu.push(None);
                    }
                    seq.gpu.push(Some(b));
                }
                seq.resident += 1;
            }
            if seq.host.len() <= i {
                seq.host.push(BlockCkpt::None);
            }
        }
        Ok(())
    }

    /// Commit `n` new tokens (caller already grew capacity). Newly
    /// *refilled* partial blocks invalidate their stale checkpoints:
    /// a block's host copy is only valid if taken when the block was full
    /// or the sequence stopped writing to it.
    pub fn commit(&mut self, id: RequestId, n: usize) -> Result<(), KvError> {
        if !self.owns(id) {
            return Err(KvError::UnknownSeq(id));
        }
        let bt = self.block_tokens;
        let slot = rid_slot(id);
        let entry = self
            .seqs
            .get_mut(slot)
            .filter(|e| e.generation == rid_gen(id))
            .ok_or(KvError::UnknownSeq(id))?;
        let seq = entry.kv.as_mut().ok_or(KvError::UnknownSeq(id))?;
        let first_dirty = seq.tokens / bt; // block receiving new tokens
        seq.tokens += n;
        debug_assert!(
            seq.tokens <= seq.gpu.len() * bt,
            "commit beyond allocated capacity"
        );
        let last_dirty = (seq.tokens - 1) / bt;
        for i in first_dirty..=last_dirty {
            if let Some(c) = seq.host.get_mut(i) {
                match *c {
                    BlockCkpt::Done(hb) => {
                        self.host.free(hb);
                        *c = BlockCkpt::None;
                        seq.host_done -= 1;
                    }
                    BlockCkpt::InFlight(hb) => {
                        self.host.free(hb);
                        *c = BlockCkpt::None;
                    }
                    BlockCkpt::None => {}
                }
            }
        }
        Ok(())
    }

    /// Logical blocks eligible for checkpointing: hold committed tokens,
    /// GPU-resident, no valid/in-flight host copy. A partial tail block
    /// is eligible too (the next commit invalidates it — §4.4 amortizes
    /// this as "checkpoint per generation iteration").
    pub fn checkpoint_candidates(&self, id: RequestId) -> Vec<usize> {
        let mut out = Vec::new();
        self.checkpoint_candidates_into(id, &mut out);
        out
    }

    /// Allocation-free variant: clears and refills `out`.
    pub fn checkpoint_candidates_into(&self, id: RequestId, out: &mut Vec<usize>) {
        out.clear();
        let Some(seq) = self.seq(id) else {
            return;
        };
        let used = seq.tokens.div_ceil(self.block_tokens);
        out.extend((0..used).filter(|&i| {
            matches!(seq.gpu.get(i), Some(Some(_)))
                && matches!(seq.host.get(i), Some(BlockCkpt::None))
        }));
    }

    /// Start a D2H checkpoint of logical block `idx`: allocates a host
    /// block and marks it in flight. Returns (gpu_block, host_block).
    pub fn begin_ckpt(
        &mut self,
        id: RequestId,
        idx: usize,
    ) -> Result<(BlockId, BlockId), KvError> {
        let hb = self.host.alloc().ok_or(KvError::OutOfHost)?;
        let Some(seq) = self.seq_mut(id) else {
            self.host.free(hb);
            return Err(KvError::UnknownSeq(id));
        };
        let gb = seq.gpu[idx].expect("checkpointing evicted block");
        debug_assert_eq!(seq.host[idx], BlockCkpt::None);
        seq.host[idx] = BlockCkpt::InFlight(hb);
        Ok((gb, hb))
    }

    /// D2H copy finished.
    pub fn finish_ckpt(&mut self, id: RequestId, idx: usize) {
        if let Some(seq) = self.seq_mut(id) {
            if let BlockCkpt::InFlight(hb) = seq.host[idx] {
                seq.host[idx] = BlockCkpt::Done(hb);
                seq.host_done += 1;
            }
        }
    }

    /// Evict all GPU blocks of `id` (host checkpoints retained). This is
    /// the O(µs) "discard + remap" release of §4.4 — legal only when the
    /// caller either has full checkpoints or accepts recompute. Returns
    /// the freed GPU block count.
    pub fn evict_gpu(&mut self, id: RequestId) -> usize {
        if !self.owns(id) {
            return 0;
        }
        let slot = rid_slot(id);
        let Some(entry) = self
            .seqs
            .get_mut(slot)
            .filter(|e| e.generation == rid_gen(id))
        else {
            return 0;
        };
        let Some(seq) = entry.kv.as_mut() else {
            return 0;
        };
        let mut n = 0;
        for s in seq.gpu.iter_mut() {
            if let Some(b) = s.take() {
                self.gpu.free(b);
                n += 1;
            }
        }
        seq.resident = 0;
        n
    }

    /// Drop everything (request finished/aborted or KV discarded).
    /// `keep_host=false` also releases checkpoints.
    pub fn release(&mut self, id: RequestId, keep_host: bool) {
        if !self.owns(id) {
            return;
        }
        let slot = rid_slot(id);
        let Some(entry) = self
            .seqs
            .get_mut(slot)
            .filter(|e| e.generation == rid_gen(id))
        else {
            return;
        };
        let Some(seq) = entry.kv.as_mut() else {
            return;
        };
        for s in seq.gpu.iter_mut() {
            if let Some(b) = s.take() {
                self.gpu.free(b);
            }
        }
        seq.resident = 0;
        if keep_host {
            // sequence dropped to host residence: keep the table so a
            // later prefetch can restore it
        } else {
            for c in seq.host.iter_mut() {
                if let BlockCkpt::Done(hb) | BlockCkpt::InFlight(hb) = *c {
                    self.host.free(hb);
                }
                *c = BlockCkpt::None;
            }
            seq.host_done = 0;
            entry.kv = None;
        }
    }

    /// Discard a sequence's KV entirely (recompute path): frees GPU and
    /// host blocks and resets committed tokens to zero, keeping the
    /// registration alive. Foreign-shard ids are a no-op like every
    /// other entry point (`register` alone asserts, so guard first).
    pub fn discard(&mut self, id: RequestId) {
        if !self.owns(id) {
            return;
        }
        self.release(id, false);
        self.register(id);
    }

    /// Blocks that must be prefetched (H2D) to resume `id`: logical
    /// indices with a host copy but no GPU copy, covering committed tokens.
    pub fn prefetch_candidates(&self, id: RequestId) -> Vec<(usize, BlockId)> {
        let mut out = Vec::new();
        self.prefetch_candidates_into(id, &mut out);
        out
    }

    /// Allocation-free variant: clears and refills `out`.
    pub fn prefetch_candidates_into(&self, id: RequestId, out: &mut Vec<(usize, BlockId)>) {
        out.clear();
        let Some(seq) = self.seq(id) else {
            return;
        };
        let used = seq.tokens.div_ceil(self.block_tokens);
        out.extend((0..used).filter_map(|i| {
            match (seq.gpu.get(i), seq.host.get(i)) {
                (Some(None), Some(BlockCkpt::Done(hb))) => Some((i, *hb)),
                _ => None,
            }
        }));
    }

    /// Count of blocks still missing on the GPU that have a host copy to
    /// restore from (the `prefetch_candidates` cardinality, without the
    /// allocation).
    pub fn missing_prefetch(&self, id: RequestId) -> usize {
        let Some(seq) = self.seq(id) else {
            return 0;
        };
        let used = seq.tokens.div_ceil(self.block_tokens);
        (0..used)
            .filter(|&i| {
                matches!(
                    (seq.gpu.get(i), seq.host.get(i)),
                    (Some(None), Some(BlockCkpt::Done(_)))
                )
            })
            .count()
    }

    /// Detach `id`'s KV accounting for cross-shard migration, freeing this
    /// shard's blocks. Returns the committed tokens covered by the
    /// detached host-checkpoint prefix (the count the importer must
    /// re-allocate), or 0 when the sequence held no state (never
    /// registered, or discarded — a cold steal).
    ///
    /// Fails with [`KvError::NotPortable`] unless the sequence is in the
    /// free-to-move state of §4.4: no GPU-resident blocks, no checkpoint
    /// in flight, and every committed token covered by a completed host
    /// checkpoint — the caller must evict (or discard) first. The block
    /// *data* is the backend's concern
    /// ([`ExecBackend::export_host_kv`](crate::backend::ExecBackend::export_host_kv));
    /// this is the page-table half of the handoff.
    pub fn export_host(&mut self, id: RequestId) -> Result<usize, KvError> {
        if !self.owns(id) {
            return Err(KvError::UnknownSeq(id));
        }
        let slot = rid_slot(id);
        let Some(entry) = self
            .seqs
            .get_mut(slot)
            .filter(|e| e.generation == rid_gen(id))
        else {
            return Ok(0); // never registered: nothing to detach
        };
        let Some(seq) = entry.kv.as_mut() else {
            return Ok(0);
        };
        let bt = self.block_tokens;
        let in_flight = seq
            .host
            .iter()
            .any(|c| matches!(c, BlockCkpt::InFlight(_)));
        if seq.resident != 0 || in_flight || !seq.fully_checkpointed(bt) {
            return Err(KvError::NotPortable(id));
        }
        let tokens = seq.tokens;
        for c in seq.host.iter_mut() {
            if let BlockCkpt::Done(hb) = *c {
                self.host.free(hb);
            }
            *c = BlockCkpt::None;
        }
        seq.host_done = 0;
        entry.kv = None;
        Ok(tokens)
    }

    /// Adopt a migrated checkpoint prefix on this shard: registers `id`
    /// and allocates host blocks (marked `Done`) covering `tokens`
    /// committed tokens, so resume is a plain prefetch. Fails atomically
    /// with [`KvError::OutOfHost`] when the pool cannot hold the prefix
    /// (the request stays registered with no KV — the recompute path).
    pub fn import_host(&mut self, id: RequestId, tokens: usize) -> Result<(), KvError> {
        self.register(id);
        if tokens == 0 {
            return Ok(());
        }
        let blocks = tokens.div_ceil(self.block_tokens);
        if self.host.available() < blocks {
            return Err(KvError::OutOfHost);
        }
        let seq = self.seqs[rid_slot(id)].kv.as_mut().unwrap();
        debug_assert!(
            seq.tokens == 0 && seq.gpu.is_empty(),
            "importing over live KV state"
        );
        for _ in 0..blocks {
            let hb = self.host.alloc().unwrap();
            seq.gpu.push(None);
            seq.host.push(BlockCkpt::Done(hb));
        }
        seq.tokens = tokens;
        seq.host_done = blocks;
        Ok(())
    }

    /// Allocate a GPU block for a prefetched logical block and return it.
    pub fn begin_prefetch(&mut self, id: RequestId, idx: usize) -> Result<BlockId, KvError> {
        let gb = self.gpu.alloc().ok_or(KvError::OutOfGpu { need: 1, free: 0 })?;
        let Some(seq) = self.seq_mut(id) else {
            self.gpu.free(gb);
            return Err(KvError::UnknownSeq(id));
        };
        debug_assert!(seq.gpu[idx].is_none());
        seq.gpu[idx] = Some(gb);
        seq.resident += 1;
        Ok(gb)
    }

    /// Invariant check used by property tests: every block is either free
    /// or owned by exactly one sequence slot, and the O(1) counters agree
    /// with the block tables they summarize.
    pub fn check_conservation(&self) -> bool {
        let mut gpu_owned = 0usize;
        let mut host_owned = 0usize;
        let mut seen_gpu = std::collections::HashSet::new();
        let mut seen_host = std::collections::HashSet::new();
        for seq in self.seqs.iter().filter_map(|e| e.kv.as_ref()) {
            let mut resident = 0;
            for b in seq.gpu.iter().flatten() {
                if !seen_gpu.insert(*b) {
                    return false; // double ownership
                }
                gpu_owned += 1;
                resident += 1;
            }
            if resident != seq.resident {
                return false; // counter drift
            }
            let mut done = 0;
            for c in &seq.host {
                if let BlockCkpt::Done(hb) | BlockCkpt::InFlight(hb) = c {
                    if !seen_host.insert(*hb) {
                        return false;
                    }
                    host_owned += 1;
                }
                if matches!(c, BlockCkpt::Done(_)) {
                    done += 1;
                }
            }
            if done != seq.host_done {
                return false;
            }
        }
        gpu_owned + self.gpu.available() == self.gpu.total()
            && host_owned + self.host.available() == self.host.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> KvManager {
        KvManager::new(8, 16, 16)
    }

    #[test]
    fn grow_and_commit() {
        let mut m = mgr();
        m.register(1);
        assert_eq!(m.blocks_needed(1, 17), 2);
        m.grow(1, 17).unwrap();
        m.commit(1, 17).unwrap();
        assert_eq!(m.seq(1).unwrap().tokens, 17);
        assert_eq!(m.gpu_free(), 6);
        assert_eq!(m.blocks_needed(1, 32), 0);
        assert_eq!(m.blocks_needed(1, 33), 1);
        assert!(m.check_conservation());
    }

    #[test]
    fn grow_fails_atomically() {
        let mut m = mgr();
        m.register(1);
        let err = m.grow(1, 16 * 9).unwrap_err();
        assert_eq!(err, KvError::OutOfGpu { need: 9, free: 8 });
        assert_eq!(m.gpu_free(), 8); // nothing leaked
        assert!(m.check_conservation());
    }

    #[test]
    fn checkpoint_lifecycle() {
        let mut m = mgr();
        m.register(1);
        m.grow(1, 40).unwrap();
        m.commit(1, 40).unwrap();
        // blocks 0,1 full; block 2 partial (8 tokens) — all candidates
        assert_eq!(m.checkpoint_candidates(1), vec![0, 1, 2]);
        let (_gb, _hb) = m.begin_ckpt(1, 0).unwrap();
        assert_eq!(m.checkpoint_candidates(1), vec![1, 2]);
        m.finish_ckpt(1, 0);
        assert_eq!(m.seq(1).unwrap().ckpt_tokens(16), 16);
        m.begin_ckpt(1, 1).unwrap();
        m.finish_ckpt(1, 1);
        m.begin_ckpt(1, 2).unwrap();
        m.finish_ckpt(1, 2);
        assert!(m.seq(1).unwrap().fully_checkpointed(16));
        assert!(m.check_conservation());
    }

    #[test]
    fn commit_invalidates_partial_block_ckpt() {
        let mut m = mgr();
        m.register(1);
        m.grow(1, 8).unwrap();
        m.commit(1, 8).unwrap();
        m.begin_ckpt(1, 0).unwrap();
        m.finish_ckpt(1, 0);
        assert!(m.seq(1).unwrap().fully_checkpointed(16));
        let host_free = m.host_free();
        // writing more tokens into block 0 invalidates its checkpoint
        m.grow(1, 12).unwrap();
        m.commit(1, 4).unwrap();
        assert!(!m.seq(1).unwrap().fully_checkpointed(16));
        assert_eq!(m.host_free(), host_free + 1); // stale copy freed
        assert_eq!(m.checkpoint_candidates(1), vec![0]);
        assert!(m.check_conservation());
    }

    #[test]
    fn evict_and_prefetch_roundtrip() {
        let mut m = mgr();
        m.register(1);
        m.grow(1, 32).unwrap();
        m.commit(1, 32).unwrap();
        for i in m.checkpoint_candidates(1) {
            m.begin_ckpt(1, i).unwrap();
            m.finish_ckpt(1, i);
        }
        let freed = m.evict_gpu(1);
        assert_eq!(freed, 2);
        assert_eq!(m.gpu_free(), 8);
        // tokens survive; prefetch restores
        assert_eq!(m.seq(1).unwrap().tokens, 32);
        let cands = m.prefetch_candidates(1);
        assert_eq!(cands.len(), 2);
        assert_eq!(m.missing_prefetch(1), 2);
        for (i, _hb) in cands {
            m.begin_prefetch(1, i).unwrap();
        }
        assert_eq!(m.seq(1).unwrap().gpu_blocks(), 2);
        assert_eq!(m.missing_prefetch(1), 0);
        assert!(m.check_conservation());
    }

    #[test]
    fn discard_resets() {
        let mut m = mgr();
        m.register(1);
        m.grow(1, 32).unwrap();
        m.commit(1, 32).unwrap();
        m.discard(1);
        assert_eq!(m.gpu_free(), 8);
        assert_eq!(m.seq(1).unwrap().tokens, 0);
        assert!(m.check_conservation());
    }

    #[test]
    fn release_keep_host_preserves_ckpts() {
        let mut m = mgr();
        m.register(1);
        m.grow(1, 16).unwrap();
        m.commit(1, 16).unwrap();
        m.begin_ckpt(1, 0).unwrap();
        m.finish_ckpt(1, 0);
        m.release(1, true);
        assert_eq!(m.gpu_free(), 8);
        assert_eq!(m.prefetch_candidates(1).len(), 1);
        m.release(1, false);
        assert_eq!(m.host_free(), 16);
        assert!(m.check_conservation());
    }

    #[test]
    fn foreign_shard_ids_never_alias() {
        use crate::request::rid_pack_sharded;
        let mut a = KvManager::for_shard(1, 8, 16, 16);
        let mut b = KvManager::for_shard(2, 8, 16, 16);
        assert_eq!(a.shard(), 1);
        // same (slot, generation) registered in both shards
        let ida = rid_pack_sharded(1, 3, 0);
        let idb = rid_pack_sharded(2, 3, 0);
        a.register(ida);
        a.grow(ida, 32).unwrap();
        a.commit(ida, 32).unwrap();
        b.register(idb);
        // shard B's id misses shard A entirely (and vice versa)
        assert!(a.seq(idb).is_none());
        assert!(b.seq(ida).is_none());
        assert_eq!(a.grow(idb, 16), Err(KvError::UnknownSeq(idb)));
        assert_eq!(b.commit(ida, 1), Err(KvError::UnknownSeq(ida)));
        assert_eq!(a.evict_gpu(idb), 0);
        b.release(ida, false); // no-op
        b.discard(ida); // no-op, not a panic
        assert_eq!(a.seq(ida).unwrap().tokens, 32);
        assert!(a.check_conservation() && b.check_conservation());
    }

    #[test]
    fn export_import_moves_checkpoint_between_shards() {
        use crate::request::rid_pack_sharded;
        let mut donor = KvManager::for_shard(1, 8, 16, 16);
        let mut target = KvManager::for_shard(2, 8, 16, 16);
        let did = rid_pack_sharded(1, 3, 0);
        donor.register(did);
        donor.grow(did, 40).unwrap();
        donor.commit(did, 40).unwrap();
        // not portable while GPU-resident / partially checkpointed
        assert_eq!(donor.export_host(did), Err(KvError::NotPortable(did)));
        for i in donor.checkpoint_candidates(did) {
            donor.begin_ckpt(did, i).unwrap();
            donor.finish_ckpt(did, i);
        }
        assert_eq!(donor.export_host(did), Err(KvError::NotPortable(did)));
        donor.evict_gpu(did);
        let tokens = donor.export_host(did).unwrap();
        assert_eq!(tokens, 40);
        // donor fully clean: no leaked blocks, no resolvable sequence
        assert_eq!(donor.gpu_free(), 8);
        assert_eq!(donor.host_free(), 16);
        assert!(donor.seq(did).is_none());
        assert!(donor.check_conservation());

        let tid = rid_pack_sharded(2, 5, 0);
        target.import_host(tid, tokens).unwrap();
        let seq = target.seq(tid).unwrap();
        assert_eq!(seq.tokens, 40);
        assert!(seq.fully_checkpointed(16));
        assert_eq!(seq.gpu_blocks(), 0);
        assert_eq!(target.host_free(), 16 - 3);
        // resume is a plain prefetch of the imported blocks
        assert_eq!(target.prefetch_candidates(tid).len(), 3);
        for (i, _hb) in target.prefetch_candidates(tid) {
            target.begin_prefetch(tid, i).unwrap();
        }
        assert_eq!(target.seq(tid).unwrap().gpu_blocks(), 3);
        assert!(target.check_conservation());
        target.release(tid, false);
        assert!(target.check_conservation());
    }

    #[test]
    fn export_host_of_empty_state_is_a_cold_steal() {
        let mut m = mgr();
        // never registered: nothing to detach, not an error
        assert_eq!(m.export_host(1), Ok(0));
        // discarded (registered, zero tokens): also cold
        m.register(2);
        m.grow(2, 20).unwrap();
        m.commit(2, 20).unwrap();
        m.discard(2);
        assert_eq!(m.export_host(2), Ok(0));
        assert!(m.seq(2).is_none(), "export drops the registration");
        assert!(m.check_conservation());
        // foreign ids still bounce
        use crate::request::rid_pack_sharded;
        let foreign = rid_pack_sharded(3, 1, 0);
        assert_eq!(m.export_host(foreign), Err(KvError::UnknownSeq(foreign)));
    }

    #[test]
    fn import_host_fails_atomically_when_pool_short() {
        let mut m = KvManager::new(8, 2, 16);
        assert_eq!(m.import_host(1, 3 * 16), Err(KvError::OutOfHost));
        assert_eq!(m.host_free(), 2, "failed import must not leak");
        // the registration survives for the recompute fallback
        assert!(m.seq(1).is_some());
        assert_eq!(m.seq(1).unwrap().tokens, 0);
        assert!(m.check_conservation());
    }

    #[test]
    fn stale_generation_never_aliases() {
        use crate::request::rid_pack;
        let mut m = mgr();
        let old = rid_pack(1, 0);
        m.register(old);
        m.grow(old, 16).unwrap();
        m.commit(old, 16).unwrap();
        m.release(old, false);
        // slot 1 recycled under generation 1
        let new = rid_pack(1, 1);
        m.register(new);
        m.grow(new, 32).unwrap();
        m.commit(new, 32).unwrap();
        // the stale id must not see (or mutate) the new occupant
        assert!(m.seq(old).is_none());
        assert_eq!(m.grow(old, 64), Err(KvError::UnknownSeq(old)));
        assert_eq!(m.evict_gpu(old), 0);
        assert_eq!(m.seq(new).unwrap().tokens, 32);
        assert!(m.check_conservation());
    }
}
