//! Workload synthesis: the gamma-process load generator (paper §5 "a
//! built-in load generator that can generate precisely timed requests
//! following the gamma distribution"), BurstGPT-like traces (Fig. 1),
//! ON/OFF phased loads (§6.3.1), and request-length datasets.

pub mod datasets;
pub mod jobs;
pub mod loadgen;
pub mod trace;

pub use datasets::{LengthSample, Lengths};
pub use jobs::{job_trace, JobTraceConfig};
pub use loadgen::LoadGen;
pub use trace::{
    burstgpt_like_rate, chat_trace, flash_crowd_trace, onoff_trace, square_wave_trace,
    ChatTraceConfig, TraceEvent,
};
