//! Execution backends: layer-group-stepped model execution with
//! preemption **safepoints** between groups (paper §4.3).
//!
//! The serving engine is generic over [`ExecBackend`]:
//!
//! * `PjrtBackend` (cargo feature `pjrt`) — the real path: AOT HLO
//!   artifacts executed through the PJRT CPU client; per-layer
//!   executables give natural safepoints.
//! * [`SimBackend`] — a discrete-event model of the paper's testbed
//!   (A100-40G, Llama-2-7B) driven by [`costmodel::CostModel`]; advances
//!   a virtual clock instead of computing.
//!
//! A safepoint callback runs between layer groups of *preemptible* (pure
//! offline, §4.3) iterations; returning [`SafepointAction::Abort`]
//! models the worker observing the preemption flag: remaining layers are
//! skipped, partial results discarded, and nothing is committed.

pub mod costmodel;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod sim;

use crate::request::{Class, Phase, RequestId, TokenId};
use crate::TimeUs;

pub use costmodel::CostModel;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use sim::SimBackend;

/// One request's work within an iteration.
///
/// Token data does not live here: each item addresses a range of the
/// owning plan's shared [`IterationPlan::staging`] buffer (empty in pure
/// simulation), so building a plan never allocates per item — the
/// staging vector is reused across iterations like every other scheduler
/// scratch buffer.
#[derive(Debug, Clone, Copy)]
pub struct WorkItem {
    pub req: RequestId,
    pub class: Class,
    pub phase: Phase,
    /// Committed context length before this iteration.
    pub ctx_len: usize,
    /// New tokens computed this iteration (prefill chunk size, or 1).
    pub n_tokens: usize,
    /// Start of this item's token chunk in [`IterationPlan::staging`].
    pub tok_start: u32,
    /// Length of this item's token chunk (0 when the request carries no
    /// token data — the whole simulator path).
    pub tok_len: u32,
    /// Per-request draw key for the token this item may sample
    /// (`mix64(sampler_state ^ generated)`): the same request position
    /// samples the same token on any shard, any chunking.
    pub sample_key: u64,
}

/// An iteration of continuous batching handed to the backend.
#[derive(Debug, Clone, Default)]
pub struct IterationPlan {
    pub items: Vec<WorkItem>,
    /// Concrete token ids for all items, one contiguous chunk per item
    /// (real path; empty in sim). Indexed via each item's
    /// `tok_start..tok_start + tok_len` — see [`IterationPlan::tokens_of`].
    pub staging: Vec<TokenId>,
    /// Safepoints active: true only for pure-offline batches (§4.3
    /// "restrict layer-wise preemption to the offline batching mode").
    pub preemptible: bool,
}

impl IterationPlan {
    /// Reset for the next iteration, keeping `items` and `staging`
    /// capacity.
    pub fn clear(&mut self) {
        self.items.clear();
        self.staging.clear();
        self.preemptible = false;
    }

    /// The staged token chunk of `item` (empty when the request carries
    /// no token data).
    pub fn tokens_of(&self, item: &WorkItem) -> &[TokenId] {
        let start = item.tok_start as usize;
        &self.staging[start..start + item.tok_len as usize]
    }

    /// Append an item with explicit token data (tests, benches, and the
    /// profiler's probe plans; the scheduler stages tokens inline). The
    /// sample key is derived from `(req, ctx_len)` so temperature
    /// sampling still draws a distinct quantile per position — the
    /// scheduler path keys by per-request sampler state instead.
    pub fn push_item(
        &mut self,
        req: RequestId,
        class: Class,
        phase: Phase,
        ctx_len: usize,
        n_tokens: usize,
        tokens: &[TokenId],
    ) {
        let tok_start = self.staging.len() as u32;
        self.staging.extend_from_slice(tokens);
        self.items.push(WorkItem {
            req,
            class,
            phase,
            ctx_len,
            n_tokens,
            tok_start,
            tok_len: tokens.len() as u32,
            sample_key: crate::util::rng::mix64(req ^ ctx_len as u64),
        });
    }
    pub fn prefill_tokens(&self) -> usize {
        self.items
            .iter()
            .filter(|i| i.phase == Phase::Prefill)
            .map(|i| i.n_tokens)
            .sum()
    }

    pub fn decode_seqs(&self) -> usize {
        self.items
            .iter()
            .filter(|i| i.phase == Phase::Decode)
            .count()
    }

    pub fn total_new_tokens(&self) -> usize {
        self.items.iter().map(|i| i.n_tokens).sum()
    }

    /// Context tokens whose KV is re-read by attention this iteration.
    pub fn ctx_tokens(&self) -> usize {
        self.items.iter().map(|i| i.ctx_len).sum()
    }

    /// Shape summary in a single pass over the items (computed at least
    /// twice per engine iteration — estimate + execute).
    pub fn summary(&self) -> PlanSummary {
        let mut s = PlanSummary {
            n_seqs: self.items.len(),
            ..PlanSummary::default()
        };
        for i in &self.items {
            match i.phase {
                Phase::Prefill => s.prefill_tokens += i.n_tokens,
                Phase::Decode => s.decode_seqs += 1,
            }
            s.ctx_tokens += i.ctx_len;
        }
        s
    }
}

/// Shape-only view of a plan (profiler estimation input, §4.5).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PlanSummary {
    pub prefill_tokens: usize,
    pub decode_seqs: usize,
    /// Total committed context across items (KV re-read volume).
    pub ctx_tokens: usize,
    pub n_seqs: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SafepointAction {
    Continue,
    /// Abort remaining layers; discard partial work (worker preemption).
    Abort,
}

/// A request's host-resident KV data detached from one backend's mirror
/// store, ready to hand to another backend — the data half of a
/// cross-shard checkpoint migration (the accounting half is
/// [`KvManager::export_host`](crate::kvcache::KvManager::export_host) /
/// `import_host`). Per-layer K and V slabs, exactly as the real
/// backend's host mirror stores them; the simulator moves no data and
/// never produces one.
#[derive(Debug, Clone, Default)]
pub struct HostKvBlob {
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

#[derive(Debug)]
pub struct ExecOutcome {
    /// False if the iteration was aborted at a safepoint.
    pub completed: bool,
    /// Per item (plan order): sampled next token for items that finished
    /// a phase step. The simulator returns an *empty* vec (it samples
    /// nothing) so the steady-state loop allocates nothing; consumers
    /// index with `.get(i)`.
    pub new_tokens: Vec<Option<TokenId>>,
    pub elapsed_us: u64,
    /// Safepoint checks performed (for §6.4.2 accounting).
    pub safepoint_checks: usize,
}

pub trait ExecBackend {
    /// Execute one iteration. `safepoint` is invoked between layer
    /// groups when `plan.preemptible`; it receives the current time.
    fn execute(
        &mut self,
        plan: &IterationPlan,
        safepoint: &mut dyn FnMut(TimeUs) -> SafepointAction,
    ) -> anyhow::Result<ExecOutcome>;

    /// Ground-truth iteration time for a hypothetical plan shape, used to
    /// build the offline profile (§4.5). The simulator answers from its
    /// cost model; the real backend measures probe executions.
    fn probe_us(&mut self, summary: &PlanSummary) -> u64;

    /// Forget a request's device state (discard preemption / finish).
    fn drop_request(&mut self, req: RequestId);

    /// Drop only the *device* copy of a request's KV (checkpoint-backed
    /// eviction, §4.4): host mirrors survive for later prefetch.
    fn evict_device(&mut self, _req: RequestId) {}

    /// Copy one KV block D2H (checkpoint commit). Real backend memcpys
    /// slab -> host mirror; sim is accounting-only.
    fn copy_block_d2h(&mut self, req: RequestId, block_idx: usize, block_tokens: usize);

    /// Copy one KV block H2D (prefetch commit).
    fn copy_block_h2d(&mut self, req: RequestId, block_idx: usize, block_tokens: usize);

    /// Detach `req`'s host KV mirror for cross-shard migration (the
    /// donor half of a steal). Default: `None` — the simulator's
    /// checkpoints are accounting-only, so there is nothing to move.
    fn export_host_kv(&mut self, _req: RequestId) -> Option<HostKvBlob> {
        None
    }

    /// Install a migrated host KV mirror under `req` (the target half of
    /// a steal); a later prefetch restores it to the device copy.
    /// Default: drop it (simulator).
    fn import_host_kv(&mut self, _req: RequestId, _blob: HostKvBlob) {}

    /// KV bytes per block (drives the swap engine).
    fn block_bytes(&self) -> u64;

    /// Host<->device link bandwidth in bytes/s.
    fn link_bandwidth(&self) -> u64;

    /// Safepoint synchronization cost in µs (§6.4.2: 988 µs measured).
    fn safepoint_cost_us(&self) -> u64;

    /// Layer groups per iteration (n_layers / safepoint_layers).
    fn n_layer_groups(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_summary_counts() {
        let mut plan = IterationPlan::default();
        plan.push_item(1, Class::Online, Phase::Prefill, 0, 512, &[]);
        plan.push_item(2, Class::Offline, Phase::Decode, 1024, 1, &[]);
        let s = plan.summary();
        assert_eq!(s.prefill_tokens, 512);
        assert_eq!(s.decode_seqs, 1);
        assert_eq!(s.ctx_tokens, 1024);
        assert_eq!(plan.total_new_tokens(), 513);
    }

    #[test]
    fn staging_buffer_addresses_per_item_chunks() {
        let mut plan = IterationPlan::default();
        plan.push_item(1, Class::Online, Phase::Prefill, 0, 3, &[10, 11, 12]);
        plan.push_item(2, Class::Offline, Phase::Decode, 8, 1, &[7]);
        plan.push_item(3, Class::Offline, Phase::Decode, 8, 1, &[]); // sim item
        assert_eq!(plan.tokens_of(&plan.items[0]), &[10, 11, 12]);
        assert_eq!(plan.tokens_of(&plan.items[1]), &[7]);
        assert!(plan.tokens_of(&plan.items[2]).is_empty());
        assert_eq!(plan.staging.len(), 4);
        let cap = plan.staging.capacity();
        plan.clear();
        assert!(plan.items.is_empty() && plan.staging.is_empty());
        assert_eq!(plan.staging.capacity(), cap, "clear keeps capacity");
    }
}
