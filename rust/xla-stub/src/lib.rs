//! Type-level stub of the `xla` crate (the PJRT CPU-client bindings the
//! real serving path uses).
//!
//! The CI image does not vendor the native `xla_extension` toolchain, so
//! this crate mirrors exactly the API surface `conserve`'s `pjrt`
//! feature touches — enough for `cargo check --features pjrt` to
//! type-check every gated module, test, and example. Every entry point
//! returns [`Error`] (or panics where the signature has no `Result`), so
//! accidentally *running* against the stub fails loudly and immediately.
//!
//! For the real path, point the `xla` dependency in `rust/Cargo.toml` at
//! the actual bindings instead of this stub:
//!
//! ```toml
//! xla = { git = "https://github.com/LaurentMazare/xla-rs" }
//! ```

use std::path::Path;

/// Stub error: every operation yields it.
#[derive(Debug, Clone)]
pub struct Error;

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("xla stub: link the real xla crate (see rust/Cargo.toml)")
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes `conserve` materializes literals for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host-side tensor value.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(Error)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error)
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error)
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        Err(Error)
    }
}

/// Device-resident buffer returned by executions.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error)
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error)
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error)
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error)
    }
}

/// Computation wrapper accepted by [`PjRtClient::compile`].
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_loudly() {
        assert!(PjRtClient::cpu().is_err());
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4]);
        assert!(lit.is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo").is_err());
        let e = Error.to_string();
        assert!(e.contains("xla stub"));
    }
}
