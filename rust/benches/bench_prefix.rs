//! `bench_prefix` — cross-request prefix KV sharing acceptance bench.
//!
//! Drives one multi-turn chat trace (shared system prompt + growing
//! per-session histories, real token vectors) through a 2-shard fleet
//! three ways at equal load:
//!
//! * `off`      — prefix cache off, prefix-affinity placement;
//! * `on`       — prefix cache on, prefix-affinity placement (same
//!                routing as `off`, so the only delta is sharing);
//! * `on_rr`    — prefix cache on, round-robin placement (what sharing
//!                is worth when the router ignores prefix residency).
//!
//! Acceptance (asserted here):
//!
//! * **prefill cut** — `on` skips prefill for a positive number of
//!   prompt tokens (`prefill_tokens_skipped > 0`) while `off` skips
//!   none;
//! * **TTFT win** — `on` mean online TTFT < `off` mean online TTFT,
//!   and the TTFT-violation rate does not regress;
//! * **correctness** — completed token streams are byte-identical
//!   between `on` and `off` (same finished set, same outputs);
//! * **placement** — prefix-affinity beats round-robin on token hit
//!   rate (`prefill_tokens_skipped / total_prompt_tokens`).
//!
//! Results go to `BENCH_prefix.json` (schema: rust/PERF.md §10).
//! Scale with `PREFIX_BENCH_SESSIONS` (chat sessions, default 32).

use std::collections::BTreeMap;

use conserve::config::EngineConfig;
use conserve::report::Report;
use conserve::request::{State, TokenId};
use conserve::shard::{run_sharded_traces_with, Placement, ShardRouter};
use conserve::util::json::{arr, num, obj, Json};
use conserve::workload::{chat_trace, ChatTraceConfig};

const SHARDS: usize = 2;
const SPAN_S: f64 = 60.0;
/// Serve window: span plus drain slack so every turn finishes and the
/// on/off completed sets are comparable.
const DURATION_S: f64 = 90.0;

fn trace_cfg(sessions: usize) -> ChatTraceConfig {
    ChatTraceConfig {
        sessions,
        turns: 6,
        span_s: SPAN_S,
        ..ChatTraceConfig::default()
    }
}

/// One measured run: route the shared trace under `placement`, serve it
/// with the prefix cache on or off, and keep every finished request's
/// output stream for the byte-identity check.
struct Point {
    label: String,
    report: Report,
    hit_rate: f64,
    outputs: BTreeMap<u64, Vec<TokenId>>,
}

fn run_point(
    label: &str,
    sessions: usize,
    prefix_on: bool,
    placement: Placement,
) -> Point {
    let mut cfg = EngineConfig::sim_a100_7b();
    cfg.sched.prefix_cache = prefix_on;
    let trace = chat_trace(&trace_cfg(sessions));
    let total_prompt_tokens: usize = trace.iter().map(|r| r.prompt_len).sum();
    let mut router = ShardRouter::new(SHARDS, placement, &cfg);
    for r in trace {
        router.push(r);
    }
    let (run, outputs) = run_sharded_traces_with(
        &cfg,
        router.into_traces(),
        DURATION_S,
        None,
        |e| {
            e.set_retain_finished(true);
            e.backend.set_synth_tokens(true);
        },
        |e| {
            e.table
                .values()
                .filter(|r| r.state == State::Finished)
                .map(|r| (r.submitted_id, r.output.clone()))
                .collect::<Vec<_>>()
        },
    );
    let outputs: BTreeMap<u64, Vec<TokenId>> = outputs.into_iter().flatten().collect();
    let report = run.merged;
    let hit_rate = report.prefill_tokens_skipped as f64 / total_prompt_tokens.max(1) as f64;
    Point {
        label: label.to_string(),
        report,
        hit_rate,
        outputs,
    }
}

fn point_json(p: &Point) -> Json {
    obj(vec![
        ("label", Json::Str(p.label.clone())),
        ("online_mean_ttft_ms", num(p.report.online_mean_ttft_ms)),
        ("online_p99_ttft_ms", num(p.report.online_p99_ttft_ms)),
        ("ttft_violation_rate", num(p.report.ttft_violations)),
        ("online_finished", num(p.report.online_finished as f64)),
        ("prefix_hits", num(p.report.prefix_hits as f64)),
        (
            "prefill_tokens_skipped",
            num(p.report.prefill_tokens_skipped as f64),
        ),
        (
            "shared_block_residency",
            num(p.report.shared_block_residency as f64),
        ),
        ("token_hit_rate", num(p.hit_rate)),
    ])
}

fn main() {
    let sessions: usize = std::env::var("PREFIX_BENCH_SESSIONS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);
    println!(
        "=== bench_prefix ({sessions} chat sessions x {} turns over {SPAN_S:.0}s, \
         {SHARDS} shards) ===",
        trace_cfg(sessions).turns
    );

    let off = run_point("off", sessions, false, Placement::prefix_affinity());
    let on = run_point("on", sessions, true, Placement::prefix_affinity());
    let on_rr = run_point("on_rr", sessions, true, Placement::RoundRobin);
    for p in [&off, &on, &on_rr] {
        println!(
            "{:>6}: mean TTFT {:.1} ms, violations {:.4}, hits {}, skipped {} tok \
             (hit rate {:.3}), shared peak {}",
            p.label,
            p.report.online_mean_ttft_ms,
            p.report.ttft_violations,
            p.report.prefix_hits,
            p.report.prefill_tokens_skipped,
            p.hit_rate,
            p.report.shared_block_residency
        );
    }

    // ---- acceptance ----
    assert_eq!(
        off.report.prefill_tokens_skipped, 0,
        "sharing off must skip nothing"
    );
    assert!(
        on.report.prefix_hits > 0 && on.report.prefill_tokens_skipped > 0,
        "sharing on must attach shared blocks on this trace"
    );
    assert!(
        on.report.online_mean_ttft_ms < off.report.online_mean_ttft_ms,
        "sharing must cut mean TTFT at equal load: on {:.2} ms vs off {:.2} ms",
        on.report.online_mean_ttft_ms,
        off.report.online_mean_ttft_ms
    );
    assert!(
        on.report.ttft_violations <= off.report.ttft_violations,
        "sharing must not add TTFT violations: on {:.4} vs off {:.4}",
        on.report.ttft_violations,
        off.report.ttft_violations
    );
    assert_eq!(
        on.outputs.len(),
        off.outputs.len(),
        "on/off must complete the same number of requests"
    );
    assert!(
        on.outputs == off.outputs,
        "completed token streams must be byte-identical with sharing on"
    );
    assert!(
        on.hit_rate > on_rr.hit_rate,
        "prefix-affinity must beat round-robin on token hit rate: \
         {:.4} vs {:.4}",
        on.hit_rate,
        on_rr.hit_rate
    );

    // ---- emit BENCH_prefix.json (schema: rust/PERF.md §10) ----
    let json = obj(vec![
        ("sessions", num(sessions as f64)),
        ("turns", num(trace_cfg(sessions).turns as f64)),
        ("span_s", num(SPAN_S)),
        ("shards", num(SHARDS as f64)),
        ("points", arr([&off, &on, &on_rr].into_iter().map(point_json))),
        ("mean_ttft_off_ms", num(off.report.online_mean_ttft_ms)),
        ("mean_ttft_on_ms", num(on.report.online_mean_ttft_ms)),
        (
            "ttft_improvement",
            num(1.0 - on.report.online_mean_ttft_ms / off.report.online_mean_ttft_ms.max(1e-9)),
        ),
        ("affinity_hit_rate", num(on.hit_rate)),
        ("rr_hit_rate", num(on_rr.hit_rate)),
        (
            "streams_identical",
            num(f64::from(u8::from(on.outputs == off.outputs))),
        ),
    ]);
    let out_path =
        std::env::var("PREFIX_BENCH_OUT").unwrap_or_else(|_| "BENCH_prefix.json".into());
    std::fs::write(&out_path, json.to_string()).expect("write BENCH_prefix.json");
    println!("\nwrote {out_path}");
    let _ = Json::parse(&json.to_string()).expect("self-emitted json parses");
    println!("bench_prefix OK");
}
