"""L2: the JAX model — a Llama-architecture transformer, exported *layered*.

The Rust coordinator executes the model as a sequence of HLO executables:

    embed  -> layer_fwd (x n_layers, one call per layer) -> lm_head

so that a preemption *safepoint* exists between every layer(-group) call —
the mechanism ConServe's preemptible worker (§4.3) uses to abort a running
offline batch with layer granularity. A monolithic `model_full` entry is
also exported so the safepoint overhead can be measured (§6.4.2 bench).

Semantics shared by every entry point:
  * Each sequence owns a dense KV cache slab of `max_seq` slots per layer.
  * `ctx_lens[b]` = number of tokens already in the cache for row b. The T
    incoming tokens occupy absolute positions ctx_lens[b] .. ctx_lens[b]+T-1
    and their K/V are written into those cache slots.
  * Chunked prefill = repeated layer_fwd calls with T-token chunks; decode
    is the T=1 bucket. Rows padded for bucketing write garbage into slots
    the *next* chunk overwrites and never attend beyond the causal
    frontier, so padding is harmless (tested in tests/test_model.py).

Attention + RMSNorm are the L1 Pallas kernels (kernels/), so they lower
into the same HLO module.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.attention import attention
from .kernels.rmsnorm import rmsnorm


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding, Llama half-split convention.

    x: [B, T, H, Dh], positions: [B, T]."""
    half = x.shape[-1] // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def update_cache(cache: jax.Array, new: jax.Array, ctx_lens: jax.Array) -> jax.Array:
    """Write `new` [B, Hkv, T, Dh] into `cache` [B, Hkv, S, Dh] at per-row
    slot offsets ctx_lens [B] (vmapped dynamic_update_slice)."""

    def row(c, n, off):
        return jax.lax.dynamic_update_slice(c, n, (0, off, 0))

    return jax.vmap(row)(cache, new, ctx_lens)


def embed(tokens: jax.Array, embedding: jax.Array) -> jax.Array:
    """tokens [B, T] i32 -> hidden [B, T, D]."""
    return embedding[tokens]


def layer_fwd(
    cfg: ModelConfig,
    hidden: jax.Array,     # [B, T, D]
    k_cache: jax.Array,    # [B, Hkv, S, Dh]
    v_cache: jax.Array,    # [B, Hkv, S, Dh]
    ctx_lens: jax.Array,   # [B] i32
    attn_norm: jax.Array,
    wq: jax.Array,
    wk: jax.Array,
    wv: jax.Array,
    wo: jax.Array,
    mlp_norm: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
):
    """One transformer layer; returns (hidden, k_cache, v_cache).

    Weights are runtime arguments (not baked constants) so a single
    compiled executable serves every layer — and, per the paper's §7 PEFT
    discussion, any weight-compatible fine-tune."""
    B, T, D = hidden.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = ctx_lens[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]

    x = rmsnorm(hidden.reshape(B * T, D), attn_norm, eps=cfg.norm_eps).reshape(B, T, D)
    q = (x @ wq).reshape(B, T, H, Dh)
    k = (x @ wk).reshape(B, T, Hkv, Dh)
    v = (x @ wv).reshape(B, T, Hkv, Dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    k_cache = update_cache(k_cache, k.transpose(0, 2, 1, 3), ctx_lens)
    v_cache = update_cache(v_cache, v.transpose(0, 2, 1, 3), ctx_lens)

    attn = attention(q.transpose(0, 2, 1, 3), k_cache, v_cache, ctx_lens)
    hidden = hidden + attn.transpose(0, 2, 1, 3).reshape(B, T, H * Dh) @ wo

    y = rmsnorm(hidden.reshape(B * T, D), mlp_norm, eps=cfg.norm_eps).reshape(B, T, D)
    hidden = hidden + (jax.nn.silu(y @ w_gate) * (y @ w_up)) @ w_down
    return hidden, k_cache, v_cache


def lm_head(
    cfg: ModelConfig,
    hidden: jax.Array,      # [B, T, D]
    final_norm: jax.Array,  # [D]
    w: jax.Array,           # [D, V]
) -> jax.Array:
    """hidden -> logits [B, T, V] (the engine picks the last valid row)."""
    B, T, D = hidden.shape
    x = rmsnorm(hidden.reshape(B * T, D), final_norm, eps=cfg.norm_eps)
    return (x @ w).reshape(B, T, -1)


def model_full(
    cfg: ModelConfig,
    tokens: jax.Array,     # [B, T] i32
    k_caches: jax.Array,   # [L, B, Hkv, S, Dh]
    v_caches: jax.Array,   # [L, B, Hkv, S, Dh]
    ctx_lens: jax.Array,   # [B] i32
    *flat_params: jax.Array,  # configs.param_specs order
):
    """Monolithic forward (no safepoints) for the §6.4.2 overhead bench.

    Returns (logits, k_caches, v_caches)."""
    from .configs import param_specs

    names = [n for n, _ in param_specs(cfg)]
    params = dict(zip(names, flat_params))

    hidden = embed(tokens, params["embedding"])
    ks, vs = [], []
    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        hidden, kc, vc = layer_fwd(
            cfg, hidden, k_caches[l], v_caches[l], ctx_lens,
            params[p + "attn_norm"], params[p + "wq"], params[p + "wk"],
            params[p + "wv"], params[p + "wo"], params[p + "mlp_norm"],
            params[p + "w_gate"], params[p + "w_up"], params[p + "w_down"],
        )
        ks.append(kc)
        vs.append(vc)
    logits = lm_head(cfg, hidden, params["final_norm"], params["lm_head"])
    return logits, jnp.stack(ks), jnp.stack(vs)


def init_params(cfg: ModelConfig, seed: int):
    """Deterministic random-init parameters as a flat name->array dict.

    Scaled init (1/sqrt(fan_in)) keeps logits O(1) so greedy sampling on
    the real path produces varied, non-degenerate token streams."""
    from .configs import param_specs

    params = {}
    key = jax.random.PRNGKey(seed)
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            arr = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            arr = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(
                jnp.float32(fan_in)
            )
        params[name] = arr
    return params
