//! Unified preemptive scheduler — paper Algorithm 1 plus the baseline
//! policies (§6.1) as switchable variants of the same machinery:
//!
//! * [`Policy::ConServe`] — SLO-aware token budget, reactive preemption
//!   of scheduled offline work, checkpoint-aware victim selection,
//!   offline batching mode with layer-wise preemption.
//! * [`Policy::VllmPP`] — strict-priority co-serving: greedy batching up
//!   to `max_batch_tokens`, memory pressure resolved with *blocking*
//!   swap-out/in (the Fig.-4b strawman), no running-batch preemption.
//! * [`Policy::OnlineOnly`] — drops offline work entirely (the paper's
//!   latency-optimal / zero-harvest baseline).
//!
//! ## Hot-path discipline
//!
//! `schedule` runs every engine iteration and is allocation-free in
//! steady state: the request table is a slab arena (array indexing, no
//! hashing), the KV manager is keyed by the same slot index, and every
//! intermediate list (`run_order`, continuing sets, deferred resumes,
//! candidate blocks) lives in a persistent scratch buffer reused across
//! iterations. The caller owns the [`ScheduleOutcome`] and passes it back
//! in each iteration, so plan/victim vectors recycle their capacity too.
//! See `rust/PERF.md` for the invariants.
//!
//! In a sharded deployment ([`crate::shard`]) every worker shard owns
//! one scheduler over its own arena and KV pool; nothing in this module
//! is shared across shards.

pub mod budget;
pub mod harvest;
pub mod preempt;

use crate::backend::{IterationPlan, WorkItem};
use crate::config::SchedConfig;
use crate::kvcache::manager::{KvError, KvManager};
use crate::kvcache::BlockId;
use crate::profiler::LatencyProfile;
use crate::request::{Class, KvResidence, Phase, Request, RequestArena, RequestId, State, TokenId};
use crate::TimeUs;
use std::collections::VecDeque;
use std::str::FromStr;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    ConServe,
    VllmPP,
    OnlineOnly,
}

impl FromStr for Policy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "conserve" => Ok(Policy::ConServe),
            "vllm++" | "vllmpp" | "vllm_pp" => Ok(Policy::VllmPP),
            "online-only" | "onlineonly" | "online_only" => Ok(Policy::OnlineOnly),
            other => Err(anyhow::anyhow!("unknown policy `{other}`")),
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Policy::ConServe => "ConServe",
            Policy::VllmPP => "vLLM++",
            Policy::OnlineOnly => "Online-Only",
        })
    }
}

/// What the scheduler decided for one iteration. Owned by the caller and
/// reused across iterations (`schedule` clears it on entry), so its
/// vectors keep their capacity instead of reallocating per step.
#[derive(Debug, Default)]
pub struct ScheduleOutcome {
    pub plan: IterationPlan,
    /// Offline victims whose GPU blocks were released instantly thanks to
    /// complete host checkpoints (§4.4 "as fast as freeing ... virtually").
    pub evicted: Vec<RequestId>,
    /// Victims whose KV was discarded (recompute on resume, Fig. 4a).
    pub discarded: Vec<RequestId>,
    /// Victims swapped out with a blocking transfer (vLLM++ path).
    pub swapped_out: Vec<RequestId>,
    /// Requests swapped in with a blocking transfer (vLLM++ resume).
    pub swapped_in: Vec<RequestId>,
    /// Requests flipped `Host -> Prefetching` this step. The engine
    /// appends these to its prefetch watch list, so the per-iteration
    /// prefetch pass never scans the whole request table.
    pub prefetch_started: Vec<RequestId>,
    /// Total blocking transfer time charged to this iteration (µs).
    pub blocking_io_us: u64,
    /// Blocking I/O block count (metrics).
    pub blocking_io_blocks: usize,
    /// Prefill-token budget that applied to offline admission.
    pub token_budget: usize,
    /// Admissions this step that attached shared prefix blocks from the
    /// KV manager's prefix trie.
    pub prefix_hits: u64,
    /// Prefill tokens those attachments covered — work the plan never
    /// has to feed (the headline prefix-sharing speedup).
    pub prefill_tokens_skipped: u64,
}

impl ScheduleOutcome {
    /// Reset for the next iteration, retaining buffer capacity.
    pub fn clear(&mut self) {
        self.plan.clear();
        self.evicted.clear();
        self.discarded.clear();
        self.swapped_out.clear();
        self.swapped_in.clear();
        self.prefetch_started.clear();
        self.blocking_io_us = 0;
        self.blocking_io_blocks = 0;
        self.token_budget = 0;
        self.prefix_hits = 0;
        self.prefill_tokens_skipped = 0;
    }
}

/// Result of one admission attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Admit {
    Planned,
    NoBudget,
    NoMemory,
}

/// Who is asking for KV blocks — determines victim-selection freedom.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum VictimMode {
    /// Newly admitted online work: may preempt any offline victim, but
    /// never other online work (vLLM-style admission control: if neither
    /// free blocks nor offline victims exist, it waits in the queue).
    OnlineAdmission,
    /// Already-running online work (decode growth / next chunk): offline
    /// victims first, youngest-online self-preemption as the last resort
    /// to guarantee progress.
    OnlineContinuing,
    /// Already-running offline work (decode growth / next chunk).
    OfflineContinuing,
    /// Freshly admitted offline work: checkpoint-backed evictions only.
    OfflineAdmission,
}

/// The unified scheduler: two priority queues + the continuous-batching
/// running set (paper §5: "priority queues with two priority levels so
/// they can share the same scheduler code").
pub struct UnifiedScheduler {
    pub cfg: SchedConfig,
    online_q: VecDeque<RequestId>,
    offline_q: VecDeque<RequestId>,
    running: Vec<RequestId>,
    /// Full-length KV footprint (blocks) reserved by running online
    /// requests, as of the last `schedule` call. Published to the shard
    /// load board ([`crate::shard::ShardLoads`]) for placement; costs
    /// nothing extra — the admission pass computes it anyway.
    reserved_online: usize,
    /// Weighted per-tenant served account (job-aware fair share,
    /// [`SchedConfig::fair_share`]): admission of a job request charges
    /// `total_len * 16 / fair_weight` to its tenant, and the offline
    /// pick order prefers the lowest account among equal urgencies, so
    /// one tenant's mega-job cannot starve the others. A short linear
    /// list — deployments see a handful of tenants per shard.
    tenant_served: Vec<(u32, u64)>,
    // ---- persistent scratch (capacity reused across iterations) ----
    /// Running set sorted for this iteration's passes.
    scratch_order: Vec<RequestId>,
    /// Continuing-prefill snapshot (rebuilt per class pass).
    scratch_cont: Vec<RequestId>,
    /// Resume-pending offline heads deferred this round.
    scratch_deferred: Vec<RequestId>,
    /// Checkpoint block indices (vLLM++ blocking swap-out path).
    scratch_blk: Vec<usize>,
    /// Prefetch candidates (blocking swap-in path).
    scratch_pf: Vec<(usize, BlockId)>,
}

pub struct Ctx<'a> {
    pub table: &'a mut RequestArena,
    pub kv: &'a mut KvManager,
    pub profile: &'a LatencyProfile,
    pub now: TimeUs,
    pub max_model_len: usize,
}

impl UnifiedScheduler {
    pub fn new(cfg: SchedConfig) -> Self {
        Self {
            cfg,
            online_q: VecDeque::new(),
            offline_q: VecDeque::new(),
            running: Vec::new(),
            reserved_online: 0,
            tenant_served: Vec::new(),
            scratch_order: Vec::new(),
            scratch_cont: Vec::new(),
            scratch_deferred: Vec::new(),
            scratch_blk: Vec::new(),
            scratch_pf: Vec::new(),
        }
    }

    pub fn enqueue(&mut self, id: RequestId, class: Class) {
        match class {
            Class::Online => self.online_q.push_back(id),
            Class::Offline => {
                if self.cfg.policy != Policy::OnlineOnly {
                    self.offline_q.push_back(id)
                }
            }
        }
    }

    /// Preempted offline requests rejoin at the *back* of the offline
    /// queue: resume needs a large contiguous restore (or a recompute)
    /// that rarely fits while the pool is busy, and parking resume-
    /// pending work at the head starves fresh admission — the head-of-
    /// line pile was measured to collapse harvest to near zero. Fresh
    /// docs keep the pipeline saturated; preempted ones return when the
    /// pool thins out (best-effort semantics, §2.2).
    pub fn requeue_preempted(&mut self, id: RequestId) {
        self.offline_q.push_back(id);
    }

    /// Ids waiting in the offline queue, tail first — the order the
    /// cross-shard steal donor harvests victims in (the tail is the work
    /// least likely to run here soon, so stealing it costs the donor the
    /// least locality).
    pub fn offline_queue_rev(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.offline_q.iter().rev().copied()
    }

    /// Remove a specific id from the offline waiting queue (steal-victim
    /// extraction). Returns false if it was not queued. Scans from the
    /// *back*, matching the tail-first harvest order, so extracting a
    /// steal victim costs O(distance from the tail), not O(backlog);
    /// runs only on the migration path, never in the scheduling loop.
    pub fn remove_offline(&mut self, id: RequestId) -> bool {
        match self.offline_q.iter().rposition(|&x| x == id) {
            Some(i) => {
                self.offline_q.remove(i);
                true
            }
            None => false,
        }
    }

    /// Remove a specific id from the online waiting queue
    /// (client-disconnect cancellation before admission). Returns false
    /// if it was not queued. Same back-scan as
    /// [`remove_offline`](Self::remove_offline); runs only on the
    /// cancellation path, never in the scheduling loop.
    pub fn remove_online(&mut self, id: RequestId) -> bool {
        match self.online_q.iter().rposition(|&x| x == id) {
            Some(i) => {
                self.online_q.remove(i);
                true
            }
            None => false,
        }
    }

    pub fn online_waiting(&self) -> usize {
        self.online_q.len()
    }

    /// Queue-head ids (observability).
    pub fn online_head(&self) -> Option<RequestId> {
        self.online_q.front().copied()
    }

    pub fn offline_head(&self) -> Option<RequestId> {
        self.offline_q.front().copied()
    }

    pub fn offline_waiting(&self) -> usize {
        self.offline_q.len()
    }

    pub fn running_ids(&self) -> &[RequestId] {
        &self.running
    }

    /// KV blocks reserved by running online requests at full length
    /// (snapshot from the last scheduling step; see the field docs).
    pub fn reserved_online_blocks(&self) -> usize {
        self.reserved_online
    }

    /// Weighted tokens already served to `tenant` (fair-share account).
    fn tenant_deficit(&self, tenant: u32) -> u64 {
        self.tenant_served
            .iter()
            .find(|&&(t, _)| t == tenant)
            .map_or(0, |&(_, v)| v)
    }

    fn charge_tenant(&mut self, tenant: u32, weighted: u64) {
        match self.tenant_served.iter_mut().find(|(t, _)| *t == tenant) {
            Some((_, v)) => *v = v.saturating_add(weighted),
            None => {
                // a tenant first seen now joins at the current floor,
                // not at zero: accounts are lifetime totals, and a
                // zero-initialized newcomer would out-rank established
                // tenants on every admission until it had "caught up"
                // with their entire history — the exact starvation this
                // mechanism exists to prevent, inverted
                let floor = self
                    .tenant_served
                    .iter()
                    .map(|&(_, v)| v)
                    .min()
                    .unwrap_or(0);
                self.tenant_served
                    .push((tenant, floor.saturating_add(weighted)));
            }
        }
    }

    /// Job-aware offline pick: the queue index to admit next, by
    /// (urgency desc, weighted tenant deficit asc, FIFO). O(queue) per
    /// admission — admissions are rare relative to scheduling iterations
    /// and the scan allocates nothing; an indexed priority structure is
    /// a future rung if deep multi-tenant backlogs make this shards'
    /// bottleneck.
    fn pick_offline_index(&self, table: &RequestArena) -> usize {
        let mut best = 0usize;
        let mut best_key: Option<(std::cmp::Reverse<u32>, u64, usize)> = None;
        for (i, &id) in self.offline_q.iter().enumerate() {
            let Some(r) = table.get(id) else { continue };
            let key = (
                std::cmp::Reverse(r.urgency),
                self.tenant_deficit(r.tenant),
                i,
            );
            if best_key.is_none_or(|b| key < b) {
                best = i;
                best_key = Some(key);
            }
        }
        best
    }

    pub fn has_work(&self, table: &RequestArena) -> bool {
        !self.online_q.is_empty()
            || !self.offline_q.is_empty()
            || self
                .running
                .iter()
                .any(|&id| table.get(id).is_some_and(|r| !r.is_done()))
    }

    /// Oldest waiting online arrival (Alg. 2 input).
    pub fn oldest_online_arrival(&self, table: &RequestArena) -> Option<TimeUs> {
        self.online_q
            .front()
            .and_then(|&id| table.get(id))
            .map(|r| r.arrival)
    }

    /// Shape of the waiting online work (Alg. 2 estimate input).
    pub fn online_queue_shape(
        &self,
        table: &RequestArena,
        chunk: usize,
    ) -> crate::backend::PlanSummary {
        let mut prefill = 0;
        for &id in &self.online_q {
            if let Some(r) = table.get(id) {
                prefill += r.remaining_feed().min(chunk);
            }
        }
        crate::backend::PlanSummary {
            prefill_tokens: prefill,
            decode_seqs: 0,
            ctx_tokens: 0,
            n_seqs: self.online_q.len(),
        }
    }

    // =====================================================================
    // Algorithm 1: one scheduling step.
    //
    // Budget accounting runs in *estimated microseconds* against the
    // profiler's latency model (§4.5): every admitted item adds its
    // marginal cost (prefill: c1·n; decode: c2 + c3·ctx) to the running
    // estimate, and admission stops when the estimate would cross the
    // SLO. Offline work — including *already-running* offline decodes —
    // is only admitted into the budget remainder after all online work,
    // which realizes PreemptOverBudgetOffline (Alg. 1 line 16): an
    // over-budget offline request simply is not scheduled this iteration
    // (its KV stays; memory-pressure preemption is separate).
    // =====================================================================
    pub fn schedule(&mut self, c: &mut Ctx, out: &mut ScheduleOutcome) {
        out.clear();

        // Drop finished/aborted from the running set.
        self.running.retain(|&id| {
            c.table
                .get(id)
                .is_some_and(|r| r.state == State::Running && !r.is_done())
        });

        let coef = c.profile.c;
        let slo_tpot_us = self.cfg.slo.tpot_ms * 1000.0;
        let slo_ttft_us = self.cfg.slo.ttft_ms * 1000.0;
        let decode_cost = move |ctx: usize| coef[2] + coef[3] * ctx as f64;

        // Work on moved-out buffers so `&mut self` helper calls stay legal;
        // every take is matched by a put-back at the end of this fn.
        let mut items = std::mem::take(&mut out.plan.items);
        let mut run_order = std::mem::take(&mut self.scratch_order);
        let mut cont = std::mem::take(&mut self.scratch_cont);

        let mut est_us = coef[0]; // fixed iteration cost
        let mut tokens_used = 0usize;
        run_order.clear();
        run_order.extend_from_slice(&self.running);
        // unstable sort: allocation-free; the id tiebreak keeps victim and
        // admission order fully deterministic
        run_order.sort_unstable_by_key(|&id| {
            let r = &c.table[id];
            (r.class == Class::Offline, r.arrival, id)
        });

        // ---- 1. online decodes: unconditional (continuous batching) ----
        for &id in &run_order {
            let r = &c.table[id];
            if r.class != Class::Online
                || r.phase() != Phase::Decode
                || r.residence != KvResidence::Gpu
            {
                continue;
            }
            if items.len() >= self.cfg.max_batch_reqs {
                break;
            }
            let ctx_len = r.ctx_len;
            if !self.ensure_blocks(
                c,
                out,
                id,
                ctx_len + 1,
                &mut items,
                VictimMode::OnlineContinuing,
            ) {
                continue; // no memory even after preemption
            }
            let r = &c.table[id];
            est_us += decode_cost(r.ctx_len);
            tokens_used += 1;
            let (tok_start, tok_len) = stage_feed(r, 1, &mut out.plan.staging);
            items.push(WorkItem {
                req: id,
                class: Class::Online,
                phase: Phase::Decode,
                ctx_len: r.ctx_len,
                n_tokens: 1,
                tok_start,
                tok_len,
                sample_key: sample_key(r),
            });
        }

        // ---- 2. online prefills within the SLO budget (§4.5: TPOT if
        // decode-phase requests exist, TTFT otherwise). "Exist" includes
        // the running set, not just this iteration's items: anything
        // mid-generation will decode next iteration, and a TTFT-sized
        // prefill-only iteration would stall it far past its TPOT.
        let any_running = !self.running.is_empty();
        let online_budget_us = if !self.cfg.slo_aware {
            f64::INFINITY
        } else if items.is_empty() && !any_running {
            slo_ttft_us
        } else {
            slo_tpot_us
        };

        // Capacity admission control for the latency-critical class: a
        // new online request is admitted only if its full KV footprint
        // (prompt + max output) fits in what the pool can ever free for
        // it. Over-admission cannibalizes running online requests
        // (discard churn) — queueing delay is the honest cost instead.
        let bt = c.kv.block_tokens;
        let mut reserved_online: usize = self
            .running
            .iter()
            .filter_map(|&id| c.table.get(id))
            .filter(|r| r.class == Class::Online)
            .map(|r| r.total_len().div_ceil(bt))
            .sum();
        let online_capacity = (c.kv.gpu_total() * 95) / 100;
        cont.clear();
        cont.extend(run_order.iter().copied().filter(|&id| {
            let r = &c.table[id];
            r.class == Class::Online
                && r.phase() == Phase::Prefill
                && r.residence == KvResidence::Gpu
        }));
        for i in 0..cont.len() {
            let id = cont[i];
            self.admit(
                c,
                out,
                id,
                online_budget_us,
                &mut est_us,
                &mut tokens_used,
                &mut items,
                VictimMode::OnlineContinuing,
            );
        }
        while let Some(&id) = self.online_q.front() {
            if items.len() >= self.cfg.max_batch_reqs
                || tokens_used >= self.cfg.max_batch_tokens
                || est_us + coef[1] > online_budget_us
            {
                break;
            }
            self.online_q.pop_front();
            let victim_this_round = out.evicted.contains(&id)
                || out.discarded.contains(&id)
                || out.swapped_out.contains(&id);
            if victim_this_round {
                // just preempted: resume attempts start next iteration
                self.online_q.push_front(id);
                break;
            }
            let need = c.table[id].total_len().div_ceil(bt);
            if reserved_online + need > online_capacity {
                // no capacity headroom: wait in the queue
                self.online_q.push_front(id);
                break;
            }
            // resets residence for preempted online victims re-entering
            // (Discarded -> recompute, Host -> prefetch / blocking swap-in).
            // Strict FIFO: a resume-pending head blocks the queue — this
            // bounds the number of concurrently-prefetching requests.
            if !self.make_resumable(c, out, id) {
                self.online_q.push_front(id);
                break;
            }
            c.kv.register(id);
            Self::try_prefix_attach(c, out, id);
            let res = self.admit(
                c,
                out,
                id,
                online_budget_us,
                &mut est_us,
                &mut tokens_used,
                &mut items,
                VictimMode::OnlineAdmission,
            );
            if res == Admit::Planned {
                reserved_online += need;
                let r = c.table.get_mut(id).unwrap();
                r.state = State::Running;
                if !self.running.contains(&id) {
                    self.running.push(id);
                }
            } else {
                // out of memory (or budget): stay at the queue head;
                // admitting without capacity only bloats the running set
                self.online_q.push_front(id);
                break;
            }
        }

        self.reserved_online = reserved_online;

        let has_online = items.iter().any(|i| i.class == Class::Online)
            || !self.online_q.is_empty();

        // ---- 3. offline admission ----
        if self.cfg.policy != Policy::OnlineOnly {
            // Offline batching mode (Alg. 1 lines 20-22): no online work
            // anywhere => ignore the SLO budget, saturate the GPU.
            let offline_mode = !has_online;
            let offline_budget_us = if !self.cfg.slo_aware || offline_mode {
                f64::INFINITY
            } else {
                slo_tpot_us
            };
            out.token_budget = if offline_budget_us.is_finite() {
                ((offline_budget_us - est_us).max(0.0) / coef[1]) as usize
            } else {
                self.cfg.max_batch_tokens.saturating_sub(tokens_used)
            };

            // running offline decodes — admitted only within the budget
            // remainder (over-budget offline is preempted from the batch)
            for &id in &run_order {
                let r = &c.table[id];
                if r.class != Class::Offline
                    || r.phase() != Phase::Decode
                    || r.residence != KvResidence::Gpu
                {
                    continue;
                }
                if items.len() >= self.cfg.max_batch_reqs
                    || tokens_used >= self.cfg.max_batch_tokens
                {
                    break;
                }
                let cost = decode_cost(r.ctx_len);
                if est_us + cost > offline_budget_us {
                    continue; // paused this iteration (budget preemption)
                }
                let ctx_len = r.ctx_len;
                if !self.ensure_blocks(
                    c,
                    out,
                    id,
                    ctx_len + 1,
                    &mut items,
                    VictimMode::OfflineContinuing,
                ) {
                    continue;
                }
                let r = &c.table[id];
                est_us += cost;
                tokens_used += 1;
                let (tok_start, tok_len) = stage_feed(r, 1, &mut out.plan.staging);
                items.push(WorkItem {
                    req: id,
                    class: Class::Offline,
                    phase: Phase::Decode,
                    ctx_len: r.ctx_len,
                    n_tokens: 1,
                    tok_start,
                    tok_len,
                    sample_key: sample_key(r),
                });
            }

            // continuing offline prefills
            cont.clear();
            cont.extend(run_order.iter().copied().filter(|&id| {
                let r = &c.table[id];
                r.class == Class::Offline
                    && r.phase() == Phase::Prefill
                    && r.residence == KvResidence::Gpu
            }));
            for i in 0..cont.len() {
                let id = cont[i];
                self.admit(
                    c,
                    out,
                    id,
                    offline_budget_us,
                    &mut est_us,
                    &mut tokens_used,
                    &mut items,
                    VictimMode::OfflineContinuing,
                );
            }

            // new / resuming offline work. Near-FIFO with a bounded skip
            // allowance: a resume-pending head (prefetch in flight /
            // swap-in blocked on memory) defers — like vLLM's separate
            // waiting vs swapped queues — but at most MAX_HEAD_SKIPS
            // requests may be in that state, so prefetch fan-out cannot
            // fill the GPU pool with half-restored KV nothing can evict.
            const MAX_HEAD_SKIPS: usize = 4;
            let mut deferred = std::mem::take(&mut self.scratch_deferred);
            deferred.clear();
            loop {
                if self.offline_q.is_empty()
                    || items.len() >= self.cfg.max_batch_reqs
                    || tokens_used >= self.cfg.max_batch_tokens
                    || est_us + coef[1] > offline_budget_us
                {
                    break;
                }
                // job-aware mode picks by (urgency, tenant fair share)
                // instead of the queue head; plain FIFO otherwise
                let id = if self.cfg.fair_share {
                    let i = self.pick_offline_index(c.table);
                    self.offline_q.remove(i).unwrap()
                } else {
                    self.offline_q.pop_front().unwrap()
                };
                let victim_this_round = out.evicted.contains(&id)
                    || out.discarded.contains(&id)
                    || out.swapped_out.contains(&id);
                if victim_this_round || !self.make_resumable(c, out, id) {
                    deferred.push(id);
                    if deferred.len() >= MAX_HEAD_SKIPS {
                        break;
                    }
                    continue;
                }
                c.kv.register(id);
                Self::try_prefix_attach(c, out, id);
                let res = self.admit(
                    c,
                    out,
                    id,
                    offline_budget_us,
                    &mut est_us,
                    &mut tokens_used,
                    &mut items,
                    VictimMode::OfflineAdmission,
                );
                let has_blocks = c.kv.seq(id).is_some_and(|s| s.gpu_blocks() > 0);
                if res == Admit::Planned || has_blocks {
                    // admitted, or resumed-with-resident-blocks (paused).
                    // Either way it moves to the running set (a request is
                    // never in the queue and the running set at once) and
                    // is visible to victim selection / continuing passes.
                    if self.cfg.fair_share && res == Admit::Planned {
                        // charge the full expected footprint once per
                        // account domain, at first admission (starvation
                        // happens at admission granularity, not per
                        // chunk). The flag is scheduler-local and does
                        // not travel: a locally preempted request
                        // re-admitting never pays twice, while a
                        // migrated or resumed request pays in its new
                        // shard's/process's fresh accounts.
                        let r = c.table.get_mut(id).unwrap();
                        if r.job != 0 && !r.fair_charged {
                            r.fair_charged = true;
                            let w = (r.total_len() as u64 * 16)
                                / u64::from(r.fair_weight.max(1));
                            let tenant = r.tenant;
                            self.charge_tenant(tenant, w);
                        }
                    }
                    let r = c.table.get_mut(id).unwrap();
                    r.state = State::Running;
                    if !self.running.contains(&id) {
                        self.running.push(id);
                    }
                } else {
                    // no capacity for fresh offline work: stop admitting
                    self.offline_q.push_front(id);
                    break;
                }
            }
            // deferred resume-pending requests return to the queue head
            // (in order) so they stay first in line
            for &id in deferred.iter().rev() {
                self.offline_q.push_front(id);
            }
            self.scratch_deferred = deferred;
        }

        // ---- 4. preemptible iff pure offline (§4.3) ----
        let pure_offline =
            !items.is_empty() && items.iter().all(|i| i.class == Class::Offline);
        out.plan.items = items;
        // safepoint instrumentation is ConServe's mechanism; the
        // baselines never arm it regardless of flag combinations
        out.plan.preemptible = pure_offline
            && self.cfg.layerwise_preempt
            && self.cfg.policy == Policy::ConServe;
        self.scratch_order = run_order;
        self.scratch_cont = cont;
    }

    /// Map a freshly-registered request's prompt onto shared prefix
    /// blocks already resident in the KV manager's trie (no-op when the
    /// prefix cache is off). A hit fast-forwards `ctx_len` past the
    /// covered tokens, so the prefill planning below only feeds the
    /// remainder — `feed_target`, `generated`, and the keyed sampling
    /// positions are untouched, keeping token streams byte-identical to
    /// the sharing-off run.
    fn try_prefix_attach(c: &mut Ctx, out: &mut ScheduleOutcome, id: RequestId) {
        let Some(r) = c.table.get_mut(id) else {
            return;
        };
        let covered = c.kv.prefix_attach(id, &r.prompt);
        if covered > 0 {
            r.ctx_len = covered;
            out.prefix_hits += 1;
            out.prefill_tokens_skipped += covered as u64;
        }
    }

    /// Admit the next work of `id` (prefill chunk or decode step) within
    /// the µs budget, updating the running estimate and token count.
    #[allow(clippy::too_many_arguments)]
    fn admit(
        &mut self,
        c: &mut Ctx,
        out: &mut ScheduleOutcome,
        id: RequestId,
        budget_us: f64,
        est_us: &mut f64,
        tokens_used: &mut usize,
        items: &mut Vec<WorkItem>,
        mode: VictimMode,
    ) -> Admit {
        let coef = c.profile.c;
        let r = &c.table[id];
        if r.residence != KvResidence::Gpu {
            // preempted earlier in this same scheduling round (continuing
            // lists are snapshots); scheduling it would undo the preemption
            return Admit::NoMemory;
        }
        if r.phase() == Phase::Decode {
            // e.g. resumed request whose next step is a decode
            let cost = coef[2] + coef[3] * r.ctx_len as f64;
            if *est_us + cost > budget_us || *tokens_used >= self.cfg.max_batch_tokens {
                return Admit::NoBudget;
            }
            let ctx_len = r.ctx_len;
            let class = r.class;
            if !self.ensure_blocks(c, out, id, ctx_len + 1, items, mode) {
                return Admit::NoMemory;
            }
            let r = &c.table[id];
            *est_us += cost;
            *tokens_used += 1;
            let (tok_start, tok_len) = stage_feed(r, 1, &mut out.plan.staging);
            items.push(WorkItem {
                req: id,
                class,
                phase: Phase::Decode,
                ctx_len: r.ctx_len,
                n_tokens: 1,
                tok_start,
                tok_len,
                sample_key: sample_key(r),
            });
            return Admit::Planned;
        }
        let slack_tokens = if budget_us.is_finite() {
            ((budget_us - *est_us) / coef[1]).floor().max(0.0) as usize
        } else {
            usize::MAX
        };
        let cap = self.cfg.max_batch_tokens.saturating_sub(*tokens_used);
        let room = c.max_model_len.saturating_sub(r.ctx_len);
        // class-aware chunk: the harvest controller actuates
        // `offline_chunk` (0 = disabled) so best-effort prefills shrink
        // under online pressure; online chunking is never touched
        let chunk = if r.class == Class::Offline && self.cfg.offline_chunk != 0 {
            self.cfg.offline_chunk
        } else {
            self.cfg.chunk_size
        };
        let n = r
            .remaining_feed()
            .min(chunk)
            .min(slack_tokens)
            .min(cap)
            .min(room);
        if n == 0 {
            return Admit::NoBudget;
        }
        let (class, ctx_len) = (r.class, r.ctx_len);
        if !self.ensure_blocks(c, out, id, ctx_len + n, items, mode) {
            return Admit::NoMemory;
        }
        let r = &c.table[id];
        *est_us += coef[1] * n as f64;
        *tokens_used += n;
        let (tok_start, tok_len) = stage_feed(r, n, &mut out.plan.staging);
        items.push(WorkItem {
            req: id,
            class,
            phase: Phase::Prefill,
            ctx_len: r.ctx_len,
            n_tokens: n,
            tok_start,
            tok_len,
            sample_key: sample_key(r),
        });
        Admit::Planned
    }

    /// Ensure `id` owns GPU blocks covering `new_total` tokens, preempting
    /// offline victims if necessary. Returns false if memory cannot be
    /// found. (Alg. 1 PREEMPTSCHEDULING — invoked for memory pressure.)
    ///
    /// Victim freedom depends on who asks (`mode`): online work may evict
    /// or discard any offline victim; *continuing* offline work prefers
    /// checkpointed victims but may discard an idle uncheckpointed one to
    /// guarantee decode progress; *newly admitted* offline work may only
    /// use checkpoint-backed (free) evictions — admitting new offline by
    /// destroying other offline KV is pure churn.
    fn ensure_blocks(
        &mut self,
        c: &mut Ctx,
        out: &mut ScheduleOutcome,
        id: RequestId,
        new_total: usize,
        items: &mut Vec<WorkItem>,
        mode: VictimMode,
    ) -> bool {
        // vLLM's admission watermark: new sequences are only admitted if
        // a slack of free blocks remains afterwards, so running-sequence
        // decode growth rarely needs preemption (which in vanilla vLLM
        // swaps out a whole victim to gain one block).
        if self.cfg.policy == Policy::VllmPP
            && matches!(
                mode,
                VictimMode::OnlineAdmission | VictimMode::OfflineAdmission
            )
        {
            let needed = c.kv.blocks_needed(id, new_total);
            let slack = c.kv.gpu_total() / 50;
            if c.kv.gpu_free() < needed + slack {
                return false;
            }
        }
        loop {
            match c.kv.grow(id, new_total) {
                Ok(()) => return true,
                Err(KvError::OutOfGpu { .. }) => {
                    // The defining vLLM++ limitation (paper §3): admission
                    // cannot preempt already-scheduled work — "incoming
                    // online requests must wait until they are served".
                    // Only running-sequence growth may preempt (vLLM's
                    // recompute/swap preemption). ConServe's reactive
                    // admission-time preemption is the contribution.
                    if self.cfg.policy == Policy::VllmPP {
                        match mode {
                            // admission never preempts in vLLM
                            VictimMode::OnlineAdmission
                            | VictimMode::OfflineAdmission => return false,
                            // growth preempts the *newest running
                            // sequence regardless of class* (vanilla vLLM
                            // FCFS-recompute/swap — "cannot be preempted
                            // selectively"). This is what lets offline
                            // decode growth evict online requests and
                            // wreck their TTFT/TPOT (paper §3, Fig. 2).
                            _ => match self.pick_youngest_victim(c, id) {
                                Some(v) => {
                                    self.preempt_request(c, out, v, items);
                                    continue;
                                }
                                None => return false,
                            },
                        }
                    }
                    let ckpt_only = mode == VictimMode::OfflineAdmission;
                    let exclude_items = !matches!(
                        mode,
                        VictimMode::OnlineAdmission | VictimMode::OnlineContinuing
                    );
                    match self.pick_victim(c, id, items, ckpt_only, exclude_items) {
                        Some(victim) => {
                            self.preempt_request(c, out, victim, items);
                        }
                        None if mode == VictimMode::OnlineContinuing => {
                            // vLLM-style self-preemption of the youngest
                            // online request to guarantee progress
                            match self.pick_online_victim(c, id) {
                                Some(v) => self.preempt_request(c, out, v, items),
                                None => return false,
                            }
                        }
                        None => return false,
                    }
                }
                Err(_) => return false,
            }
        }
    }

    /// Victim preference (§4.4): fully-checkpointed offline first (free
    /// release), then other offline by largest resident footprint. Only
    /// running requests can hold GPU blocks, so the scan is bounded by
    /// the running set, not the request table.
    fn pick_victim(
        &self,
        c: &Ctx,
        requester: RequestId,
        items: &[WorkItem],
        ckpt_only: bool,
        exclude_items: bool,
    ) -> Option<RequestId> {
        let bt = c.kv.block_tokens;
        let mut best: Option<(bool, usize, std::cmp::Reverse<RequestId>)> = None;
        for &rid in &self.running {
            let Some(r) = c.table.get(rid) else { continue };
            if rid == requester
                || r.class != Class::Offline
                || r.residence != KvResidence::Gpu
            {
                continue;
            }
            if exclude_items && items.iter().any(|i| i.req == rid) {
                continue;
            }
            let Some(seq) = c.kv.seq(rid) else { continue };
            let resident = seq.gpu_blocks();
            if resident == 0 {
                continue;
            }
            let ckpt = seq.fully_checkpointed(bt);
            if ckpt_only && !ckpt {
                continue;
            }
            // prefer checkpointed; among equals, largest footprint; break
            // remaining ties by id so victim choice is deterministic
            // regardless of running-set order
            let cand = (ckpt, resident, std::cmp::Reverse(rid));
            best = match best {
                None => Some(cand),
                Some(b) if cand > b => Some(cand),
                Some(b) => Some(b),
            };
        }
        best.map(|(_, _, rid)| rid.0)
    }

    /// vLLM's class-blind LIFO preemption: the newest running sequence
    /// with resident blocks, regardless of priority.
    fn pick_youngest_victim(&self, c: &Ctx, requester: RequestId) -> Option<RequestId> {
        self.running
            .iter()
            .copied()
            .filter(|&rid| rid != requester)
            .filter(|&rid| {
                let Some(r) = c.table.get(rid) else { return false };
                r.residence == KvResidence::Gpu
                    && c.kv.seq(rid).is_some_and(|s| s.gpu_blocks() > 0)
            })
            .max_by_key(|&rid| (c.table[rid].arrival, rid))
    }

    fn pick_online_victim(&self, c: &Ctx, requester: RequestId) -> Option<RequestId> {
        // youngest online request with resident blocks
        self.running
            .iter()
            .copied()
            .filter(|&rid| rid != requester)
            .filter(|&rid| {
                let r = &c.table[rid];
                r.class == Class::Online
                    && r.residence == KvResidence::Gpu
                    && c.kv.seq(rid).is_some_and(|s| s.gpu_blocks() > 0)
            })
            .max_by_key(|&rid| c.table[rid].arrival)
    }

    /// Preempt `victim` during scheduling: release its GPU memory via the
    /// cheapest legal mechanism for the active policy.
    fn preempt_request(
        &mut self,
        c: &mut Ctx,
        out: &mut ScheduleOutcome,
        victim: RequestId,
        items: &mut Vec<WorkItem>,
    ) {
        // remove any work items already planned for the victim
        items.retain(|i| i.req != victim);
        self.running.retain(|&rid| rid != victim);

        let bt = c.kv.block_tokens;
        let fully_ckpt = c.kv.seq(victim).is_some_and(|s| s.fully_checkpointed(bt));
        let r = c.table.get_mut(victim).unwrap();
        r.state = State::Preempted;
        r.preemptions += 1;

        if fully_ckpt {
            // §4.4: discard GPU copies, host checkpoints make resume a
            // pure prefetch — microseconds, no data motion now.
            c.kv.evict_gpu(victim);
            r.residence = KvResidence::Host;
            out.evicted.push(victim);
        } else if self.cfg.policy == Policy::VllmPP {
            // blocking swap-out of every resident block (Fig. 4b)
            let seq = c.kv.seq(victim).unwrap();
            let blocks = seq.gpu_blocks();
            let mut idxs = std::mem::take(&mut self.scratch_blk);
            c.kv.checkpoint_candidates_into(victim, &mut idxs);
            for &i in &idxs {
                if c.kv.begin_ckpt(victim, i).is_ok() {
                    c.kv.finish_ckpt(victim, i);
                }
            }
            self.scratch_blk = idxs;
            c.kv.evict_gpu(victim);
            r.residence = KvResidence::Host;
            out.swapped_out.push(victim);
            out.blocking_io_blocks += blocks;
        } else {
            // ConServe extreme case (§4.4): discard and recompute later
            c.kv.discard(victim);
            c.table.get_mut(victim).unwrap().discard_to_recompute();
            out.discarded.push(victim);
        }
        if c.table[victim].class == Class::Offline {
            self.requeue_preempted(victim);
        } else {
            self.online_q.push_front(victim);
        }
    }

    /// Make a queued request runnable. Returns false if it must wait for
    /// an asynchronous prefetch (it stays queued).
    fn make_resumable(
        &mut self,
        c: &mut Ctx,
        out: &mut ScheduleOutcome,
        id: RequestId,
    ) -> bool {
        let r = &c.table[id];
        match r.residence {
            KvResidence::Gpu | KvResidence::Discarded => {
                let r = c.table.get_mut(id).unwrap();
                r.residence = KvResidence::Gpu;
                true
            }
            KvResidence::Prefetching => {
                // the engine flips Prefetching -> Gpu when the last H2D
                // op completes; until then the request stays queued
                false
            }
            KvResidence::Host => {
                if self.cfg.prefetch && self.cfg.policy == Policy::ConServe {
                    // background prefetch: the engine issues the H2D ops;
                    // not runnable yet
                    let r = c.table.get_mut(id).unwrap();
                    r.residence = KvResidence::Prefetching;
                    out.prefetch_started.push(id);
                    false
                } else {
                    // blocking swap-in (vLLM++ and no-prefetch ablation).
                    // Gated on vLLM's small free-memory watermark (~1%);
                    // under sustained pressure the same blocks ping-pong
                    // across PCIe — exactly the swap thrash the paper's
                    // Fig. 4b/§6.2 attributes to this baseline.
                    let mut cands = std::mem::take(&mut self.scratch_pf);
                    c.kv.prefetch_candidates_into(id, &mut cands);
                    let watermark = (c.kv.gpu_total() / 100).max(1);
                    if c.kv.gpu_free() < cands.len() + watermark {
                        self.scratch_pf = cands;
                        return false;
                    }
                    let n = cands.len();
                    let mut ok = true;
                    for &(idx, _hb) in &cands {
                        if c.kv.begin_prefetch(id, idx).is_err() {
                            ok = false;
                            break;
                        }
                    }
                    self.scratch_pf = cands;
                    if !ok {
                        // GPU too full to swap in; leave on host
                        return false;
                    }
                    out.swapped_in.push(id);
                    out.blocking_io_blocks += n;
                    let r = c.table.get_mut(id).unwrap();
                    r.residence = KvResidence::Gpu;
                    true
                }
            }
        }
    }
}

/// Stage the next `n` feed tokens of `r` into the plan's shared staging
/// buffer, returning the item's `(start, len)` range. Requests with no
/// token data (empty prompt, no sampled outputs — the whole simulator
/// path) stage nothing, so the steady-state scheduling loop never
/// touches the heap; the real path appends its chunk to the one
/// iteration-reused buffer instead of allocating a per-item vector.
#[inline]
fn stage_feed(r: &Request, n: usize, staging: &mut Vec<TokenId>) -> (u32, u32) {
    let start = staging.len() as u32;
    if r.prompt.is_empty() && r.output.is_empty() {
        return (start, 0);
    }
    r.feed_tokens_into(n, staging);
    (start, n as u32)
}

/// Draw key for the token this item may sample: per-request sampler
/// state mixed with the output position, so the same request position
/// samples identically on any shard, under any chunking or batch
/// composition (the invariant cross-shard migration relies on).
#[inline]
fn sample_key(r: &Request) -> u64 {
    crate::util::rng::mix64(r.sampler_state ^ r.generated as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;

    fn setup(policy: Policy) -> (UnifiedScheduler, RequestArena, KvManager) {
        let mut cfg = EngineConfig::sim_a100_7b();
        cfg.sched.policy = policy;
        let kv = KvManager::new(cfg.mem.gpu_blocks, cfg.mem.host_blocks, cfg.mem.block_tokens);
        (UnifiedScheduler::new(cfg.sched), RequestArena::new(), kv)
    }

    fn profile() -> LatencyProfile {
        LatencyProfile {
            c: [1200.0, 96.0, 40.0, 0.385],
        }
    }

    fn add(
        table: &mut RequestArena,
        class: Class,
        prompt: usize,
        output: usize,
    ) -> RequestId {
        table.insert(Request::new(0, class, vec![], prompt, output, 0))
    }

    fn sched_once(
        s: &mut UnifiedScheduler,
        table: &mut RequestArena,
        kv: &mut KvManager,
        max_model_len: usize,
    ) -> ScheduleOutcome {
        let p = profile();
        let mut out = ScheduleOutcome::default();
        let mut ctx = Ctx {
            table,
            kv,
            profile: &p,
            now: 0,
            max_model_len,
        };
        s.schedule(&mut ctx, &mut out);
        out
    }

    #[test]
    fn online_only_ignores_offline() {
        let (mut s, mut table, mut kv) = setup(Policy::OnlineOnly);
        let id = add(&mut table, Class::Offline, 1024, 128);
        s.enqueue(id, Class::Offline);
        let out = sched_once(&mut s, &mut table, &mut kv, 4096);
        assert!(out.plan.items.is_empty());
    }

    #[test]
    fn online_first_then_offline_fill() {
        let (mut s, mut table, mut kv) = setup(Policy::ConServe);
        let on = add(&mut table, Class::Online, 1024, 128);
        let off = add(&mut table, Class::Offline, 2048, 128);
        s.enqueue(on, Class::Online);
        s.enqueue(off, Class::Offline);
        let out = sched_once(&mut s, &mut table, &mut kv, 4096);
        assert_eq!(out.plan.items.len(), 2);
        assert_eq!(out.plan.items[0].class, Class::Online);
        assert_eq!(out.plan.items[0].n_tokens, 512); // chunk_size
        assert!(!out.plan.preemptible, "mixed batch is not preemptible");
        // offline got (only) the remaining budget
        let offline: usize = out
            .plan
            .items
            .iter()
            .filter(|i| i.class == Class::Offline)
            .map(|i| i.n_tokens)
            .sum();
        assert!(offline > 0, "offline must fill the budget remainder");
        assert!(offline <= out.token_budget);
    }

    #[test]
    fn pure_offline_batch_is_preemptible() {
        let (mut s, mut table, mut kv) = setup(Policy::ConServe);
        let id = add(&mut table, Class::Offline, 2048, 128);
        s.enqueue(id, Class::Offline);
        let out = sched_once(&mut s, &mut table, &mut kv, 4096);
        assert!(!out.plan.items.is_empty());
        assert!(out.plan.preemptible);
        // offline batching mode: budget ignores the SLO cap
        let total: usize = out.plan.items.iter().map(|i| i.n_tokens).sum();
        assert!(total >= 512);
    }

    #[test]
    fn outcome_buffers_recycle_across_iterations() {
        // the same ScheduleOutcome is reused; capacities persist and the
        // cleared state never leaks stale items between iterations
        let (mut s, mut table, mut kv) = setup(Policy::ConServe);
        let id = add(&mut table, Class::Offline, 2048, 64);
        s.enqueue(id, Class::Offline);
        let p = profile();
        let mut out = ScheduleOutcome::default();
        for step in 0..50 {
            let mut ctx = Ctx {
                table: &mut table,
                kv: &mut kv,
                profile: &p,
                now: step * 100_000,
                max_model_len: 4096,
            };
            s.schedule(&mut ctx, &mut out);
            for item in &out.plan.items {
                kv.commit(item.req, item.n_tokens).unwrap();
                let r = table.get_mut(item.req).unwrap();
                r.ctx_len += item.n_tokens;
                if r.ctx_len == r.feed_target() {
                    r.generated += 1;
                }
            }
            assert!(out.plan.items.iter().all(|i| i.req == id));
            if table[id].is_done() {
                break;
            }
        }
        assert!(table[id].is_done(), "request must finish via reused outcome");
    }

    #[test]
    fn offline_queue_steal_accessors() {
        let (mut s, mut table, _kv) = setup(Policy::ConServe);
        let a = add(&mut table, Class::Offline, 64, 8);
        let b = add(&mut table, Class::Offline, 64, 8);
        let c = add(&mut table, Class::Offline, 64, 8);
        for id in [a, b, c] {
            s.enqueue(id, Class::Offline);
        }
        let rev: Vec<_> = s.offline_queue_rev().collect();
        assert_eq!(rev, vec![c, b, a], "harvest order is tail-first");
        assert!(s.remove_offline(b));
        assert!(!s.remove_offline(b), "second removal must miss");
        let rev: Vec<_> = s.offline_queue_rev().collect();
        assert_eq!(rev, vec![c, a]);
        assert_eq!(s.offline_waiting(), 2);
    }

    #[test]
    fn fair_share_prefers_urgent_then_starved_tenant() {
        let (mut s, mut table, mut kv) = setup(Policy::ConServe);
        s.cfg.fair_share = true;
        // tenant 1 floods the queue first (a mega-job); tenant 2 submits
        // one urgent request behind it
        for _ in 0..6 {
            let id = add(&mut table, Class::Offline, 2048, 128);
            let r = table.get_mut(id).unwrap();
            r.job = 1;
            r.tenant = 1;
            r.urgency = 0;
            s.enqueue(id, Class::Offline);
        }
        let tight = add(&mut table, Class::Offline, 256, 32);
        {
            let r = table.get_mut(tight).unwrap();
            r.job = 2;
            r.tenant = 2;
            r.urgency = 900;
        }
        s.enqueue(tight, Class::Offline);
        let out = sched_once(&mut s, &mut table, &mut kv, 4096);
        let first_offline = out
            .plan
            .items
            .iter()
            .find(|i| i.class == Class::Offline)
            .expect("offline admitted");
        assert_eq!(first_offline.req, tight, "urgent request jumps the mega-job");
    }

    #[test]
    fn fair_share_balances_equal_urgency_tenants() {
        let (mut s, mut table, _kv) = setup(Policy::ConServe);
        s.cfg.fair_share = true;
        let mk = |table: &mut RequestArena, tenant: u32| {
            let id = add(table, Class::Offline, 512, 64);
            let r = table.get_mut(id).unwrap();
            r.job = u64::from(tenant);
            r.tenant = tenant;
            id
        };
        // queue: two of tenant 1, then one of tenant 2
        let a1 = mk(&mut table, 1);
        let a2 = mk(&mut table, 1);
        let b1 = mk(&mut table, 2);
        for id in [a1, a2, b1] {
            s.enqueue(id, Class::Offline);
        }
        // tenant 1 already consumed an admission's worth of service
        s.charge_tenant(1, 512 * 16);
        assert_eq!(s.pick_offline_index(&table), 2, "starved tenant 2 first");
        // a first-seen tenant joins at the current floor (tenant 1's
        // account), so its total = floor + its own charge — lifetime
        // totals never let a newcomer out-rank everyone indefinitely
        s.charge_tenant(2, 512 * 16 * 2);
        assert_eq!(s.pick_offline_index(&table), 0, "FIFO among the rest");
        assert_eq!(s.tenant_deficit(1), 512 * 16);
        assert_eq!(s.tenant_deficit(2), 512 * 16 + 512 * 16 * 2);
        assert_eq!(s.tenant_deficit(3), 0);
    }

    #[test]
    fn memory_pressure_evicts_checkpointed_victim_first() {
        let (mut s, mut table, _) = setup(Policy::ConServe);
        // two offline requests holding most of a small pool
        let mut small = KvManager::new(16, 64, 16);
        let mut offline_ids = Vec::new();
        for _ in 0..2 {
            let id = add(&mut table, Class::Offline, 96, 8);
            small.register(id);
            small.grow(id, 96).unwrap();
            small.commit(id, 96).unwrap();
            table.get_mut(id).unwrap().state = State::Running;
            table.get_mut(id).unwrap().ctx_len = 96;
            s.running.push(id);
            offline_ids.push(id);
        }
        let (ck, unck) = (offline_ids[0], offline_ids[1]);
        // request `ck` fully checkpointed, `unck` not
        for i in small.checkpoint_candidates(ck) {
            small.begin_ckpt(ck, i).unwrap();
            small.finish_ckpt(ck, i);
        }
        // an online request arrives needing more blocks than are free
        let on = add(&mut table, Class::Online, 128, 8);
        s.enqueue(on, Class::Online);
        let out = sched_once(&mut s, &mut table, &mut small, 4096);
        assert!(out.evicted.contains(&ck), "checkpointed victim evicted: {out:?}");
        assert!(!out.discarded.contains(&unck), "non-ckpt victim spared if possible");
        assert_eq!(table[ck].residence, KvResidence::Host);
        assert!(out.plan.items.iter().any(|i| i.req == on));
    }

    #[test]
    fn vllmpp_admission_never_preempts() {
        // the paper's §3 contrast: vLLM++ cannot preempt scheduled work
        // to admit an online request — it waits for free memory
        let (mut s, mut table, _) = setup(Policy::VllmPP);
        let mut small = KvManager::new(8, 64, 16);
        let off = add(&mut table, Class::Offline, 128, 8);
        small.register(off);
        small.grow(off, 128).unwrap();
        small.commit(off, 128).unwrap();
        table.get_mut(off).unwrap().state = State::Running;
        table.get_mut(off).unwrap().ctx_len = 128;
        s.running.push(off);

        let on = add(&mut table, Class::Online, 64, 8);
        s.enqueue(on, Class::Online);
        let out = sched_once(&mut s, &mut table, &mut small, 4096);
        assert!(out.swapped_out.is_empty(), "no admission-time preemption");
        assert!(!out.plan.items.iter().any(|i| i.req == on), "online waits");
        assert_eq!(s.online_waiting(), 1);
        assert_eq!(table[off].residence, KvResidence::Gpu);
    }

    #[test]
    fn vllmpp_growth_swaps_out_youngest_blocking() {
        // vanilla-vLLM growth preemption: class-blind, newest victim,
        // blocking swap-out (Fig. 4b)
        let (mut s, mut table, _) = setup(Policy::VllmPP);
        let mut small = KvManager::new(8, 64, 16);
        // old offline decode occupying half the pool
        let off = add(&mut table, Class::Offline, 64, 8);
        // younger online decode occupying the rest; growth of 1 forces
        // preemption of the *newest* sequence — which is itself online
        let on = add(&mut table, Class::Online, 64, 8);
        // on's next decode fits its current block (63->64); off's does not
        // (64->65), so the offline growth is what triggers preemption
        for (id, tokens, arrival) in [(off, 64usize, 0u64), (on, 63, 10)] {
            small.register(id);
            small.grow(id, tokens).unwrap();
            small.commit(id, tokens).unwrap();
            let r = table.get_mut(id).unwrap();
            r.state = State::Running;
            r.ctx_len = tokens;
            r.prompt_len = tokens;
            r.generated = 1;
            r.arrival = arrival;
            s.running.push(id);
        }
        // pool: 4 + 4 blocks used, 0 free; `off`'s decode needs block 5
        let out = sched_once(&mut s, &mut table, &mut small, 4096);
        assert_eq!(out.swapped_out, vec![on], "newest (online!) swapped out");
        assert!(out.blocking_io_blocks > 0);
        assert_eq!(table[on].residence, KvResidence::Host);
        assert!(out.plan.items.iter().any(|i| i.req == off));
    }

    #[test]
    fn conserve_discards_uncheckpointed_victim() {
        let (mut s, mut table, _) = setup(Policy::ConServe);
        let mut small = KvManager::new(8, 64, 16);
        let off = add(&mut table, Class::Offline, 128, 8);
        small.register(off);
        small.grow(off, 128).unwrap();
        small.commit(off, 128).unwrap();
        table.get_mut(off).unwrap().state = State::Running;
        table.get_mut(off).unwrap().ctx_len = 128;
        s.running.push(off);

        let on = add(&mut table, Class::Online, 64, 8);
        s.enqueue(on, Class::Online);
        let out = sched_once(&mut s, &mut table, &mut small, 4096);
        assert_eq!(out.discarded, vec![off]);
        let r = &table[off];
        assert_eq!(r.ctx_len, 0);
        assert_eq!(r.recomputed_tokens, 128);
        assert_eq!(r.residence, KvResidence::Discarded);
        // and it resumes from the front of the offline queue
        assert_eq!(s.offline_q.front(), Some(&off));
    }

    #[test]
    fn slo_budget_limits_offline_alongside_decodes() {
        let (mut s, mut table, mut kv) = setup(Policy::ConServe);
        // a running online decode with large context
        let on = add(&mut table, Class::Online, 1024, 128);
        {
            let r = table.get_mut(on).unwrap();
            r.state = State::Running;
            r.ctx_len = 2048;
            r.prompt_len = 2048;
            r.generated = 1;
        }
        kv.register(on);
        kv.grow(on, 2049).unwrap();
        kv.commit(on, 2048).unwrap();
        s.running.push(on);

        let off = add(&mut table, Class::Offline, 8192, 128);
        s.enqueue(off, Class::Offline);
        let out = sched_once(&mut s, &mut table, &mut kv, 16384);
        let offline_tokens: usize = out
            .plan
            .items
            .iter()
            .filter(|i| i.class == Class::Offline)
            .map(|i| i.n_tokens)
            .sum();
        assert!(offline_tokens <= out.token_budget);
        // TPOT budget (110 ms) at one decode: ~1.1k tokens of prefill
        assert!(out.token_budget < 1500, "budget={}", out.token_budget);
    }
}
