"""L1 Pallas kernel: fused cached causal attention (flash-style).

This is the compute hot-spot of the serving path: every prefill chunk and
every decode step runs it once per layer. One kernel serves both phases —
decode is the T=1 case — like the flash/paged decode kernels in vLLM, but
expressed for the TPU memory hierarchy:

Hardware adaptation (paper targets A100/CUDA; see DESIGN.md):
  * the CUDA version streams KV through shared memory per threadblock;
    here `BlockSpec` stages the (batch-row, KV-head) tile of the cache from
    HBM into VMEM, and the kernel streams it in `block_k`-sized chunks with
    an online-softmax (running max / sum / accumulator) carried in f32 —
    the BlockSpec + inner loop *are* the HBM<->VMEM schedule that the CUDA
    version expressed with threadblocks.
  * tiles are MXU-shaped: the [T, BK] score GEMM and the [BK, Dh] value
    GEMM keep the contracted/lane dimensions at multiples of (8, 128)
    where the model dims allow; `preferred_element_type=f32` pins MXU
    accumulation width.
  * masking is positional (ctx_lens scalar per row), so padded cache slots
    beyond the causal frontier are never attended.

Compiled with interpret=True: the CPU PJRT client cannot execute Mosaic
custom-calls; numerics are validated against kernels.ref.attention_ref by
pytest + hypothesis. VMEM footprint / MXU utilization estimates live in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sequence-dimension streaming chunk. 128 matches the TPU lane width; the
# tiny real-path model uses S=256 so the stream is 2 chunks long.
DEFAULT_BLOCK_K = 128

# Large-negative instead of -inf: keeps the running max finite for rows
# whose first chunks are fully masked, avoiding inf-inf = nan.
NEG_INF = -1e30


def _attn_kernel(ctx_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int):
    """One grid step handles one (batch row, query head).

    q_ref: [1, 1, T, Dh]; k_ref/v_ref: [1, 1, S, Dh] (this row's KV head);
    ctx_ref: [1] i32; o_ref: [1, 1, T, Dh].
    """
    T, Dh = q_ref.shape[2], q_ref.shape[3]
    S = k_ref.shape[2]
    nblk = S // block_k

    q = q_ref[0, 0].astype(jnp.float32)  # [T, Dh]
    ctx = ctx_ref[0]
    scale = 1.0 / (Dh ** 0.5)
    qpos = ctx + jax.lax.broadcasted_iota(jnp.int32, (T, block_k), 0)

    def body(blk, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, 0, pl.dslice(blk * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, 0, pl.dslice(blk * block_k, block_k), slice(None)))
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [T, BK]
        kpos = blk * block_k + jax.lax.broadcasted_iota(jnp.int32, (T, block_k), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    init = (
        jnp.full((T, 1), NEG_INF, jnp.float32),
        jnp.zeros((T, 1), jnp.float32),
        jnp.zeros((T, Dh), jnp.float32),
    )
    _, l, acc = jax.lax.fori_loop(0, nblk, body, init)
    # Every query row attends at least slot 0 (kpos 0 <= qpos always), so
    # l >= exp(NEG_INF-m)·… > 0; no division guard needed.
    o_ref[0, 0] = (acc / l).astype(o_ref.dtype)


def attention(
    q: jax.Array,         # [B, H, T, Dh], RoPE applied
    k_cache: jax.Array,   # [B, Hkv, S, Dh], new tokens already written
    v_cache: jax.Array,   # [B, Hkv, S, Dh]
    ctx_lens: jax.Array,  # [B] i32, context length BEFORE this chunk
    *,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Fused flash-style attention over a per-sequence KV cache (GQA-aware).

    Query t of row b sits at absolute position ctx_lens[b] + t and attends
    cache slots s <= that position. Returns [B, H, T, Dh].
    """
    B, H, T, Dh = q.shape
    _, Hkv, S, _ = k_cache.shape
    assert H % Hkv == 0, "query heads must be a multiple of KV heads"
    bk = min(block_k, S)
    assert S % bk == 0, f"S={S} not tileable by block_k={bk}"
    group = H // Hkv

    kernel = functools.partial(_attn_kernel, block_k=bk)
    return pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h: (b,)),
            pl.BlockSpec((1, 1, T, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, Dh), lambda b, h: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, S, Dh), lambda b, h: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, T, Dh), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, Dh), q.dtype),
        interpret=interpret,
    )(ctx_lens, q, k_cache, v_cache)
