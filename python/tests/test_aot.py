"""AOT pipeline sanity: lowerings produce parseable HLO text, the manifest
is self-consistent, and weights.bin matches the tensor index.

These tests lower a couple of representative entries in-process (they do
not require `make artifacts` to have run), then — if artifacts/ exists —
validate the emitted manifest against the on-disk files.
"""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model
from compile.configs import EXPORT, MODEL, param_specs

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lowering_produces_hlo_text():
    specs = aot.entry_specs(MODEL, 1, 16)["layer"]
    fn = aot.entry_fns(MODEL)["layer"]
    lowered = jax.jit(aot.wrap_tuple(fn)).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "ROOT" in text
    # layered export must contain the cache-update scatter/DUS and the
    # attention GEMMs
    assert "dot(" in text or "dot." in text


def test_embed_entry_is_tuple():
    specs = aot.entry_specs(MODEL, 1, 1)["embed"]
    fn = aot.entry_fns(MODEL)["embed"]
    text = aot.to_hlo_text(jax.jit(aot.wrap_tuple(fn)).lower(*specs))
    # return_tuple=True: root instruction is a tuple
    assert "tuple(" in text


def test_param_specs_cover_weights():
    params = model.init_params(MODEL, EXPORT.seed)
    names = [n for n, _ in param_specs(MODEL)]
    assert set(names) == set(params.keys())
    total = sum(int(np.prod(s)) for _, s in param_specs(MODEL))
    assert total == sum(int(np.prod(p.shape)) for p in params.values())


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_consistent_with_disk():
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)

    # every entry file exists and is non-trivial HLO text
    for e in m["entries"]:
        p = os.path.join(ART, e["file"])
        assert os.path.exists(p), e["file"]
        with open(p) as f:
            head = f.read(4096)
        assert "HloModule" in head

    # weights.bin length == sum of tensor numels * 4 bytes, offsets contiguous
    size = os.path.getsize(os.path.join(ART, m["weights_file"]))
    offset = 0
    for t in m["tensors"]:
        assert t["offset"] == offset
        assert t["numel"] == int(np.prod(t["shape"]))
        offset += t["numel"]
    assert size == offset * 4

    # bucket grid covered for the layered entries
    kinds = {(e["kind"], e["batch"], e["chunk"]) for e in m["entries"]}
    for b in m["buckets"]["batch"]:
        for t in m["buckets"]["chunk"]:
            for kind in ("embed", "layer", "head"):
                assert (kind, b, t) in kinds


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "weights.bin")),
    reason="artifacts not built",
)
def test_weights_bin_reproducible():
    """weights.bin must be the deterministic seed-derived values."""
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    params = model.init_params(MODEL, m["seed"])
    raw = np.fromfile(os.path.join(ART, "weights.bin"), dtype="<f4")
    for t in m["tensors"]:
        got = raw[t["offset"] : t["offset"] + t["numel"]].reshape(t["shape"])
        np.testing.assert_allclose(got, params[t["name"]], rtol=1e-6, atol=1e-6)
