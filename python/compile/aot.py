"""AOT pipeline: lower every model entry point to HLO *text* artifacts.

Run once at build time (`make artifacts`); Python never touches the
request path. Emits into the output directory:

  * `<entry>_b<B>_t<T>.hlo.txt` — HLO text per (entry point, bucket).
    Text, NOT `.serialize()`: jax >= 0.5 emits HloModuleProtos with 64-bit
    instruction ids that the xla crate's xla_extension 0.5.1 rejects
    (`proto.id() <= INT_MAX`); the text parser reassigns ids and
    round-trips cleanly (see /opt/xla-example/README.md).
  * `weights.bin` — all parameters, little-endian f32, concatenated in
    configs.param_specs order.
  * `manifest.json` — model config, bucket grid, tensor index (name,
    shape, offset), entry-point index, and per-entry argument order; the
    Rust runtime is driven entirely by this file.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .configs import EXPORT, LAYER_WEIGHT_NAMES, MODEL, param_specs

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_specs(cfg, B, T):
    """Argument ShapeDtypeStructs per entry kind, in call order."""
    D, Dh = cfg.d_model, cfg.head_dim
    Hkv, S, V, F = cfg.n_kv_heads, cfg.max_seq, cfg.vocab_size, cfg.d_ffn
    cache = spec((B, Hkv, S, Dh))
    return {
        "embed": [spec((B, T), I32), spec((V, D))],
        "layer": [
            spec((B, T, D)), cache, cache, spec((B,), I32),
            spec((D,)), spec((D, cfg.q_dim)), spec((D, cfg.kv_dim)),
            spec((D, cfg.kv_dim)), spec((cfg.q_dim, D)), spec((D,)),
            spec((D, F)), spec((D, F)), spec((F, D)),
        ],
        "head": [spec((B, T, D)), spec((D,)), spec((D, V))],
        "full": [
            spec((B, T), I32),
            spec((cfg.n_layers, B, Hkv, S, Dh)),
            spec((cfg.n_layers, B, Hkv, S, Dh)),
            spec((B,), I32),
        ] + [spec(shape) for _, shape in param_specs(cfg)],
    }


def entry_fns(cfg):
    return {
        "embed": model.embed,
        "layer": functools.partial(model.layer_fwd, cfg),
        "head": functools.partial(model.lm_head, cfg),
        "full": functools.partial(model.model_full, cfg),
    }


def wrap_tuple(fn):
    """Ensure the lowered computation returns a tuple (uniform unwrap)."""

    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


# The monolithic entry is only used by the safepoint-overhead bench; keep
# the artifact set small by exporting it at two representative buckets.
FULL_BUCKETS = ((8, 1), (4, 16))


def export_weights(cfg, seed, out_dir):
    params = model.init_params(cfg, seed)
    tensors = []
    offset = 0
    blobs = []
    for name, shape in param_specs(cfg):
        arr = np.asarray(params[name], dtype="<f4")
        assert tuple(arr.shape) == tuple(shape), name
        tensors.append(
            {"name": name, "shape": list(shape), "offset": offset, "numel": arr.size}
        )
        offset += arr.size
        blobs.append(arr.tobytes())
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for b in blobs:
            f.write(b)
    return tensors


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    cfg, exp = MODEL, EXPORT
    os.makedirs(args.out, exist_ok=True)

    tensors = export_weights(cfg, exp.seed, args.out)
    fns = entry_fns(cfg)

    entries = []
    jobs = []
    for B in exp.batch_buckets:
        for T in exp.chunk_buckets:
            jobs += [("embed", B, T), ("layer", B, T), ("head", B, T)]
    jobs += [("full", B, T) for (B, T) in FULL_BUCKETS]

    for kind, B, T in jobs:
        name = f"{kind}_b{B}_t{T}"
        specs = entry_specs(cfg, B, T)[kind]
        lowered = jax.jit(wrap_tuple(fns[kind])).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        entries.append(
            {"name": name, "kind": kind, "batch": B, "chunk": T, "file": fname}
        )
        print(f"  lowered {name}: {len(text)} chars")

    manifest = {
        "model": {
            "vocab_size": cfg.vocab_size,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "head_dim": cfg.head_dim,
            "d_ffn": cfg.d_ffn,
            "max_seq": cfg.max_seq,
            "rope_theta": cfg.rope_theta,
            "norm_eps": cfg.norm_eps,
        },
        "buckets": {
            "batch": list(exp.batch_buckets),
            "chunk": list(exp.chunk_buckets),
        },
        "seed": exp.seed,
        "weights_file": "weights.bin",
        "tensors": tensors,
        "layer_weight_order": list(LAYER_WEIGHT_NAMES),
        "entries": entries,
        "arg_order": {
            "embed": ["tokens", "embedding"],
            "layer": ["hidden", "k_cache", "v_cache", "ctx_lens"]
            + list(LAYER_WEIGHT_NAMES),
            "head": ["hidden", "final_norm", "lm_head"],
            "full": ["tokens", "k_caches", "v_caches", "ctx_lens", "*params"],
        },
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} entries to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
