//! AOT artifact loading: parses `artifacts/manifest.json`, loads
//! `weights.bin` into per-tensor literals, and lazily compiles the HLO
//! text entries on the PJRT CPU client.
//!
//! HLO *text* is the interchange format (not serialized protos): jax
//! >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Model dimensions from the manifest (mirrors python configs.ModelConfig).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelDims {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ffn: usize,
    pub max_seq: usize,
}

impl ModelDims {
    /// f32 elements in one sequence's per-layer KV slab ([Hkv, S, Dh]).
    pub fn slab_elems(&self) -> usize {
        self.n_kv_heads * self.max_seq * self.head_dim
    }

    /// KV bytes per token across all layers (f32 K + V).
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * self.n_layers * self.n_kv_heads * self.head_dim * 4) as u64
    }
}

#[derive(Debug, Clone)]
pub struct TensorInfo {
    pub shape: Vec<usize>,
    pub offset: usize,
    pub numel: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EntryKey {
    pub kind: EntryKind,
    pub batch: usize,
    pub chunk: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EntryKind {
    Embed,
    Layer,
    Head,
    Full,
}

impl EntryKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "embed" => EntryKind::Embed,
            "layer" => EntryKind::Layer,
            "head" => EntryKind::Head,
            "full" => EntryKind::Full,
            other => bail!("unknown entry kind `{other}`"),
        })
    }
}

/// Loaded artifacts: weights as literals + lazily compiled executables.
pub struct Artifacts {
    pub dims: ModelDims,
    pub batch_buckets: Vec<usize>,
    pub chunk_buckets: Vec<usize>,
    pub layer_weight_order: Vec<String>,
    dir: PathBuf,
    client: xla::PjRtClient,
    tensors: HashMap<String, TensorInfo>,
    weights_raw: Vec<f32>,
    weight_literals: HashMap<String, xla::Literal>,
    entry_files: HashMap<EntryKey, String>,
    executables: HashMap<EntryKey, xla::PjRtLoadedExecutable>,
}

impl Artifacts {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let m = Json::parse(&text).context("parsing manifest.json")?;

        let md = m.req("model");
        let dim = |k: &str| -> usize { md.req(k).as_usize().unwrap() };
        let dims = ModelDims {
            vocab_size: dim("vocab_size"),
            d_model: dim("d_model"),
            n_layers: dim("n_layers"),
            n_heads: dim("n_heads"),
            n_kv_heads: dim("n_kv_heads"),
            head_dim: dim("head_dim"),
            d_ffn: dim("d_ffn"),
            max_seq: dim("max_seq"),
        };

        let buckets = |k: &str| -> Vec<usize> {
            m.req("buckets")
                .req(k)
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect()
        };

        let mut tensors = HashMap::new();
        for t in m.req("tensors").as_arr().unwrap() {
            tensors.insert(
                t.req("name").as_str().unwrap().to_string(),
                TensorInfo {
                    shape: t
                        .req("shape")
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|v| v.as_usize().unwrap())
                        .collect(),
                    offset: t.req("offset").as_usize().unwrap(),
                    numel: t.req("numel").as_usize().unwrap(),
                },
            );
        }

        let weights_file = dir.join(m.req("weights_file").as_str().unwrap());
        let raw = std::fs::read(&weights_file)
            .with_context(|| format!("reading {weights_file:?}"))?;
        if raw.len() % 4 != 0 {
            bail!("weights.bin length not a multiple of 4");
        }
        let weights_raw: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let total: usize = tensors.values().map(|t| t.numel).sum();
        if total != weights_raw.len() {
            bail!(
                "weights.bin has {} f32s but manifest expects {total}",
                weights_raw.len()
            );
        }

        let mut entry_files = HashMap::new();
        for e in m.req("entries").as_arr().unwrap() {
            let key = EntryKey {
                kind: EntryKind::parse(e.req("kind").as_str().unwrap())?,
                batch: e.req("batch").as_usize().unwrap(),
                chunk: e.req("chunk").as_usize().unwrap(),
            };
            entry_files.insert(key, e.req("file").as_str().unwrap().to_string());
        }

        let layer_weight_order = m
            .req("layer_weight_order")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap().to_string())
            .collect();

        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;

        let mut art = Self {
            dims,
            batch_buckets: buckets("batch"),
            chunk_buckets: buckets("chunk"),
            layer_weight_order,
            dir,
            client,
            tensors,
            weights_raw,
            weight_literals: HashMap::new(),
            entry_files,
            executables: HashMap::new(),
        };
        art.build_weight_literals()?;
        Ok(art)
    }

    fn build_weight_literals(&mut self) -> Result<()> {
        let names: Vec<String> = self.tensors.keys().cloned().collect();
        for name in names {
            let info = self.tensors[&name].clone();
            let data = &self.weights_raw[info.offset..info.offset + info.numel];
            let lit = f32_literal(data, &info.shape)?;
            self.weight_literals.insert(name, lit);
        }
        Ok(())
    }

    pub fn tensor_data(&self, name: &str) -> Option<&[f32]> {
        let info = self.tensors.get(name)?;
        Some(&self.weights_raw[info.offset..info.offset + info.numel])
    }

    pub fn weight(&self, name: &str) -> &xla::Literal {
        &self.weight_literals[name]
    }

    /// Weight literals of layer `l` in the entry-point argument order.
    pub fn layer_weights(&self, l: usize) -> Vec<&xla::Literal> {
        self.layer_weight_order
            .iter()
            .map(|role| self.weight(&format!("layers.{l}.{role}")))
            .collect()
    }

    /// Compile (once) and return the executable for an entry bucket.
    pub fn executable(&mut self, key: EntryKey) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(&key) {
            let file = self
                .entry_files
                .get(&key)
                .ok_or_else(|| anyhow!("no artifact for {key:?}"))?;
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("loading {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {file}: {e}"))?;
            self.executables.insert(key, exe);
        }
        Ok(&self.executables[&key])
    }

    pub fn has_entry(&self, key: EntryKey) -> bool {
        self.entry_files.contains_key(&key)
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn f32_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .map_err(|e| anyhow!("f32 literal {shape:?}: {e}"))
}

/// Build an i32 literal of the given shape from a slice.
pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
        .map_err(|e| anyhow!("i32 literal {shape:?}: {e}"))
}
