//! `bench_trace` — flight-recorder acceptance bench: tracing must be
//! (nearly) free, and the exported artifacts must be structurally
//! sound.
//!
//! Runs the skewed steal workload (offline burst pinned to shard 0, so
//! migrations are guaranteed) twice per repetition — tracing off, then
//! tracing on — in alternation, and takes the best wall time per mode
//! so a single noisy neighbour cannot decide the verdict.
//!
//! Acceptance (asserted here):
//!
//! * tracing-on throughput is ≥ 97 % of tracing-off (the emit path is
//!   a few relaxed atomic stores — it must not show up);
//! * the Perfetto export validates: a JSON array, one named track per
//!   shard, `X` iteration slices with durations, and flow ids that
//!   link a donate on one track to an absorb on another (requests are
//!   followable across migration);
//! * request spans are well-formed: every span reaches a terminal
//!   event, none are orphaned.
//!
//! Results go to `BENCH_trace.json` (schema: rust/PERF.md §11); the
//! Perfetto file itself goes to `BENCH_trace.perfetto.json`. Scale
//! with `TRACE_BENCH_REQS` (default 20_000; CI smoke uses a small
//! value).

use conserve::config::EngineConfig;
use conserve::request::{Class, Request};
use conserve::shard::{run_sharded_traces_with, ShardedRun, StealConfig};
use conserve::trace::{analyze_spans, perfetto, FleetTracer};
use conserve::util::json::{num, obj, Json};
use conserve::util::rng::Rng;
use conserve::workload::trace::onoff_trace;
use std::sync::Arc;
use std::time::Instant;

const N_SHARDS: usize = 4;

/// Online spread evenly, offline burst pinned to shard 0 (guarantees
/// steal migrations, hence cross-track flow arrows in the export).
fn skewed_traces(n_reqs: usize) -> (Vec<Vec<Request>>, f64) {
    let n_online = n_reqs * 3 / 4;
    let n_offline = n_reqs - n_online;
    let on_rate = 60.0;
    let duration_s = 2.0 * n_online as f64 / on_rate;
    let arrivals = onoff_trace(42, duration_s, 30.0, on_rate, 2.0);
    let mut rng = Rng::new(7);
    let mut traces: Vec<Vec<Request>> = (0..N_SHARDS).map(|_| Vec::new()).collect();
    let mut next_id = 1u64;
    for (i, &t) in arrivals.iter().take(n_online).enumerate() {
        let input = rng.range_usize(64, 256);
        let output = rng.range_usize(8, 24);
        traces[i % N_SHARDS].push(Request::new(next_id, Class::Online, vec![], input, output, t));
        next_id += 1;
    }
    for _ in 0..n_offline {
        let input = rng.range_usize(512, 2048);
        let output = rng.range_usize(32, 96);
        traces[0].push(Request::new(next_id, Class::Offline, vec![], input, output, 0));
        next_id += 1;
    }
    (traces, duration_s)
}

fn run_mode(
    cfg: &EngineConfig,
    traces: &[Vec<Request>],
    duration_s: f64,
    tracer: Option<Arc<FleetTracer>>,
) -> (f64, ShardedRun) {
    let t0 = Instant::now();
    let (run, _) = run_sharded_traces_with(
        cfg,
        traces.to_vec(),
        duration_s,
        Some(StealConfig::default()),
        |e| {
            if let Some(t) = &tracer {
                e.set_tracer(t.shard(e.shard()));
            }
        },
        |_| (),
    );
    (t0.elapsed().as_secs_f64(), run)
}

fn main() {
    let n_reqs: usize = std::env::var("TRACE_BENCH_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);
    let (traces, duration_s) = skewed_traces(n_reqs);
    let n_events: usize = traces.iter().map(Vec::len).sum();
    let cfg = EngineConfig::sim_a100_7b();
    // ring sized to hold the whole run so the span check is exact
    let ring_cap = (n_events * 16 / N_SHARDS + 65_536).next_power_of_two();
    let reps: usize = if n_events <= 20_000 { 5 } else { 3 };

    println!("=== bench_trace ({n_events} requests, {N_SHARDS} shards, {reps} reps/mode) ===");
    let mut best_off = f64::INFINITY;
    let mut best_on = f64::INFINITY;
    let mut last_tracer: Option<Arc<FleetTracer>> = None;
    let mut last_run: Option<ShardedRun> = None;
    for rep in 0..reps {
        let (w_off, run_off) = run_mode(&cfg, &traces, duration_s * 6.0, None);
        let tracer = FleetTracer::new(N_SHARDS, ring_cap);
        let (w_on, run_on) = run_mode(&cfg, &traces, duration_s * 6.0, Some(tracer.clone()));
        let same = run_off.merged.online_finished + run_off.merged.offline_finished
            == run_on.merged.online_finished + run_on.merged.offline_finished;
        assert!(same, "tracing must not change what the fleet serves");
        println!(
            "  rep {rep}: off {w_off:.3}s  on {w_on:.3}s  ({} events, {} dropped)",
            tracer.total_events(),
            tracer.dropped()
        );
        best_off = best_off.min(w_off);
        best_on = best_on.min(w_on);
        last_tracer = Some(tracer);
        last_run = Some(run_on);
    }
    let tracer = last_tracer.unwrap();
    let run = last_run.unwrap();
    // same work both modes, so the throughput ratio is the wall ratio
    let throughput_ratio = best_off / best_on;
    println!(
        "best wall: off {best_off:.3}s  on {best_on:.3}s  → tracing-on throughput {:.1}% of off",
        throughput_ratio * 100.0
    );

    // ---- acceptance: overhead ----
    assert!(
        throughput_ratio >= 0.97,
        "tracing costs more than 3% throughput: on/off ratio {throughput_ratio:.4}"
    );

    // ---- acceptance: export validity ----
    assert!(
        run.merged.steals_in > 0,
        "the skewed trace must trigger migrations (got none)"
    );
    let text = perfetto::export_perfetto(&tracer);
    let st = perfetto::validate(&text).expect("export must be valid trace-event JSON");
    assert_eq!(st.tracks, N_SHARDS, "one named track per shard");
    assert!(st.iterations > 0, "iteration slices must be present");
    assert!(st.flow_starts > 0 && st.flow_ends > 0, "steal flows must be present");
    assert!(
        st.flows_linked > 0,
        "flow ids must link donates to absorbs across tracks"
    );

    // ---- acceptance: span well-formedness ----
    let had_drops = tracer.dropped() > 0;
    let rep = analyze_spans(&tracer.merged(), &[], had_drops, had_drops);
    assert!(rep.spans > 0);
    assert!(
        rep.ok(),
        "orphan request spans in the trace: {:?} (of {})",
        &rep.orphans[..rep.orphans.len().min(8)],
        rep.spans
    );
    println!(
        "perfetto: {} events on {} tracks, {} iterations, {} linked flows; {} spans ({} finished)",
        st.events, st.tracks, st.iterations, st.flows_linked, rep.spans, rep.finished
    );

    // ---- emit BENCH_trace.json + the Perfetto artifact ----
    let json = obj(vec![
        ("requests", num(n_events as f64)),
        ("shards", num(N_SHARDS as f64)),
        ("reps_per_mode", num(reps as f64)),
        ("ring_capacity", num(ring_cap as f64)),
        ("wall_off_s", num(best_off)),
        ("wall_on_s", num(best_on)),
        ("throughput_ratio", num(throughput_ratio)),
        ("trace_events", num(tracer.total_events() as f64)),
        ("trace_dropped", num(tracer.dropped() as f64)),
        (
            "perfetto",
            obj(vec![
                ("events", num(st.events as f64)),
                ("tracks", num(st.tracks as f64)),
                ("iterations", num(st.iterations as f64)),
                ("flow_starts", num(st.flow_starts as f64)),
                ("flow_ends", num(st.flow_ends as f64)),
                ("flows_linked", num(st.flows_linked as f64)),
            ]),
        ),
        ("perfetto_ok", num(1.0)),
        (
            "spans",
            obj(vec![
                ("spans", num(rep.spans as f64)),
                ("finished", num(rep.finished as f64)),
                ("killed", num(rep.killed as f64)),
                ("orphans", num(rep.orphans.len() as f64)),
            ]),
        ),
    ]);
    let out_path = std::env::var("TRACE_BENCH_OUT").unwrap_or_else(|_| "BENCH_trace.json".into());
    std::fs::write(&out_path, json.to_string()).expect("write BENCH_trace.json");
    let pf_path = std::env::var("TRACE_BENCH_PERFETTO_OUT")
        .unwrap_or_else(|_| "BENCH_trace.perfetto.json".into());
    std::fs::write(&pf_path, &text).expect("write perfetto artifact");
    println!("\nwrote {out_path} and {pf_path}");
    let _ = Json::parse(&json.to_string()).expect("self-emitted json parses");
    println!("bench_trace OK");
}
