//! Multi-worker sharded serving: N independent engines behind one
//! placement layer.
//!
//! ConServe's fine-grained resource management (token budgets,
//! sub-iteration preemption, incremental KV) is a *per-GPU* story;
//! scaling it to heavy traffic means running many such engines side by
//! side with cheap, allocation-free routing. A **shard** is one complete
//! worker: its own [`RequestArena`](crate::request::RequestArena),
//! [`KvManager`](crate::kvcache::KvManager) + block pools, and
//! [`UnifiedScheduler`](crate::scheduler::UnifiedScheduler) driving one
//! backend. Shards share *nothing* on the hot path — no lock, no table,
//! no allocator — the only cross-shard traffic is submission-time
//! placement and the relaxed-atomic load summaries ([`ShardLoads`]) that
//! feed it.
//!
//! Routing rides on the id layout: [`RequestId`] packs **(generation:32 |
//! shard:8 | slot:24)**, so resolving a ticket to its owner is a
//! mask+shift ([`rid_shard`](crate::request::rid_shard)), and every
//! shard's arena and KV table
//! reject ids whose shard bits are not theirs — a stale or misrouted id
//! can never alias state in another shard (see `tests/shard_props.rs`).
//!
//! Two frontends mirror the single-worker engine's:
//!
//! * [`ShardRouter`] — trace mode: partition a pre-generated request
//!   trace across shards with a [`Placement`] policy, then run each
//!   bucket on its own worker thread ([`run_sharded_sim`]) and merge the
//!   per-shard recorders into one aggregate [`Report`].
//! * [`ShardedClient`] — live mode: per-shard [`EngineClient`]s behind
//!   one submission handle; placement reads the [`ShardLoads`] snapshots
//!   the engines publish each iteration.
//!
//! Fleet runs are *supervised* ([`supervisor`]): every worker executes
//! inside a panic-isolation boundary, a dead shard is retired from the
//! steal protocol (its mailbox drains to the orphan pool, so nothing
//! migrated is stranded), and [`run_sharded_traces_supervised`] reports
//! deaths as structured [`ShardDied`] values on the [`FleetRun`]
//! instead of propagating the panic. Deterministic fault injection
//! ([`crate::util::fault`]) exercises every failure path; the failure
//! model and recovery sequence live in `rust/ARCHITECTURE.md` §8.
//!
//! The scaling acceptance bench is `cargo bench --bench
//! bench_shard_scale` (results: `BENCH_shard.json`; schema in
//! `rust/PERF.md`).

pub mod placement;
pub mod steal;
pub mod supervisor;

use crate::backend::{CostModel, ExecBackend, SimBackend};
use crate::batch::{tier_weight, JobBoard, JobSpec};
use crate::clock::Clock;
use crate::config::EngineConfig;
use crate::kvcache::prefix::digest_insert;
use crate::kvcache::{prefix_probes, PREFIX_DIGEST_WORDS};
use crate::metrics::Recorder;
use crate::profiler::LatencyProfile;
use crate::report::Report;
use crate::request::{Class, Request, RequestId, TokenId, MAX_SHARDS};
use crate::server::{ArrivalSource, EngineClient, ServingEngine, SubmitError};
use crate::{TimeUs, US_PER_SEC};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

pub use placement::{LoadSnapshot, Placement};

/// Cap on per-request prefix probes computed at routing/placement time:
/// bounds the hashing cost per submission while still covering prompts
/// far longer than any realistic shared prefix (64 blocks = 1024 tokens
/// at the default 16-token blocks).
pub const ROUTE_PROBE_CAP: usize = 64;
pub use steal::{MigratedRequest, StealConfig, StealCoordinator};
pub use supervisor::{FleetSupervisor, ShardDied};

/// Lock-free per-shard load board. Engines publish a summary once per
/// scheduling iteration (three relaxed stores); placement reads a
/// snapshot at submission time. Staleness is bounded by one engine
/// iteration, which is exactly the granularity at which load can change.
#[derive(Debug)]
pub struct ShardLoads {
    capacity_blocks: u64,
    cells: Vec<LoadCell>,
}

#[derive(Debug)]
struct LoadCell {
    resident: AtomicU64,
    online: AtomicU64,
    waiting: AtomicU64,
    /// Offline backlog (queued offline requests) — the work-stealing
    /// imbalance signal.
    offline_waiting: AtomicU64,
    /// Decaying recent-thief score (steal-aware placement; see
    /// [`LoadSnapshot::steal_score`]): the engine bumps it by 16 per
    /// adopted steal and decays it x7/8 per publish.
    steal_score: AtomicU64,
    /// Bumped on every publish; lets submitters expire their optimistic
    /// in-flight charges once the engine has seen the queued arrivals.
    seq: AtomicU64,
    /// Live offline token budget as a fraction of the static
    /// `max_batch_tokens`, in permille. 1000 (= full static budget)
    /// unless a harvest controller is actively tightening — published
    /// via [`ShardLoads::publish_budget`], read by the admission
    /// estimator as effective offline capacity.
    budget_permille: AtomicU64,
    /// Cumulative prefix-cache hits / lookups on this shard's engine
    /// (prefix sharing, `kvcache::prefix`) — published via
    /// [`ShardLoads::publish_prefix`], summed into
    /// [`FleetOccupancy`] for the `/healthz` hit rate.
    prefix_hits: AtomicU64,
    prefix_lookups: AtomicU64,
    /// Membership digest of the shard's prefix cache, word by word
    /// (see [`LoadSnapshot::prefix_digest`]). All-zero with the prefix
    /// cache off.
    prefix_digest: [AtomicU64; PREFIX_DIGEST_WORDS],
}

impl Default for LoadCell {
    fn default() -> Self {
        Self {
            resident: AtomicU64::new(0),
            online: AtomicU64::new(0),
            waiting: AtomicU64::new(0),
            offline_waiting: AtomicU64::new(0),
            steal_score: AtomicU64::new(0),
            seq: AtomicU64::new(0),
            // full static budget until a controller says otherwise —
            // fleets without harvesting see unchanged estimates
            budget_permille: AtomicU64::new(1000),
            prefix_hits: AtomicU64::new(0),
            prefix_lookups: AtomicU64::new(0),
            prefix_digest: Default::default(),
        }
    }
}

impl ShardLoads {
    /// A board for `n_shards` shards, each with a GPU KV pool of
    /// `capacity_blocks` blocks.
    pub fn new(n_shards: usize, capacity_blocks: usize) -> Self {
        assert!((1..=MAX_SHARDS).contains(&n_shards));
        Self {
            capacity_blocks: capacity_blocks as u64,
            cells: (0..n_shards).map(|_| LoadCell::default()).collect(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.cells.len()
    }

    /// Publish shard `shard`'s current load (called by its engine once
    /// per iteration; relaxed stores, no synchronization).
    /// `offline_waiting` is the queued-offline share of `waiting` — the
    /// backlog signal the steal coordinator balances — and
    /// `steal_score` is the engine's decayed recent-thief counter
    /// (steal-aware placement bias).
    pub fn publish(
        &self,
        shard: usize,
        resident_blocks: u64,
        online_blocks: u64,
        waiting: u64,
        offline_waiting: u64,
        steal_score: u64,
    ) {
        let c = &self.cells[shard];
        c.resident.store(resident_blocks, Ordering::Relaxed);
        c.online.store(online_blocks, Ordering::Relaxed);
        c.waiting.store(waiting, Ordering::Relaxed);
        c.offline_waiting.store(offline_waiting, Ordering::Relaxed);
        c.steal_score.store(steal_score, Ordering::Relaxed);
        c.seq.fetch_add(1, Ordering::Relaxed);
    }

    /// Publish shard `shard`'s live offline token budget (permille of
    /// the static `max_batch_tokens`). Harvest-enabled engines post
    /// this alongside [`publish`](Self::publish); it has its own setter
    /// so budget-less fleets keep the 1000 default without every
    /// publish call site growing an argument.
    pub fn publish_budget(&self, shard: usize, permille: u64) {
        self.cells[shard]
            .budget_permille
            .store(permille.min(1000), Ordering::Relaxed);
    }

    /// Publish shard `shard`'s prefix-cache state: cumulative attachment
    /// hits/lookups plus the membership digest of its indexed prefix
    /// hashes. Prefix-enabled engines post this alongside
    /// [`publish`](Self::publish); like the budget it has its own
    /// setter so prefix-less fleets never touch these words.
    pub fn publish_prefix(
        &self,
        shard: usize,
        hits: u64,
        lookups: u64,
        digest: &[u64; PREFIX_DIGEST_WORDS],
    ) {
        let c = &self.cells[shard];
        c.prefix_hits.store(hits, Ordering::Relaxed);
        c.prefix_lookups.store(lookups, Ordering::Relaxed);
        for (cell, &w) in c.prefix_digest.iter().zip(digest) {
            cell.store(w, Ordering::Relaxed);
        }
    }

    /// Publish count for `shard`: how many times its engine has posted a
    /// load summary. The sharded client uses advances of this counter to
    /// expire its optimistic in-flight charges (a fresh publish already
    /// reflects the arrivals queued since the last one).
    pub fn publish_seq(&self, shard: usize) -> u64 {
        self.cells[shard].seq.load(Ordering::Relaxed)
    }

    /// Heartbeat: bump `shard`'s publish sequence without touching its
    /// load values. The idle-wait loop of a steal-enabled worker calls
    /// this (it is not iterating, so it publishes nothing), keeping the
    /// sequence advancing while the shard is alive — the liveness
    /// signal [`FleetSupervisor`] samples.
    pub fn beat(&self, shard: usize) {
        self.cells[shard].seq.fetch_add(1, Ordering::Relaxed);
    }

    /// Read one shard's snapshot.
    pub fn snapshot(&self, shard: usize) -> LoadSnapshot {
        let c = &self.cells[shard];
        LoadSnapshot {
            resident_blocks: c.resident.load(Ordering::Relaxed),
            online_blocks: c.online.load(Ordering::Relaxed),
            waiting: c.waiting.load(Ordering::Relaxed),
            offline_waiting: c.offline_waiting.load(Ordering::Relaxed),
            steal_score: c.steal_score.load(Ordering::Relaxed),
            capacity_blocks: self.capacity_blocks,
            prefix_digest: std::array::from_fn(|i| c.prefix_digest[i].load(Ordering::Relaxed)),
        }
    }

    /// Fill `out` with all shards' snapshots (submission path; reuses the
    /// caller's buffer).
    pub fn snapshot_into(&self, out: &mut Vec<LoadSnapshot>) {
        out.clear();
        out.extend((0..self.cells.len()).map(|s| self.snapshot(s)));
    }

    /// Fleet-wide occupancy aggregate — the live capacity signal the
    /// front door's admission controller
    /// ([`crate::server::admission`]) gates on. Staleness is bounded by
    /// one engine iteration per shard, same as placement.
    pub fn fleet_occupancy(&self) -> FleetOccupancy {
        let mut o = FleetOccupancy {
            n_shards: self.cells.len(),
            capacity_blocks: self.capacity_blocks,
            ..Default::default()
        };
        let mut budget_sum = 0u64;
        for c in &self.cells {
            o.resident_blocks += c.resident.load(Ordering::Relaxed);
            o.online_blocks += c.online.load(Ordering::Relaxed);
            o.waiting += c.waiting.load(Ordering::Relaxed);
            o.offline_waiting += c.offline_waiting.load(Ordering::Relaxed);
            o.prefix_hits += c.prefix_hits.load(Ordering::Relaxed);
            o.prefix_lookups += c.prefix_lookups.load(Ordering::Relaxed);
            budget_sum += c.budget_permille.load(Ordering::Relaxed);
        }
        o.budget_permille = budget_sum / self.cells.len().max(1) as u64;
        o
    }
}

/// Summed load-board snapshot across all shards (see
/// [`ShardLoads::fleet_occupancy`]). `capacity_blocks` is *per shard*;
/// the fleet total is `n_shards * capacity_blocks`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FleetOccupancy {
    pub n_shards: usize,
    /// Per-shard GPU KV pool size (blocks).
    pub capacity_blocks: u64,
    /// Σ resident KV blocks across shards.
    pub resident_blocks: u64,
    /// Σ online-reserved KV blocks across shards.
    pub online_blocks: u64,
    /// Σ waiting requests (both classes) across shards.
    pub waiting: u64,
    /// Σ queued offline requests across shards.
    pub offline_waiting: u64,
    /// Mean live offline token budget across shards, permille of the
    /// static `max_batch_tokens` (1000 = every shard at full static
    /// budget; lower = harvest controllers are tightening).
    pub budget_permille: u64,
    /// Σ prefix-cache attachment hits across shards (prefix sharing;
    /// 0 everywhere when the cache is off).
    pub prefix_hits: u64,
    /// Σ prefix-cache lookups across shards — the hit-rate denominator.
    pub prefix_lookups: u64,
}

/// Trace-mode request router: assigns each request to a shard under a
/// [`Placement`] policy and buckets it into that shard's trace.
///
/// Load is tracked as *admission-time estimates* — the cumulative KV
/// footprint (`total_len` in blocks) routed to each shard — which is the
/// same information a global admission layer has before any worker has
/// run. The estimates never decay; over a long trace this balances
/// cumulative KV demand rather than instantaneous residency, which is
/// the right objective when every shard must eventually absorb its whole
/// bucket.
#[derive(Debug)]
pub struct ShardRouter {
    policy: Placement,
    tick: usize,
    block_tokens: usize,
    est: Vec<LoadSnapshot>,
    buckets: Vec<Vec<Request>>,
}

impl ShardRouter {
    pub fn new(n_shards: usize, policy: Placement, cfg: &EngineConfig) -> Self {
        assert!(
            (1..=MAX_SHARDS).contains(&n_shards),
            "n_shards must be in 1..={MAX_SHARDS}"
        );
        Self {
            policy,
            tick: 0,
            block_tokens: cfg.mem.block_tokens,
            est: vec![
                LoadSnapshot {
                    capacity_blocks: cfg.mem.gpu_blocks as u64,
                    ..LoadSnapshot::default()
                };
                n_shards
            ],
            buckets: (0..n_shards).map(|_| Vec::new()).collect(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.buckets.len()
    }

    /// Choose a shard for `req` and charge its estimated KV footprint to
    /// that shard. Does not store the request — use [`push`](Self::push)
    /// to also bucket it.
    ///
    /// Under [`Placement::PrefixAffinity`] the router also hashes the
    /// request's prompt into block-prefix probes and folds them into the
    /// chosen shard's estimated digest — the admission-time analogue of
    /// a live engine publishing its prefix index, so later requests with
    /// the same prompt prefix follow the first one to its shard.
    pub fn route(&mut self, req: &Request) -> usize {
        let need = req.total_len().div_ceil(self.block_tokens) as u64;
        let probes = match self.policy {
            Placement::PrefixAffinity { .. } => {
                prefix_probes(&req.prompt, self.block_tokens, ROUTE_PROBE_CAP)
            }
            _ => Vec::new(),
        };
        let s = self
            .policy
            .pick_prefix(req.class, need, req.urgency, &self.est, self.tick, &probes);
        self.tick += 1;
        let e = &mut self.est[s];
        e.resident_blocks += need;
        e.waiting += 1;
        match req.class {
            Class::Online => e.online_blocks += need,
            Class::Offline => e.offline_waiting += 1,
        }
        for h in probes {
            digest_insert(&mut e.prefix_digest, h);
        }
        s
    }

    /// Route `req` and append it to its shard's trace bucket. Returns the
    /// chosen shard.
    pub fn push(&mut self, req: Request) -> usize {
        let s = self.route(&req);
        self.buckets[s].push(req);
        s
    }

    /// Requests routed to each shard so far.
    pub fn bucket_sizes(&self) -> Vec<usize> {
        self.buckets.iter().map(Vec::len).collect()
    }

    /// Consume the router, yielding one trace per shard.
    pub fn into_traces(self) -> Vec<Vec<Request>> {
        self.buckets
    }
}

/// Result of a sharded simulation run: per-shard reports plus the merged
/// aggregate ([`Recorder::merge`] folds the shard recorders, so the
/// merged percentiles are over the union of all samples, not an average
/// of averages).
#[derive(Debug)]
pub struct ShardedRun {
    /// One report per shard, over that shard's own finish time.
    pub per_shard: Vec<Report>,
    /// Requests routed to each shard.
    pub shard_requests: Vec<usize>,
    /// Aggregate report over the fleet makespan.
    pub merged: Report,
    /// Fleet makespan in seconds: the slowest shard's finish time (the
    /// denominator of aggregate throughput).
    pub makespan_s: f64,
}

/// Partition `events` across `n_shards` simulated workers under
/// `policy`, run every shard to completion on its own OS thread (each
/// with a private virtual clock, simulated A100 backend, arena, KV pool
/// and scheduler), and aggregate the results.
///
/// `duration_s` bounds each shard's run exactly like
/// [`SimExperiment`](crate::report::SimExperiment): a shard stops when
/// its work is exhausted or the cap is hit. With `n_shards == 1` and the
/// same config this is the single-worker experiment, so sweeps against a
/// 1-shard baseline are apples-to-apples.
pub fn run_sharded_sim(
    cfg: &EngineConfig,
    n_shards: usize,
    policy: Placement,
    events: Vec<Request>,
    duration_s: f64,
) -> ShardedRun {
    run_sharded_sim_steal(cfg, n_shards, policy, events, duration_s, None)
}

/// [`run_sharded_sim`] with optional cross-shard offline work stealing:
/// pass a [`StealConfig`] and backlogged shards migrate queued offline
/// requests to idle siblings (see [`steal`]).
pub fn run_sharded_sim_steal(
    cfg: &EngineConfig,
    n_shards: usize,
    policy: Placement,
    events: Vec<Request>,
    duration_s: f64,
    steal: Option<StealConfig>,
) -> ShardedRun {
    run_sharded_sim_traced(cfg, n_shards, policy, events, duration_s, steal, None)
}

/// [`run_sharded_sim_steal`] with an optional fleet flight recorder
/// ([`crate::trace::FleetTracer`]; one ring per shard, attached before
/// serving). Each shard's virtual clock starts at 0, so two runs over
/// the same seed produce byte-identical trace exports.
#[allow(clippy::too_many_arguments)]
pub fn run_sharded_sim_traced(
    cfg: &EngineConfig,
    n_shards: usize,
    policy: Placement,
    events: Vec<Request>,
    duration_s: f64,
    steal: Option<StealConfig>,
    tracer: Option<Arc<crate::trace::FleetTracer>>,
) -> ShardedRun {
    let mut router = ShardRouter::new(n_shards, policy, cfg);
    for r in events {
        router.push(r);
    }
    run_sharded_traces_with(
        cfg,
        router.into_traces(),
        duration_s,
        steal,
        |engine| {
            if let Some(t) = &tracer {
                engine.set_tracer(t.shard(engine.shard()));
            }
        },
        |_| (),
    )
    .0
}

/// Drive one shard to completion under the steal protocol: serve until
/// local work is exhausted, then idle-wait for deliveries (re-posting
/// the hunger demand) until the whole fleet has nothing in flight. The
/// wall-clock failsafe guarantees a protocol bug degrades to a normal
/// exit instead of a hung fleet.
fn run_shard_with_steals<B: ExecBackend>(
    engine: &mut ServingEngine<B>,
    until: TimeUs,
    st: &Arc<StealCoordinator>,
    loads: &ShardLoads,
    shard: usize,
) -> TimeUs {
    let mut end;
    'serve: loop {
        end = engine.run(until);
        if !engine.drained() {
            break; // stopped on the time cap with work still admitted
        }
        if engine.poll_steals() {
            continue; // a delivery landed between iterations
        }
        st.enter_idle(shard);
        let idle_since = std::time::Instant::now();
        loop {
            // idle-waiting, not iterating: heartbeat by hand so the
            // supervisor's liveness sampling keeps seeing this shard
            loads.beat(shard);
            if st.finished() {
                break 'serve;
            }
            if engine.poll_steals() {
                st.leave_idle(shard);
                continue 'serve;
            }
            engine.post_hunger();
            if idle_since.elapsed() > std::time::Duration::from_secs(10) {
                break 'serve; // failsafe: never hang the fleet
            }
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
    }
    st.retire(shard);
    end
}

/// Run pre-partitioned per-shard traces — the router-free entry point
/// ([`run_sharded_sim`] routes first): `bench_steal` uses it to build a
/// deliberately skewed placement (the offline burst on one shard) that
/// no sane policy would produce but every fleet eventually sees.
pub fn run_sharded_traces(
    cfg: &EngineConfig,
    traces: Vec<Vec<Request>>,
    duration_s: f64,
    steal: Option<StealConfig>,
) -> ShardedRun {
    run_sharded_traces_with(cfg, traces, duration_s, steal, |_| {}, |_| ()).0
}

/// Generic [`run_sharded_traces`]: `setup` runs on every shard's engine
/// before serving (attach a job board, re-enable finished-request
/// retention, switch on token synthesis, ...) and `collect` extracts a
/// per-shard value after the shard drains but before its engine is torn
/// down (harvest finished outputs, snapshot unfinished requests for a
/// durable store). The batch-job driver ([`crate::batch::run_jobs`]) is
/// the in-tree consumer; plain runs pass no-ops.
///
/// This entry point has no recovery driver behind it, so a shard death
/// here is a genuine bug: it is surfaced as a panic carrying the
/// structured [`ShardDied`] record — but only *after* supervision has
/// retired the dead shard and re-drained its mailbox, so no migrated
/// request is stranded. Callers that expect (or inject) deaths use
/// [`run_sharded_traces_supervised`] and get them as data instead.
pub fn run_sharded_traces_with<T: Send>(
    cfg: &EngineConfig,
    traces: Vec<Vec<Request>>,
    duration_s: f64,
    steal: Option<StealConfig>,
    setup: impl Fn(&mut ServingEngine<SimBackend>) + Sync,
    collect: impl Fn(&mut ServingEngine<SimBackend>) -> T + Sync,
) -> (ShardedRun, Vec<T>) {
    let fleet = run_sharded_traces_supervised(cfg, traces, duration_s, steal, setup, collect);
    if let Some(d) = fleet.deaths.first() {
        panic!("{d}");
    }
    let extras = fleet
        .extras
        .into_iter()
        .map(|e| e.expect("no deaths => every collect value present"))
        .collect();
    (fleet.run, extras)
}

/// One supervised fleet run's results: the aggregate [`ShardedRun`]
/// (a dead shard contributes an empty per-shard report — its recorder
/// unwound with it), each shard's `collect` value (`None` for dead
/// shards), and the structured death log.
#[derive(Debug)]
pub struct FleetRun<T> {
    pub run: ShardedRun,
    /// Per-shard `collect` results; `None` where the worker died.
    pub extras: Vec<Option<T>>,
    /// Shards that panicked mid-run, in observation order. Empty on a
    /// healthy run.
    pub deaths: Vec<ShardDied>,
}

/// Stringify a panic payload for a [`ShardDied`] record.
fn panic_payload_string(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic payload>".to_string())
}

/// [`run_sharded_traces_with`] under full supervision: every worker
/// runs inside a panic-isolation boundary ([`supervisor`]), and a death
/// becomes data — the dying thread itself marks the shard dead (so the
/// steal coordinator retires it *immediately*, long before join: its
/// inbox re-drains to the orphan pool and survivors' termination checks
/// stop waiting on it), the join handles are resolved without
/// `.expect`, and the [`FleetRun`] carries the per-shard outcomes plus
/// the death log. A warn-only watchdog thread samples the heartbeat
/// sequence numbers ([`ShardLoads::beat`]) while workers run and logs
/// shards whose heartbeat froze.
///
/// Fault injection hooks in through `setup`: arm each engine with
/// [`ServingEngine::set_fault_injector`] from a
/// [`FaultPlan`](crate::util::fault::FaultPlan) to kill shards, delay
/// or drop steal deliveries, and tear checkpoint writes —
/// deterministically, keyed on iteration counts.
pub fn run_sharded_traces_supervised<T: Send>(
    cfg: &EngineConfig,
    traces: Vec<Vec<Request>>,
    duration_s: f64,
    steal: Option<StealConfig>,
    setup: impl Fn(&mut ServingEngine<SimBackend>) + Sync,
    collect: impl Fn(&mut ServingEngine<SimBackend>) -> T + Sync,
) -> FleetRun<T> {
    let n_shards = traces.len();
    assert!(
        (1..=MAX_SHARDS).contains(&n_shards),
        "n_shards must be in 1..={MAX_SHARDS}"
    );
    let shard_requests: Vec<usize> = traces.iter().map(Vec::len).collect();
    let until = (duration_s * US_PER_SEC as f64) as TimeUs;

    // One offline profiling pass (§4.5) shared by all shards: the shards
    // are identical hardware, so the fitted model is too.
    let cost = CostModel::a100_llama2_7b();
    let profile = {
        let pclock = Clock::virtual_at(0);
        let mut pb = SimBackend::new(cost, pclock, cfg.sched.safepoint_layers);
        LatencyProfile::profile(&mut pb, 4096, 128, 2048).expect("profiling failed")
    };
    let sched_policy = cfg.sched.policy;
    // stealing needs the load board (backlog signals) even in trace
    // mode, and heartbeats ride on its sequence numbers always
    let loads = Arc::new(ShardLoads::new(n_shards, cfg.mem.gpu_blocks));
    let steal_co: Option<Arc<StealCoordinator>> =
        steal.map(|sc| Arc::new(StealCoordinator::new(sc, loads.clone())));
    let sup = Arc::new(FleetSupervisor::new(loads.clone(), steal_co.clone()));

    let results: Vec<Option<(Recorder, TimeUs, T)>> = std::thread::scope(|scope| {
        let setup = &setup;
        let collect = &collect;
        // Warn-only stall watchdog: samples heartbeats every ~200 ms of
        // wall time while any worker still runs. Short ticks keep the
        // post-run exit latency negligible. Panics are caught directly
        // at the isolation boundary below, so in-process this only
        // flags hangs; it never kills anything.
        let monitor = {
            let sup = sup.clone();
            scope.spawn(move || {
                let mut tick = 0u32;
                while !sup.all_settled() {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    tick += 1;
                    if tick % 40 == 0 {
                        for shard in sup.sample_stalled() {
                            eprintln!(
                                "[supervisor] shard {shard}: heartbeat frozen since last sample"
                            );
                        }
                    }
                }
            })
        };
        let handles: Vec<_> = traces
            .into_iter()
            .enumerate()
            .map(|(shard, trace)| {
                let cfg = cfg.clone();
                let loads = loads.clone();
                let steal_co = steal_co.clone();
                let sup = sup.clone();
                scope.spawn(move || {
                    let worker = std::panic::AssertUnwindSafe(|| {
                        let clock = Clock::virtual_at(0);
                        let backend =
                            SimBackend::new(cost, clock.clone(), cfg.sched.safepoint_layers);
                        let arrivals = ArrivalSource::from_trace(trace);
                        let mut engine =
                            ServingEngine::for_shard(shard, cfg, backend, clock, profile, arrivals);
                        engine.set_retain_finished(false);
                        engine.set_shard_loads(loads.clone());
                        setup(&mut engine);
                        let end = match &steal_co {
                            Some(st) => {
                                engine.set_steal_coordinator(st.clone());
                                run_shard_with_steals(&mut engine, until, st, &loads, shard)
                            }
                            None => engine.run(until),
                        };
                        assert!(
                            engine.kv.check_conservation(),
                            "shard {shard}: KV conservation violated"
                        );
                        let extra = collect(&mut engine);
                        (std::mem::take(&mut engine.rec), end, extra)
                    });
                    match std::panic::catch_unwind(worker) {
                        Ok(res) => {
                            sup.mark_done(shard);
                            Some(res)
                        }
                        Err(payload) => {
                            // the dying thread performs its own death
                            // bookkeeping: retire must not wait for join
                            sup.mark_dead(shard, panic_payload_string(payload.as_ref()));
                            None
                        }
                    }
                })
            })
            .collect();
        let results = handles
            .into_iter()
            .enumerate()
            .map(|(shard, h)| {
                // the catch_unwind boundary spans the whole worker body,
                // so join errors should be impossible — but even one of
                // those resolves to a structured death, never an .expect
                h.join().unwrap_or_else(|payload| {
                    sup.mark_dead(shard, panic_payload_string(payload.as_ref()));
                    None
                })
            })
            .collect();
        let _ = monitor.join();
        results
    });

    let deaths = sup.deaths();
    let makespan = results
        .iter()
        .flatten()
        .map(|&(_, end, _)| end.min(until))
        .max()
        .unwrap_or(1)
        .max(1);
    let per_shard: Vec<Report> = results
        .iter()
        .map(|res| match res {
            Some((rec, end, _)) => {
                Report::from_engine(rec, sched_policy, (*end).min(until).max(1))
            }
            None => Report::from_engine(&Recorder::new(), sched_policy, makespan),
        })
        .collect();
    let mut merged_rec = Recorder::new();
    for (rec, _, _) in results.iter().flatten() {
        merged_rec.merge(rec);
    }
    let merged = Report::from_engine(&merged_rec, sched_policy, makespan);
    let extras = results.into_iter().map(|res| res.map(|(_, _, e)| e)).collect();
    FleetRun {
        run: ShardedRun {
            per_shard,
            shard_requests,
            merged,
            makespan_s: makespan as f64 / US_PER_SEC as f64,
        },
        extras,
        deaths,
    }
}

/// A submission ticket plus the shard it was routed to (results are
/// collected from that shard's engine by matching
/// [`Request::submitted_id`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardTicket {
    pub shard: usize,
    pub ticket: RequestId,
}

/// Live-mode submission handle over N shard engines: one
/// [`EngineClient`] per shard behind a [`Placement`] policy fed by the
/// engines' published [`ShardLoads`].
///
/// Tickets are globally unique across shards (the per-shard clients
/// share one ticket counter), and placement is lock-free: a snapshot of
/// the load board plus a few atomic ops. Thread-safe — wrap it in an
/// `Arc` to share across producer threads.
///
/// Placement overlays *optimistic in-flight charges* on the board:
/// submissions made since a shard's last publish are invisible to it
/// (the board only updates once per engine iteration), so without the
/// overlay a burst between iterations would herd onto the one argmin
/// shard. Each placement charges its KV footprint to the chosen shard;
/// the charge expires when that shard's publish sequence advances,
/// because a fresh publish already reflects the drained arrivals.
pub struct ShardedClient {
    clients: Vec<EngineClient>,
    loads: Arc<ShardLoads>,
    policy: Placement,
    tick: AtomicUsize,
    block_tokens: usize,
    pending: Vec<PendingCell>,
    /// The shared ticket counter all per-shard clients mint from (kept
    /// here so a restarted server can seed it past resumed sids, see
    /// [`seed_tickets`](Self::seed_tickets)).
    tickets: Arc<AtomicU64>,
}

/// A job built but not yet dispatched ([`ShardedClient::prepare_job`]):
/// members are placed and fully stamped, the job is registered on the
/// shared board, but nothing has been sent to any engine. The split lets
/// the front door persist the job's [`JobSpec`] + member descriptors to
/// the durable [`JobStore`](crate::batch::JobStore) *before* any member
/// can start (no window where work exists only in volatile queues), then
/// [`dispatch`](ShardedClient::dispatch_job) it.
pub struct PreparedJob {
    pub handle: crate::server::BatchHandle,
    pub tickets: Vec<ShardTicket>,
    pub spec: JobSpec,
    /// Stamped member requests in submission order — the slice
    /// [`JobStore::record_spec`](crate::batch::JobStore::record_spec)
    /// persists.
    pub members: Vec<Request>,
    /// Placement decision per member (parallel to `members`).
    shards: Vec<usize>,
}

/// Per-shard optimistic charge (see [`ShardedClient`] docs). Relaxed
/// atomics; concurrent submitters may briefly double-reset, which only
/// softens the estimate.
#[derive(Debug, Default)]
struct PendingCell {
    seq: AtomicU64,
    blocks: AtomicU64,
    online_blocks: AtomicU64,
    /// Offline submissions since the shard's last publish — the
    /// queue-depth complement of `blocks`. Without it, a multi-member
    /// urgent job under [`Placement::Deadline`] would herd onto the one
    /// shallow-queue shard (each member's footprint charge never
    /// outweighs the 32-block-per-queued-request penalty the other
    /// shards pay), building exactly the backlog the policy avoids.
    offline: AtomicU64,
}

impl ShardedClient {
    /// Shared load board (for observability or ad-hoc placement).
    pub fn loads(&self) -> &Arc<ShardLoads> {
        &self.loads
    }

    pub fn n_shards(&self) -> usize {
        self.clients.len()
    }

    /// The per-shard submission client — entry-point routing (sticky
    /// sessions, one tenant's dedicated ingress) that bypasses the
    /// placement policy. The live work-stealing test drives a skewed
    /// load through one shard's client this way.
    pub fn client(&self, shard: usize) -> &EngineClient {
        &self.clients[shard]
    }

    fn place(
        &self,
        class: Class,
        prompt: &[TokenId],
        max_new_tokens: usize,
        urgency: u32,
    ) -> usize {
        let need = (prompt.len() + max_new_tokens).div_ceil(self.block_tokens) as u64;
        // hash the prompt's block prefixes only under a prefix-aware
        // policy — every other policy ignores the probes
        let probes = match self.policy {
            Placement::PrefixAffinity { .. } => {
                prefix_probes(prompt, self.block_tokens, ROUTE_PROBE_CAP)
            }
            _ => Vec::new(),
        };
        // submission path, off every engine's hot loop: a small snapshot
        // buffer per call is fine
        let mut snaps = Vec::with_capacity(self.clients.len());
        self.loads.snapshot_into(&mut snaps);
        for (s, snap) in snaps.iter_mut().enumerate() {
            let cell = &self.pending[s];
            let seq = self.loads.publish_seq(s);
            if cell.seq.swap(seq, Ordering::Relaxed) != seq {
                // the engine published since our last look: its snapshot
                // already covers what we had charged
                cell.blocks.store(0, Ordering::Relaxed);
                cell.online_blocks.store(0, Ordering::Relaxed);
                cell.offline.store(0, Ordering::Relaxed);
            }
            snap.resident_blocks += cell.blocks.load(Ordering::Relaxed);
            snap.online_blocks += cell.online_blocks.load(Ordering::Relaxed);
            snap.offline_waiting += cell.offline.load(Ordering::Relaxed);
        }
        let s = self.policy.pick_prefix(
            class,
            need,
            urgency,
            &snaps,
            self.tick.fetch_add(1, Ordering::Relaxed),
            &probes,
        );
        let cell = &self.pending[s];
        cell.blocks.fetch_add(need, Ordering::Relaxed);
        match class {
            Class::Online => {
                cell.online_blocks.fetch_add(need, Ordering::Relaxed);
            }
            Class::Offline => {
                cell.offline.fetch_add(1, Ordering::Relaxed);
            }
        }
        s
    }

    /// Route one latency-critical request to a shard.
    pub fn submit_online(&self, prompt: Vec<TokenId>, max_new_tokens: usize) -> ShardTicket {
        let shard = self.place(Class::Online, &prompt, max_new_tokens, 0);
        let ticket = self.clients[shard].submit_online(prompt, max_new_tokens);
        ShardTicket { shard, ticket }
    }

    /// Non-blocking [`submit_online`](Self::submit_online): refuses with
    /// [`SubmitError::Full`] when the chosen shard's bounded channel is
    /// at capacity instead of blocking the caller. On refusal the
    /// optimistic placement charge stays until that shard's next publish
    /// — it only softens the estimate, in the conservative direction.
    pub fn try_submit_online(
        &self,
        prompt: Vec<TokenId>,
        max_new_tokens: usize,
    ) -> Result<ShardTicket, SubmitError> {
        let shard = self.place(Class::Online, &prompt, max_new_tokens, 0);
        let ticket = self.clients[shard].try_submit_online(prompt, max_new_tokens)?;
        Ok(ShardTicket { shard, ticket })
    }

    /// The shared job-progress board (wire a clone to every engine).
    pub fn job_board(&self) -> &Arc<JobBoard> {
        self.clients[0].job_board()
    }

    /// Mint + register a job id without building or sending any member
    /// — the front door does this first so even an admission-*rejected*
    /// job has a correlatable id in its structured 429 body.
    pub fn reserve_job(&self, n_requests: u64, tenant: u32, deadline: TimeUs) -> u64 {
        self.clients[0].register_job(n_requests, tenant, deadline)
    }

    /// Drop a job's board entry (admission rejection, abandoned batch).
    /// Keeps a long-lived server's board bounded; see
    /// [`JobBoard::retire`].
    pub fn retire_job(&self, job: u64) -> bool {
        self.job_board().retire(job)
    }

    /// Seed the shared ticket counter to at least `min_next` (the ticket
    /// namespace bit is masked off). A restarted server calls this with
    /// 1 + the highest sid found in the durable store, so freshly minted
    /// tickets can never collide with resumed submission ids.
    pub fn seed_tickets(&self, min_next: u64) {
        self.tickets.fetch_max(
            min_next & !crate::server::api::CLIENT_TICKET_BIT,
            Ordering::Relaxed,
        );
    }

    /// Place and stamp every member of a job *without dispatching it*:
    /// the job is registered on the shared board (deadline as given —
    /// pass the post-verdict deadline, not the requested one) and each
    /// member carries the full durable identity (job, tenant, urgency,
    /// tier weight, deadline). The caller persists
    /// `(prepared.spec, &prepared.members)` to the store, then calls
    /// [`dispatch_job`](Self::dispatch_job).
    #[allow(clippy::too_many_arguments)]
    pub fn prepare_job(
        &self,
        prompts: Vec<(Vec<TokenId>, usize)>,
        tenant: u32,
        tier: u8,
        urgency: u32,
        deadline: TimeUs,
        submitted_at: TimeUs,
    ) -> PreparedJob {
        let job = self.clients[0].register_job(prompts.len() as u64, tenant, deadline);
        let fair = tier_weight(tier);
        let n_requests = prompts.len() as u64;
        let mut members = Vec::with_capacity(prompts.len());
        let mut shards = Vec::with_capacity(prompts.len());
        let mut tickets = Vec::with_capacity(prompts.len());
        let mut total_tokens = 0u64;
        for (prompt, max_new_tokens) in prompts {
            let shard = self.place(Class::Offline, &prompt, max_new_tokens, urgency);
            let req = self.clients[shard].build_job_member(
                job,
                tenant,
                urgency,
                deadline,
                fair,
                prompt,
                max_new_tokens,
            );
            total_tokens += (req.prompt_len + req.max_new_tokens) as u64;
            tickets.push(ShardTicket {
                shard,
                ticket: req.id,
            });
            shards.push(shard);
            members.push(req);
        }
        let handle = self.clients[0].handle(job, tickets.iter().map(|t| t.ticket).collect());
        PreparedJob {
            handle,
            tickets,
            spec: JobSpec {
                job,
                tenant,
                tier,
                deadline,
                submitted_at,
                n_requests,
                total_tokens,
            },
            members,
            shards,
        }
    }

    /// Send a prepared job's members to their shards (blocking sends —
    /// an accepted job is never shed here). Returns the poll-able handle
    /// and the member tickets.
    pub fn dispatch_job(
        &self,
        prepared: PreparedJob,
    ) -> (crate::server::BatchHandle, Vec<ShardTicket>) {
        let PreparedJob {
            handle,
            tickets,
            members,
            shards,
            ..
        } = prepared;
        for (shard, req) in shards.into_iter().zip(members) {
            self.clients[shard].send(req);
        }
        (handle, tickets)
    }

    /// Route a pool of best-effort requests as one anonymous job
    /// (default tenant, no urgency, no deadline), placing each member
    /// independently. Returns the poll-able [`BatchHandle`] — the same
    /// status surface as [`EngineClient::submit_batch`] — plus each
    /// member's shard.
    pub fn submit_batch(
        &self,
        prompts: Vec<(Vec<TokenId>, usize)>,
    ) -> (crate::server::BatchHandle, Vec<ShardTicket>) {
        self.submit_job(prompts, 0, 0, 0)
    }

    /// Route a batch *job* across the fleet: one job id on the shared
    /// board, each member placed independently with its urgency (so a
    /// [`Placement::Deadline`] policy actually sees it — urgent members
    /// land on shallow-backlog shards). Returns the poll-able handle
    /// plus each member's shard.
    pub fn submit_job(
        &self,
        prompts: Vec<(Vec<TokenId>, usize)>,
        tenant: u32,
        urgency: u32,
        deadline: crate::TimeUs,
    ) -> (crate::server::BatchHandle, Vec<ShardTicket>) {
        let job = self.clients[0].register_job(prompts.len() as u64, tenant, deadline);
        let tickets: Vec<ShardTicket> = prompts
            .into_iter()
            .map(|(prompt, max_new_tokens)| {
                let shard = self.place(Class::Offline, &prompt, max_new_tokens, urgency);
                let ticket = self.clients[shard].submit_job_member(
                    job,
                    tenant,
                    urgency,
                    deadline,
                    prompt,
                    max_new_tokens,
                );
                ShardTicket { shard, ticket }
            })
            .collect();
        let handle = self.clients[0].handle(job, tickets.iter().map(|t| t.ticket).collect());
        (handle, tickets)
    }
}

/// Build the live sharded frontend: a [`ShardedClient`], the shared
/// [`ShardLoads`] board, and one [`ArrivalSource`] per shard.
///
/// Wire shard `i`'s source into `ServingEngine::for_shard(i, ..)` and
/// hand the engine the board via
/// [`ServingEngine::set_shard_loads`] so placement sees its load.
pub fn sharded_channel(
    n_shards: usize,
    policy: Placement,
    cfg: &EngineConfig,
) -> (ShardedClient, Arc<ShardLoads>, Vec<ArrivalSource>) {
    let loads = Arc::new(ShardLoads::new(n_shards, cfg.mem.gpu_blocks));
    let tickets = Arc::new(AtomicU64::new(1));
    // one job board across all shards: a batch whose members land on
    // different shards still reports unified progress (wire it to each
    // engine via set_job_board)
    let jobs = Arc::new(JobBoard::new());
    let mut clients = Vec::with_capacity(n_shards);
    let mut sources = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        let (c, s) = ArrivalSource::channel_with_board(tickets.clone(), jobs.clone());
        clients.push(c);
        sources.push(s);
    }
    (
        ShardedClient {
            clients,
            loads: loads.clone(),
            policy,
            tick: AtomicUsize::new(0),
            block_tokens: cfg.mem.block_tokens,
            pending: (0..n_shards).map(|_| PendingCell::default()).collect(),
            tickets,
        },
        loads,
        sources,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::rid_gen;

    fn req(class: Class, input: usize, output: usize, at: TimeUs) -> Request {
        Request::new(0, class, vec![], input, output, at)
    }

    #[test]
    fn router_round_robin_partitions_evenly() {
        let cfg = EngineConfig::sim_a100_7b();
        let mut r = ShardRouter::new(4, Placement::RoundRobin, &cfg);
        for i in 0..20 {
            r.push(req(Class::Online, 64, 8, i));
        }
        assert_eq!(r.bucket_sizes(), vec![5, 5, 5, 5]);
        let traces = r.into_traces();
        assert_eq!(traces.len(), 4);
        assert_eq!(traces.iter().map(Vec::len).sum::<usize>(), 20);
    }

    #[test]
    fn router_least_kv_balances_footprint() {
        let cfg = EngineConfig::sim_a100_7b();
        let mut r = ShardRouter::new(2, Placement::LeastKv, &cfg);
        // one giant request, then several small ones: the small ones
        // should all dodge the loaded shard until footprints even out
        let big = r.push(req(Class::Offline, 4000, 96, 0));
        let mut smalls = Vec::new();
        for _ in 0..4 {
            smalls.push(r.push(req(Class::Offline, 64, 8, 0)));
        }
        assert!(smalls.iter().all(|&s| s != big));
    }

    #[test]
    fn router_affinity_keeps_online_spread() {
        let cfg = EngineConfig::sim_a100_7b();
        let mut r = ShardRouter::new(2, Placement::affinity(), &cfg);
        let a = r.push(req(Class::Online, 512, 64, 0));
        let b = r.push(req(Class::Online, 512, 64, 1));
        assert_ne!(a, b, "online requests must spread across shards");
    }

    #[test]
    fn loads_publish_snapshot_round_trip() {
        let loads = ShardLoads::new(3, 1000);
        loads.publish(1, 42, 7, 3, 2, 5);
        let s = loads.snapshot(1);
        assert_eq!(s.resident_blocks, 42);
        assert_eq!(s.online_blocks, 7);
        assert_eq!(s.waiting, 3);
        assert_eq!(s.offline_waiting, 2);
        assert_eq!(s.steal_score, 5);
        assert_eq!(s.capacity_blocks, 1000);
        assert_eq!(s.prefix_digest, [0; PREFIX_DIGEST_WORDS], "prefix-less default");
        let mut all = Vec::new();
        loads.snapshot_into(&mut all);
        assert_eq!(all.len(), 3);
        assert_eq!(all[0], loads.snapshot(0));
        // prefix publication travels word-for-word and sums fleet-wide
        let mut digest = [0u64; PREFIX_DIGEST_WORDS];
        digest_insert(&mut digest, 77);
        digest_insert(&mut digest, 600);
        loads.publish_prefix(1, 3, 9, &digest);
        loads.publish_prefix(2, 1, 4, &[0; PREFIX_DIGEST_WORDS]);
        assert_eq!(loads.snapshot(1).prefix_digest, digest);
        let o = loads.fleet_occupancy();
        assert_eq!((o.prefix_hits, o.prefix_lookups), (4, 13));
    }

    #[test]
    fn router_prefix_affinity_steers_repeat_prompts() {
        let cfg = EngineConfig::sim_a100_7b();
        let mut r = ShardRouter::new(2, Placement::prefix_affinity(), &cfg);
        let shared: Vec<TokenId> = (0..64).map(|i| i as TokenId).collect();
        let first = r.push(Request::new(0, Class::Online, shared.clone(), 64, 8, 0));
        // the same prefix follows the first request to its shard, even
        // though the other shard is now emptier
        let second = r.push(Request::new(0, Class::Online, shared, 64, 8, 1));
        assert_eq!(first, second, "repeat prompt must follow its prefix");
        // a cold prompt sees zero digest hits everywhere and balances
        // load onto the emptier shard
        let other: Vec<TokenId> = (1000..1064).map(|i| i as TokenId).collect();
        let cold = r.push(Request::new(0, Class::Online, other, 64, 8, 2));
        assert_ne!(cold, first, "cold prompts must still spread");
    }

    #[test]
    fn sharded_client_routes_by_load_and_tickets_are_unique() {
        let cfg = EngineConfig::sim_a100_7b();
        let (client, loads, mut sources) = sharded_channel(2, Placement::LeastKv, &cfg);
        assert_eq!(client.n_shards(), 2);
        // shard 0 reports heavy load; placement must pick shard 1
        loads.publish(0, 500, 100, 9, 4, 0);
        loads.publish(1, 10, 5, 0, 0, 0);
        let t1 = client.submit_online(vec![1, 2, 3], 4);
        assert_eq!(t1.shard, 1);
        let (handle, batch) = client.submit_batch(vec![(vec![4], 2), (vec![5], 2)]);
        assert_eq!(handle.len(), 2);
        assert!(!handle.done());
        assert!(batch.iter().all(|t| t.shard == 1));
        // globally unique tickets despite independent per-shard clients
        let mut all = vec![t1];
        all.extend(batch);
        for (i, a) in all.iter().enumerate() {
            for b in &all[i + 1..] {
                assert_ne!(a.ticket, b.ticket);
            }
        }
        // the requests actually arrive on shard 1's source
        assert_eq!(sources[1].poll(100).len(), 3);
        assert!(sources[0].poll(100).is_empty());
    }

    #[test]
    fn sharded_client_job_routes_by_urgency_and_shares_board() {
        use crate::request::URGENCY_MAX;
        let cfg = EngineConfig::sim_a100_7b();
        let (client, loads, mut sources) = sharded_channel(2, Placement::deadline(), &cfg);
        // shard 0: lighter footprint but a deep offline backlog;
        // shard 1: heavier footprint, empty queue
        loads.publish(0, 20, 0, 10, 10, 0);
        loads.publish(1, 60, 0, 0, 0, 0);
        // a lax job (urgency 0) balances footprint -> shard 0
        let (h_lax, t_lax) = client.submit_job(vec![(vec![1], 4)], 7, 0, 0);
        assert_eq!(t_lax[0].shard, 0);
        // an urgent job pays the queue penalty -> shard 1
        let (h_urgent, t_urgent) =
            client.submit_job(vec![(vec![2], 4)], 7, URGENCY_MAX, 123);
        assert_eq!(t_urgent[0].shard, 1, "deadline placement must see urgency");
        assert_ne!(h_lax.job, h_urgent.job);
        // the member arrives stamped with its job identity
        let got = sources[1].poll(50);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].job, h_urgent.job);
        assert_eq!(got[0].tenant, 7);
        assert_eq!(got[0].urgency, URGENCY_MAX);
        assert_eq!(got[0].deadline, 123);
        // every shard's client shares one board, so any engine's
        // completion notify drives the handle
        assert!(!h_urgent.done());
        let done = client
            .client(0)
            .job_board()
            .note_finished(h_urgent.job, 4, 10);
        assert!(done.is_some());
        assert!(h_urgent.done());
    }

    #[test]
    fn sharded_sim_finishes_everything_and_stamps_shards() {
        let cfg = EngineConfig::sim_a100_7b();
        let mut events = Vec::new();
        for i in 0..24 {
            events.push(req(Class::Online, 128, 8, i * 500_000));
        }
        for _ in 0..6 {
            events.push(req(Class::Offline, 512, 16, 0));
        }
        let run = run_sharded_sim(&cfg, 2, Placement::affinity(), events, 600.0);
        assert_eq!(run.shard_requests.iter().sum::<usize>(), 30);
        assert_eq!(
            run.merged.online_finished + run.merged.offline_finished,
            30,
            "all routed requests must finish: {:?}",
            run.merged
        );
        let per_shard_fin: u64 = run
            .per_shard
            .iter()
            .map(|r| r.online_finished + r.offline_finished)
            .sum();
        assert_eq!(per_shard_fin, 30);
        assert!(run.makespan_s > 0.0);
        assert_eq!(run.per_shard.len(), 2);
    }

    #[test]
    fn skewed_traces_complete_with_stealing() {
        // all offline work lands on shard 0; with stealing, the fleet
        // still completes everything and the idle shard does real work
        let cfg = EngineConfig::sim_a100_7b();
        let mut shard0 = Vec::new();
        for i in 0..8 {
            shard0.push(req(Class::Online, 128, 8, i * 400_000));
        }
        for _ in 0..40 {
            shard0.push(req(Class::Offline, 512, 16, 0));
        }
        let shard1 = (0..8)
            .map(|i| req(Class::Online, 128, 8, i * 400_000))
            .collect();
        let run = run_sharded_traces(
            &cfg,
            vec![shard0, shard1],
            600.0,
            Some(StealConfig::default()),
        );
        assert_eq!(
            run.merged.online_finished + run.merged.offline_finished,
            56,
            "stealing must not lose or duplicate requests: {:?}",
            run.merged
        );
        assert!(
            run.merged.steals_in > 0 && run.merged.steals_in == run.merged.steals_out,
            "every migration must be adopted exactly once: out={} in={}",
            run.merged.steals_out,
            run.merged.steals_in
        );
        assert!(
            run.per_shard[1].offline_finished > 0,
            "the idle shard must finish stolen offline work: {:?}",
            run.per_shard[1]
        );
    }

    #[test]
    fn supervised_run_isolates_an_injected_kill() {
        use crate::util::fault::{silence_injected_panics, FaultPlan, INJECTED_PANIC_MARKER};
        silence_injected_panics();
        let cfg = EngineConfig::sim_a100_7b();
        let plan = FaultPlan::parse("kill=0@3").unwrap();
        let mk_trace = || -> Vec<Request> {
            (0..10).map(|i| req(Class::Online, 128, 8, i * 400_000)).collect()
        };
        let fleet = run_sharded_traces_supervised(
            &cfg,
            vec![mk_trace(), mk_trace()],
            600.0,
            Some(StealConfig::default()),
            |e| {
                let shard = e.shard();
                e.set_fault_injector(plan.injector_for(shard));
            },
            |e| e.shard(),
        );
        assert_eq!(fleet.deaths.len(), 1, "exactly the injected death");
        assert_eq!(fleet.deaths[0].shard, 0);
        assert!(
            fleet.deaths[0].payload.contains(INJECTED_PANIC_MARKER),
            "payload travels: {}",
            fleet.deaths[0].payload
        );
        assert!(fleet.extras[0].is_none(), "dead shard yields no collect value");
        assert_eq!(fleet.extras[1], Some(1));
        // the survivor's own work completed despite the sibling's death
        assert_eq!(fleet.run.per_shard[1].online_finished, 10);
        assert_eq!(fleet.run.merged.online_finished, 10);
    }

    #[test]
    fn sharded_client_spreads_bursts_between_publishes() {
        // nothing has published yet (or an engine is mid-iteration): the
        // optimistic in-flight charges must spread a burst instead of
        // herding it onto the single argmin shard
        let cfg = EngineConfig::sim_a100_7b();
        let (client, loads, _sources) = sharded_channel(4, Placement::LeastKv, &cfg);
        let (_handle, batch) = client.submit_batch(vec![(vec![1], 8); 8]);
        let mut counts = [0usize; 4];
        for t in &batch {
            counts[t.shard] += 1;
        }
        assert_eq!(counts, [2, 2, 2, 2], "burst herded: {counts:?}");
        // a publish expires the charges: placement follows the board again
        for s in 0..4 {
            loads.publish(s, if s == 3 { 0 } else { 100 }, 0, 0, 0, 0);
        }
        let t = client.submit_online(vec![1], 4);
        assert_eq!(t.shard, 3);
    }

    #[test]
    fn shard_tickets_keep_the_client_namespace_bit() {
        // tickets stay in the client id namespace (high bit set), so they
        // can never resolve against any shard's arena
        let cfg = EngineConfig::sim_a100_7b();
        let (client, _loads, _sources) = sharded_channel(2, Placement::RoundRobin, &cfg);
        let t = client.submit_online(vec![1], 1);
        assert!(rid_gen(t.ticket) >= 1 << 31, "ticket bit must be set");
    }
}
