//! `bench_steal` — cross-shard offline work-stealing acceptance bench.
//!
//! Serves a deliberately *skewed* 4-shard workload: online traffic is
//! spread round-robin across all shards, but the entire offline burst
//! lands on shard 0 (the worst case no placement policy should produce
//! but every fleet eventually sees — a tenant submitting a huge batch
//! through one entry point). The same traces run twice, stealing off
//! then on, at equal total load.
//!
//! Acceptance (asserted here):
//!
//! * offline completion throughput (offline generated tokens over the
//!   fleet makespan) improves with stealing — idle shards must absorb
//!   the backlogged shard's tail;
//! * the online TTFT-violation rate does not regress (harvested shards
//!   keep their SLO-aware budgets);
//! * stealing neither loses nor duplicates requests.
//!
//! Results go to `BENCH_steal.json` (schema: rust/PERF.md §5). Scale
//! with `STEAL_BENCH_REQS` (default 40_000; CI smoke uses a small
//! value).

use conserve::config::EngineConfig;
use conserve::report::Report;
use conserve::request::{Class, Request};
use conserve::shard::{run_sharded_traces, ShardedRun, StealConfig};
use conserve::util::json::{arr, num, obj, Json};
use conserve::util::rng::Rng;
use conserve::workload::trace::onoff_trace;
use std::time::Instant;

const N_SHARDS: usize = 4;

/// Online spread evenly, offline burst pinned to shard 0.
fn skewed_traces(n_reqs: usize) -> (Vec<Vec<Request>>, f64) {
    let n_online = n_reqs * 3 / 4;
    let n_offline = n_reqs - n_online;
    let on_rate = 60.0;
    let duration_s = 2.0 * n_online as f64 / on_rate;
    let arrivals = onoff_trace(42, duration_s, 30.0, on_rate, 2.0);
    let mut rng = Rng::new(7);
    let mut traces: Vec<Vec<Request>> = (0..N_SHARDS).map(|_| Vec::new()).collect();
    let mut next_id = 1u64;
    for (i, &t) in arrivals.iter().take(n_online).enumerate() {
        let input = rng.range_usize(64, 256);
        let output = rng.range_usize(8, 24);
        traces[i % N_SHARDS].push(Request::new(next_id, Class::Online, vec![], input, output, t));
        next_id += 1;
    }
    for _ in 0..n_offline {
        let input = rng.range_usize(512, 2048);
        let output = rng.range_usize(32, 96);
        traces[0].push(Request::new(next_id, Class::Offline, vec![], input, output, 0));
        next_id += 1;
    }
    (traces, duration_s)
}

struct Row {
    label: &'static str,
    wall_s: f64,
    run: ShardedRun,
}

fn offline_tput(r: &Report) -> f64 {
    r.offline_gen_tput
}

fn main() {
    let n_reqs: usize = std::env::var("STEAL_BENCH_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(40_000);
    let (traces, duration_s) = skewed_traces(n_reqs);
    let shard_sizes: Vec<usize> = traces.iter().map(Vec::len).collect();
    let n_events: usize = shard_sizes.iter().sum();
    let cfg = EngineConfig::sim_a100_7b();
    let steal_cfg = StealConfig::default();

    println!(
        "=== bench_steal ({n_events} requests, {N_SHARDS} shards, offline burst on shard 0: {:?}) ===",
        shard_sizes
    );
    let mut rows: Vec<Row> = Vec::new();
    for (label, steal) in [("steal-off", None), ("steal-on", Some(steal_cfg))] {
        let t0 = Instant::now();
        let run = run_sharded_traces(&cfg, traces.clone(), duration_s * 6.0, steal);
        let wall_s = t0.elapsed().as_secs_f64();
        let m = &run.merged;
        println!(
            "{label:>10}: wall={wall_s:>6.2}s makespan={:>8.1}s offline_gen={:>7.0} tok/s p99TTFT={:>8.1}ms viol={:>5.2}% finished={} steals(out/in)={}/{}",
            run.makespan_s,
            offline_tput(m),
            m.online_p99_ttft_ms,
            m.ttft_violations * 100.0,
            m.online_finished + m.offline_finished,
            m.steals_out,
            m.steals_in,
        );
        rows.push(Row { label, wall_s, run });
    }

    // ---- acceptance ----
    let base = &rows[0].run;
    let steal = &rows[1].run;
    let finished =
        |r: &ShardedRun| r.merged.online_finished + r.merged.offline_finished;
    assert_eq!(
        finished(base),
        finished(steal),
        "stealing must not lose or duplicate requests"
    );
    assert_eq!(
        steal.merged.steals_out, steal.merged.steals_in,
        "every migration must be adopted exactly once"
    );
    assert!(
        steal.merged.steals_in > 0,
        "the skewed trace must actually trigger steals"
    );
    assert!(
        offline_tput(&steal.merged) > offline_tput(&base.merged),
        "offline completion throughput must improve with stealing: {:.0} vs {:.0} tok/s",
        offline_tput(&steal.merged),
        offline_tput(&base.merged)
    );
    assert!(
        steal.merged.ttft_violations <= base.merged.ttft_violations + 0.005,
        "online SLO violations must not regress: {:.4} vs {:.4}",
        steal.merged.ttft_violations,
        base.merged.ttft_violations
    );
    println!(
        "offline throughput ratio (on/off): {:.2}x, makespan ratio {:.2}x",
        offline_tput(&steal.merged) / offline_tput(&base.merged).max(1e-9),
        base.makespan_s / steal.makespan_s.max(1e-9),
    );

    // ---- emit BENCH_steal.json (schema documented in rust/PERF.md §5) ----
    let mode_row = |row: &Row| {
        let m = &row.run.merged;
        obj(vec![
            ("mode", Json::Str(row.label.to_string())),
            ("wall_s", num(row.wall_s)),
            ("makespan_s", num(row.run.makespan_s)),
            ("offline_gen_tok_s", num(offline_tput(m))),
            ("agg_gen_tok_s", num(m.total_gen_tput)),
            ("online_p99_ttft_ms", num(m.online_p99_ttft_ms)),
            ("online_p99_tpot_ms", num(m.online_p99_tpot_ms)),
            ("ttft_violation_rate", num(m.ttft_violations)),
            (
                "finished",
                num((m.online_finished + m.offline_finished) as f64),
            ),
            ("steals_out", num(m.steals_out as f64)),
            ("steals_in", num(m.steals_in as f64)),
            ("preemptions", num(m.preemptions as f64)),
            (
                "per_shard",
                arr(row.run.per_shard.iter().zip(&row.run.shard_requests).map(
                    |(r, &n)| {
                        obj(vec![
                            ("requests", num(n as f64)),
                            ("offline_finished", num(r.offline_finished as f64)),
                            ("online_finished", num(r.online_finished as f64)),
                            ("steals_out", num(r.steals_out as f64)),
                            ("steals_in", num(r.steals_in as f64)),
                        ])
                    },
                )),
            ),
        ])
    };
    let json = obj(vec![
        ("requests", num(n_events as f64)),
        ("shards", num(N_SHARDS as f64)),
        (
            "skew",
            Json::Str("offline burst pinned to shard 0".to_string()),
        ),
        (
            "steal_config",
            obj(vec![
                ("budget_per_iter", num(steal_cfg.budget_per_iter as f64)),
                ("min_donor_backlog", num(steal_cfg.min_donor_backlog as f64)),
                ("hungry_below", num(steal_cfg.hungry_below as f64)),
            ]),
        ),
        ("modes", arr(rows.iter().map(mode_row))),
        (
            "offline_tput_on_over_off",
            num(offline_tput(&steal.merged) / offline_tput(&base.merged).max(1e-9)),
        ),
    ]);
    let out_path =
        std::env::var("STEAL_BENCH_OUT").unwrap_or_else(|_| "BENCH_steal.json".into());
    std::fs::write(&out_path, json.to_string()).expect("write BENCH_steal.json");
    println!("\nwrote {out_path}");
    let _ = Json::parse(&json.to_string()).expect("self-emitted json parses");
    println!("bench_steal OK");
}
