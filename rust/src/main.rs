//! ConServe launcher.
//!
//! ```text
//! conserve simulate [--policy conserve|vllm++|online-only] [--rate R]
//!                   [--cv CV] [--duration S] [--offline-pool N]
//!                   [--shards N] [--placement rr|least-kv|affinity[:headroom]]
//!                   [--steal on|off] [--harvest on|off[:SLO_US]]
//!                   [--prefix-cache on|off] [--trace-out FILE]
//!                   [--set key=value ...]
//!     Run a co-serving experiment on the simulated A100/Llama-2-7B
//!     testbed and print the report. With --shards N > 1 the trace is
//!     routed across N independent worker shards (each its own
//!     simulated GPU, arena, KV pool and scheduler, run on its own
//!     thread) and per-shard plus merged reports are printed;
//!     --steal on adds cross-shard offline work stealing.
//!
//! conserve serve    [--addr HOST:PORT] [--shards N] [--duration S]
//!                   [--state-dir DIR] [--ckpt-every K]
//!                   [--admission on|off] [--harvest on|off[:SLO_US]]
//!                   [--prefix-cache on|off] [--trace-out FILE]
//!                   [--set key=value ...]
//!     Run the live HTTP front door over a sharded simulated fleet:
//!     OpenAI-style `POST /v1/completions` (chunked token streaming
//!     with `"stream": true`), `POST /v1/batches` for offline jobs
//!     (deadline-feasibility admission: accept / down-tier / reject),
//!     `GET /v1/batches/{id}`, `GET /healthz`, and `POST /drain` for
//!     graceful shutdown (flush accepted online work, checkpoint
//!     in-flight offline work to --state-dir, exit with zero
//!     accepted-request loss). Overload is shed with structured
//!     `429 + Retry-After` responses, offline first. --duration 0
//!     (default) serves until `/drain`. A restart on the same
//!     --state-dir resumes unfinished offline jobs byte-identically.
//!     --admission off disables every gate (overload benchmarking).
//!     With `--backend pjrt` (requires the `pjrt` feature) this
//!     instead serves the real tiny-Llama model end-to-end on the CPU
//!     PJRT runtime with a trace-driven load.
//!
//! conserve profile  [--artifacts DIR]
//!     Run the offline profiler against the PJRT backend and print the
//!     fitted latency model.
//!
//! conserve trace    [--duration S] [--rate R] | --in FILE [--top K]
//!     Without --in: emit the BurstGPT-like rate series (Figure 1
//!     data). With --in FILE: summarize a Perfetto trace previously
//!     written by --trace-out — event counts per track, the top-K
//!     slowest engine iterations (estimated vs actual latency), and
//!     per-request span timelines.
//!
//! conserve jobs     [--jobs N] [--tenants K] [--span S] [--shards N]
//!                   [--placement deadline|affinity|...] [--steal on|off]
//!                   [--sched fifo|urgency] [--rate R] [--duration S]
//!                   [--state-dir DIR] [--resume] [--ckpt-every K]
//!                   [--restamp-every S] [--faults SPEC]
//!                   [--harvest on|off[:SLO_US]] [--prefix-cache on|off]
//!                   [--trace-out FILE] [--set key=value ...]
//!     Run a multi-tenant batch-job experiment (deadline-aware job
//!     manager over the sharded fleet) and print per-job deadline
//!     attainment. --sched urgency enables EDF placement + fair-share
//!     scheduling; fifo is the baseline. With --state-dir the job
//!     specs, outputs and checkpoints of unfinished requests persist
//!     as JSONL; --resume reloads them and replays unfinished work
//!     (byte-identical token streams — sampling is keyed), and
//!     --ckpt-every K flushes cold checkpoints of in-progress work
//!     every K engine iterations (crash loses at most one interval).
//!     --restamp-every S recomputes queued-offline deadline urgency
//!     every S seconds of virtual time. --faults injects deterministic
//!     failures (`kill=SHARD@ITER,delay-steals=N,drop-steals=M,
//!     torn-ckpt=SHARD`): the fleet is supervised, a killed shard is
//!     retired, its online requests fail fast for client retry, and —
//!     with --state-dir — its offline work is recovered from the
//!     durable store onto the survivors under degraded offline
//!     budgets. See rust/ARCHITECTURE.md §8.
//!
//! `--harvest on` (simulate / serve / jobs) enables the per-shard
//! closed-loop harvest controller (rust/ARCHITECTURE.md §10): the
//! offline token budget and prefill chunk retune each iteration from
//! live online TTFT/TPOT percentiles instead of the static
//! `max_batch_tokens`. `--harvest on:SLO_US` overrides the controller's
//! TTFT target in microseconds (default: the `ttft_ms` SLO).
//!
//! `--trace-out FILE` (simulate / serve / jobs) attaches the fleet
//! flight recorder (rust/ARCHITECTURE.md §12) — a fixed-size per-shard
//! ring of binary trace events covering every scheduling decision — and
//! writes the run's merged Perfetto/Chrome trace-event JSON to FILE at
//! exit (open in https://ui.perfetto.dev, or summarize with
//! `conserve trace --in FILE`). Simulated clocks make two identical-seed
//! runs produce byte-identical trace files.
//!
//! `--prefix-cache on` (simulate / serve / jobs) enables cross-request
//! prefix KV sharing (rust/ARCHITECTURE.md §11): committed whole prompt
//! blocks are indexed in a prefix trie and later prompts with the same
//! token prefix attach the resident blocks refcounted instead of
//! re-running their prefill. Pair with `--placement prefix-affinity`
//! so the router steers repeat prefixes to the shard already holding
//! them. Off by default.
//! ```

use anyhow::{bail, Context, Result};
use conserve::config::EngineConfig;
use conserve::report::{Report, SimExperiment};
use conserve::workload::{self, Lengths};

/// Flags that may appear without a value (`--resume` == `--resume true`).
const BARE_BOOL_FLAGS: &[&str] = &["resume"];

/// Parse an on/off flag value (one accepted set for every boolean flag).
fn parse_switch(name: &str, v: &str) -> Result<bool> {
    match v {
        "on" | "true" | "1" | "yes" => Ok(true),
        "off" | "false" | "0" | "no" => Ok(false),
        other => bail!("--{name} expects on|off, got `{other}`"),
    }
}

struct Args {
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    flags.push((k.to_string(), v.to_string()));
                } else if BARE_BOOL_FLAGS.contains(&key) {
                    // known boolean switches may omit their value; every
                    // other flag still hard-errors on a missing one so a
                    // forgotten argument cannot silently become "true"
                    match argv.get(i + 1) {
                        Some(v) if !v.starts_with("--") => {
                            flags.push((key.to_string(), v.clone()));
                            i += 1;
                        }
                        _ => flags.push((key.to_string(), "true".to_string())),
                    }
                } else {
                    // a following `--flag` is never a value: error out
                    // instead of silently consuming it (`--state-dir
                    // --resume` must not create a dir named `--resume`)
                    let v = argv
                        .get(i + 1)
                        .filter(|v| !v.starts_with("--"))
                        .with_context(|| format!("--{key} needs a value"))?;
                    flags.push((key.to_string(), v.clone()));
                    i += 1;
                }
            } else {
                bail!("unexpected argument `{a}`");
            }
            i += 1;
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key}")),
            None => Ok(default),
        }
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key}")),
            None => Ok(default),
        }
    }

    fn apply_sets(&self, cfg: &mut EngineConfig) -> Result<()> {
        for (k, v) in &self.flags {
            if k == "set" {
                let (key, val) = v
                    .split_once('=')
                    .context("--set expects key=value")?;
                cfg.set(key, val)?;
            }
        }
        Ok(())
    }
}

/// Apply `--harvest on|off[:SLO_US]`: toggles the closed-loop harvest
/// controller, with an optional TTFT-target override in µs
/// (`--harvest on:250000`).
fn apply_harvest_flag(args: &Args, cfg: &mut EngineConfig) -> Result<()> {
    let Some(v) = args.get("harvest") else {
        return Ok(());
    };
    let (head, slo) = match v.split_once(':') {
        Some((h, s)) => (h, Some(s)),
        None => (v, None),
    };
    cfg.sched.harvest = parse_switch("harvest", head)?;
    if let Some(s) = slo {
        cfg.sched.harvest_slo_us = s
            .parse()
            .with_context(|| format!("--harvest {v}: bad SLO_US `{s}`"))?;
    }
    Ok(())
}

/// Apply `--prefix-cache on|off`: toggles cross-request prefix KV
/// sharing (admission-time trie attach over refcounted blocks).
fn apply_prefix_flag(args: &Args, cfg: &mut EngineConfig) -> Result<()> {
    if let Some(v) = args.get("prefix-cache") {
        cfg.sched.prefix_cache = parse_switch("prefix-cache", v)?;
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        eprintln!("usage: conserve <simulate|serve|profile|trace|jobs> [flags]");
        std::process::exit(2);
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "simulate" => simulate(&args),
        "serve" => serve(&args),
        "profile" => profile(&args),
        "trace" => trace(&args),
        "jobs" => jobs(&args),
        other => bail!("unknown command `{other}`"),
    }
}

/// Multi-tenant batch-job experiment: admit (or resume) a job trace,
/// serve it on a sharded simulated fleet alongside online background
/// traffic, and report deadline attainment.
fn jobs(args: &Args) -> Result<()> {
    use conserve::batch::{self, JobManager, JobStore};
    use conserve::request::{Class, Request};
    use conserve::workload::jobs::JobTraceConfig;

    let mut cfg = EngineConfig::sim_a100_7b();
    args.apply_sets(&mut cfg)?;
    apply_harvest_flag(args, &mut cfg)?;
    apply_prefix_flag(args, &mut cfg)?;
    let shards = args.get_usize("shards", 4)?;
    let duration = args.get_f64("duration", 240.0)?;
    let rate = args.get_f64("rate", 2.0)?;
    let sched = args.get("sched").unwrap_or("urgency");
    let urgency_mode = match sched {
        "urgency" | "edf" => true,
        "fifo" => false,
        other => bail!("--sched expects fifo|urgency, got `{other}`"),
    };
    cfg.sched.fair_share = urgency_mode;
    let placement: conserve::shard::Placement = match args.get("placement") {
        Some(p) => p.parse()?,
        None if urgency_mode => conserve::shard::Placement::deadline(),
        None => conserve::shard::Placement::affinity(),
    };
    let steal = parse_switch("steal", args.get("steal").unwrap_or("on"))?
        .then(conserve::StealConfig::default);
    let state_dir = args.get("state-dir").map(std::path::PathBuf::from);
    let resume = match args.get("resume") {
        None => false,
        Some(v) => parse_switch("resume", v)?,
    };
    let faults = match args.get("faults") {
        Some(spec) => {
            let p = conserve::util::fault::FaultPlan::parse(spec)?;
            (!p.is_noop()).then_some(p)
        }
        None => None,
    };
    if faults.as_ref().is_some_and(|p| p.kill_shard.is_some()) {
        if state_dir.is_none() {
            bail!(
                "--faults with a kill requires --state-dir: recovery rebuilds the dead \
                 shard's offline work from the durable store"
            );
        }
        conserve::util::fault::silence_injected_panics();
    }
    let ckpt_every = args.get_usize("ckpt-every", 50)? as u64;
    let restamp_s = args.get_f64("restamp-every", if urgency_mode { 5.0 } else { 0.0 })?;
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let tracer = trace_out
        .as_ref()
        .map(|_| conserve::trace::FleetTracer::new(shards, conserve::trace::DEFAULT_RING_EVENTS));

    // A fresh (non-resume) run must not append into an existing state
    // dir: job and submission ids restart from the same bases every
    // run, so mixing two runs' records would silently cross-wire a
    // later --resume (an old output line would mark a new request as
    // already complete).
    if !resume {
        if let Some(dir) = &state_dir {
            let specs = dir.join("specs.jsonl");
            if std::fs::metadata(&specs).map(|m| m.len() > 0).unwrap_or(false) {
                bail!(
                    "state dir {} already holds a run; pass --resume to continue it \
                     or point --state-dir at a fresh directory",
                    dir.display()
                );
            }
        }
    }

    // per-shard nominal rate scaled by the fleet size
    let svc = batch::NOMINAL_TOK_PER_S * shards as f64;
    let mut jm = JobManager::new(svc);
    let mut events: Vec<Request> = Vec::new();
    let mut store = match &state_dir {
        Some(dir) => Some(JobStore::open(dir)?),
        None => None,
    };
    if resume {
        let dir = state_dir
            .as_ref()
            .context("--resume requires --state-dir")?;
        let state = JobStore::load(dir)?;
        let replayed = jm.resume(&state, &mut events);
        println!(
            "resumed {} jobs from {} ({} requests to replay, {} already complete)",
            jm.specs().len(),
            dir.display(),
            replayed,
            state.outputs.len()
        );
    } else {
        let trace_cfg = JobTraceConfig {
            seed: cfg.seed ^ 0x1057,
            n_jobs: args.get_usize("jobs", 24)?,
            n_tenants: args.get_usize("tenants", 4)? as u32,
            span_s: args.get_f64("span", duration / 4.0)?,
            svc_tok_per_s: svc,
        };
        for input in conserve::workload::jobs::job_trace(&trace_cfg) {
            let before = events.len();
            let spec = jm.admit(&input, &mut events);
            if let Some(store) = store.as_mut() {
                store.record_spec(&spec, &events[before..])?;
            }
        }
    }

    // online background traffic (ids 1.. never collide with job sids)
    let mut lg = workload::LoadGen::new(cfg.seed, rate, 1.0);
    let mut rng = conserve::util::rng::Rng::new(cfg.seed ^ 0xB06);
    let mut next_id = 1u64;
    for t in lg.arrivals_until(duration) {
        let l = Lengths::online_paper().sample(&mut rng);
        events.push(Request::new(next_id, Class::Online, vec![], l.input, l.output, t));
        next_id += 1;
    }

    let opts = conserve::batch::JobRunOpts {
        n_shards: shards,
        placement,
        steal,
        duration_s: duration,
        collect_state: store.is_some(),
        synth_tokens: store.is_some(),
        ckpt_every: if store.is_some() { ckpt_every } else { 0 },
        restamp_every_us: (restamp_s * 1e6) as u64,
        svc_tok_per_s: svc,
        tracer: tracer.clone(),
    };
    let board = jm.board().clone();
    let store = store.map(|s| std::sync::Arc::new(std::sync::Mutex::new(s)));
    let (out, recovery) = match &store {
        Some(s) => {
            // supervised run with the durable sink; on a shard death
            // the store-backed recovery round runs automatically
            let rec = batch::run_jobs_with_recovery(
                &cfg,
                &opts,
                board,
                events,
                s.clone(),
                faults.as_ref(),
            )?;
            println!(
                "persisted {} outputs + {} checkpoints to {}",
                rec.first.finished.len(),
                rec.first.unfinished.len(),
                s.lock().unwrap().dir().display()
            );
            if rec.recovery.is_some() {
                println!(
                    "recovery: replayed {} requests on the survivors ({} torn checkpoint line(s) skipped)",
                    rec.resumed_requests, rec.torn_checkpoint_lines
                );
            }
            (rec.first, rec.recovery)
        }
        None => (
            batch::run_jobs_with_store(&cfg, &opts, board, events, None, faults.as_ref()),
            None,
        ),
    };
    for d in &out.deaths {
        println!("  SHARD DEATH: {d}");
    }
    if let (Some(path), Some(t)) = (&trace_out, &tracer) {
        if !out.deaths.is_empty() {
            if let Some(dir) = &state_dir {
                match conserve::trace::flight_dump(dir, "jobs-death", t, conserve::trace::DEFAULT_DUMP_LAST)
                {
                    Ok(p) => println!("  flight record dumped to {}", p.display()),
                    Err(e) => eprintln!("  flight dump failed: {e}"),
                }
            }
        }
        std::fs::write(path, conserve::trace::perfetto::export_perfetto(t))
            .with_context(|| format!("writing trace to {}", path.display()))?;
        println!(
            "trace: wrote {} events ({} dropped) to {}",
            t.total_events(),
            t.dropped(),
            path.display()
        );
    }
    if !out.failed_online.is_empty() {
        println!(
            "  {} online requests failed fast (routed to a dead shard) — clients must retry",
            out.failed_online.len()
        );
    }

    println!(
        "== jobs: {} jobs, {shards} shards, {placement} placement, sched {} ==",
        out.jobs.len(),
        if urgency_mode { "urgency" } else { "fifo" },
    );
    for j in &out.jobs {
        let p = &j.progress;
        println!(
            "  job {:>4} tenant {:>3}  {:>4}/{:<4} done{}{}",
            j.job,
            p.tenant,
            p.finished,
            p.total,
            match p.completed_at {
                Some(t) => format!("  at {:>7.1}s", t as f64 / 1e6),
                None => "  (in flight)".to_string(),
            },
            match p.met_deadline() {
                Some(true) => "  deadline MET",
                Some(false) => "  deadline MISSED",
                None => "",
            }
        );
    }
    println!("  job deadline attainment: {:.1}%", out.job_attainment * 100.0);
    for t in &out.run.merged.per_tenant {
        println!(
            "  tenant {:>3}: finished {:>5}, gen tokens {:>8}, deadline {}/{} met",
            t.tenant,
            t.finished,
            t.gen_tokens,
            t.deadline_met,
            t.deadline_met + t.deadline_missed
        );
    }
    print_report(&out.run.merged);
    if let Some(r) = &recovery {
        println!(
            "== recovery round: {} survivor shards, degraded offline budgets ==",
            shards - out.deaths.len()
        );
        print_report(&r.run.merged);
    }
    Ok(())
}

fn simulate(args: &Args) -> Result<()> {
    let mut cfg = EngineConfig::sim_a100_7b();
    if let Some(p) = args.get("policy") {
        cfg.set("policy", p)?;
    }
    args.apply_sets(&mut cfg)?;
    apply_harvest_flag(args, &mut cfg)?;
    apply_prefix_flag(args, &mut cfg)?;
    let rate = args.get_f64("rate", 2.0)?;
    let cv = args.get_f64("cv", 1.0)?;
    let duration = args.get_f64("duration", 120.0)?;
    let offline_pool = args.get_usize("offline-pool", 512)?;
    let shards = args.get_usize("shards", 1)?;
    let placement: conserve::shard::Placement =
        args.get("placement").unwrap_or("affinity").parse()?;
    let steal = parse_switch("steal", args.get("steal").unwrap_or("off"))?
        .then(conserve::StealConfig::default);
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);

    let mut lg = workload::LoadGen::new(cfg.seed, rate, cv);
    let arrivals = lg.arrivals_until(duration);
    // tracing rides the sharded runner (the only path with a tracer
    // attach hook); a single-shard traced run is just a 1-shard fleet
    if shards > 1 || trace_out.is_some() {
        return simulate_sharded(
            cfg,
            shards,
            placement,
            &arrivals,
            offline_pool,
            duration,
            steal,
            trace_out,
        );
    }
    let report = SimExperiment {
        cfg,
        online_arrivals: arrivals,
        online_lengths: Lengths::online_paper(),
        offline_pool,
        offline_lengths: Lengths::offline_paper(),
        duration_s: duration,
    }
    .run();
    print_report(&report);
    Ok(())
}

/// Sharded variant of `simulate`: the exact workload
/// `SimExperiment::run` would serve ([`SimExperiment::events`]), routed
/// across N worker shards.
#[allow(clippy::too_many_arguments)]
fn simulate_sharded(
    cfg: EngineConfig,
    shards: usize,
    placement: conserve::shard::Placement,
    online_arrivals: &[conserve::TimeUs],
    offline_pool: usize,
    duration: f64,
    steal: Option<conserve::StealConfig>,
    trace_out: Option<std::path::PathBuf>,
) -> Result<()> {
    use conserve::shard::run_sharded_sim_traced;

    let exp = SimExperiment {
        cfg: cfg.clone(),
        online_arrivals: online_arrivals.to_vec(),
        online_lengths: Lengths::online_paper(),
        offline_pool,
        offline_lengths: Lengths::offline_paper(),
        duration_s: duration,
    };
    let stealing = steal.is_some();
    let tracer = trace_out
        .as_ref()
        .map(|_| conserve::trace::FleetTracer::new(shards, conserve::trace::DEFAULT_RING_EVENTS));
    let run = run_sharded_sim_traced(
        &cfg,
        shards,
        placement,
        exp.events(),
        duration,
        steal,
        tracer.clone(),
    );
    if let (Some(path), Some(t)) = (&trace_out, &tracer) {
        std::fs::write(path, conserve::trace::perfetto::export_perfetto(t))
            .with_context(|| format!("writing trace to {}", path.display()))?;
        println!(
            "trace: wrote {} events ({} dropped) to {}",
            t.total_events(),
            t.dropped(),
            path.display()
        );
    }
    for (i, r) in run.per_shard.iter().enumerate() {
        println!("-- shard {i} ({} requests) --", run.shard_requests[i]);
        print_report(r);
    }
    println!(
        "== merged: {shards} shards, {placement} placement, steal {}, makespan {:.1} s ==",
        if stealing { "on" } else { "off" },
        run.makespan_s
    );
    print_report(&run.merged);
    if stealing {
        println!(
            "  steals              {:>6} out / {} in",
            run.merged.steals_out, run.merged.steals_in
        );
    }
    Ok(())
}

/// The live HTTP front door (default), or the PJRT tiny-model demo
/// with `--backend pjrt`.
fn serve(args: &Args) -> Result<()> {
    match args.get("backend") {
        Some("pjrt") => return serve_pjrt(args),
        Some(other) if other != "sim" => {
            bail!("--backend expects sim|pjrt, got `{other}`")
        }
        _ => {}
    }
    use conserve::server::admission::AdmissionConfig;
    use conserve::server::http::{HttpServer, ServeOptions};

    let mut cfg = EngineConfig::sim_a100_7b();
    args.apply_sets(&mut cfg)?;
    apply_harvest_flag(args, &mut cfg)?;
    apply_prefix_flag(args, &mut cfg)?;
    let mut opts = ServeOptions {
        addr: args.get("addr").unwrap_or("127.0.0.1:8077").to_string(),
        shards: args.get_usize("shards", 2)?,
        duration_s: args.get_f64("duration", 0.0)?,
        state_dir: args.get("state-dir").map(std::path::PathBuf::from),
        ckpt_every: args.get_usize("ckpt-every", 50)? as u64,
        trace_out: args.get("trace-out").map(std::path::PathBuf::from),
        ..ServeOptions::default()
    };
    if !parse_switch("admission", args.get("admission").unwrap_or("on"))? {
        opts.admission = AdmissionConfig::admit_all();
    }

    let server = HttpServer::bind(cfg, opts)?;
    println!("conserve serve: listening on http://{}", server.local_addr());
    println!("  POST /v1/completions  POST /v1/batches  GET /v1/batches/{{id}}");
    println!("  GET /healthz          POST /drain");
    let summary = server.run()?;

    println!("serve summary: {}", summary.to_json());
    if !summary.failed_online.is_empty() {
        println!(
            "  {} online requests failed on dead shards (each answered with a structured 503)",
            summary.failed_online.len()
        );
    }
    print_report(&summary.report);
    if summary.lost_online > 0 {
        bail!(
            "{} accepted online requests were lost (accepted {} != completed {} + cancelled {} + failed {})",
            summary.lost_online,
            summary.accepted_online,
            summary.completed_online,
            summary.cancelled_online,
            summary.failed_online.len()
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn serve_pjrt(_args: &Args) -> Result<()> {
    bail!("this binary was built without the `pjrt` feature; rebuild with --features pjrt")
}

#[cfg(not(feature = "pjrt"))]
fn profile(_args: &Args) -> Result<()> {
    bail!("this binary was built without the `pjrt` feature; rebuild with --features pjrt")
}

#[cfg(feature = "pjrt")]
fn serve_pjrt(args: &Args) -> Result<()> {
    use conserve::backend::PjrtBackend;
    use conserve::profiler::LatencyProfile;
    use conserve::request::{Class, Request};
    use conserve::runtime::tokenizer;
    use conserve::server::{ArrivalSource, ServingEngine};
    use conserve::util::rng::Rng;
    use conserve::US_PER_SEC;

    let mut cfg = EngineConfig::real_tiny();
    args.apply_sets(&mut cfg)?;
    let duration = args.get_f64("duration", 20.0)?;
    let rate = args.get_f64("rate", 2.0)?;
    let artifacts = args.get("artifacts").unwrap_or("artifacts");

    let backend = PjrtBackend::load(artifacts, cfg.seed, cfg.sched.safepoint_layers)?;
    let clock = backend.clock();
    println!("profiling PJRT backend ...");
    let mut backend = backend;
    let profile = LatencyProfile::profile(&mut backend, 128, 8, 128)?;
    println!("profile: {:?}", profile.c);

    // trace-driven live load: online gamma arrivals + offline pool
    let mut rng = Rng::new(cfg.seed);
    let mut lg = workload::LoadGen::new(cfg.seed ^ 1, rate, 1.0);
    let mut events = Vec::new();
    let mut id = 1u64;
    for t in lg.arrivals_until(duration) {
        let l = Lengths::online_tiny().sample(&mut rng);
        let prompt = workload::datasets::synth_prompt(&mut rng, l.input);
        let plen = prompt.len();
        events.push(Request::new(id, Class::Online, prompt, plen, l.output, t));
        id += 1;
    }
    for _ in 0..args.get_usize("offline-pool", 24)? {
        let l = Lengths::offline_tiny().sample(&mut rng);
        let prompt = workload::datasets::synth_prompt(&mut rng, l.input);
        let plen = prompt.len();
        events.push(Request::new(id, Class::Offline, prompt, plen, l.output, 0));
        id += 1;
    }

    let arrivals = ArrivalSource::from_trace(events);
    let mut engine = ServingEngine::new(cfg, backend, clock, profile, arrivals);
    let end = engine.run((duration * US_PER_SEC as f64) as u64 * 4);
    let report = Report::from_engine(&engine.rec, engine.cfg.sched.policy, end);
    print_report(&report);

    // show one served completion
    if let Some(r) = engine
        .table
        .values()
        .find(|r| r.class == Class::Online && !r.output.is_empty())
    {
        println!(
            "\nsample completion for request {}:\n  prompt: {:?}\n  output: {:?}",
            r.id,
            tokenizer::detokenize(&r.prompt[..r.prompt.len().min(48)]),
            tokenizer::detokenize(&r.output)
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn profile(args: &Args) -> Result<()> {
    use conserve::backend::PjrtBackend;
    use conserve::profiler::LatencyProfile;

    let artifacts = args.get("artifacts").unwrap_or("artifacts");
    let mut backend = PjrtBackend::load(artifacts, 7, 1)?;
    let profile = LatencyProfile::profile(&mut backend, 128, 8, 128)?;
    println!("fitted latency model (µs): t = {:.1} + {:.3}*prefill_tok + {:.1}*decode_seq + {:.4}*ctx_tok",
        profile.c[0], profile.c[1], profile.c[2], profile.c[3]);
    println!("json: {}", profile.to_json());
    Ok(())
}

fn trace(args: &Args) -> Result<()> {
    // `--in FILE`: summarize a Perfetto trace written by --trace-out
    // instead of emitting the synthetic BurstGPT rate series.
    if let Some(path) = args.get("in") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {path}"))?;
        let top_k = args.get_usize("top", 10)?;
        let max_spans = args.get_usize("spans", 20)?;
        print!("{}", conserve::trace::perfetto::summarize(&text, top_k, max_spans)?);
        return Ok(());
    }
    let duration = args.get_f64("duration", 900.0)?;
    let rate = args.get_f64("rate", 2.0)?;
    let arrivals = workload::trace::burstgpt_like_arrivals(42, duration, rate, 1.0);
    println!("t_s,requests,tokens_per_s");
    for (t, n, toks) in workload::trace::rate_series(&arrivals, 1152, 30.0, duration) {
        println!("{t:.0},{n},{toks:.0}");
    }
    Ok(())
}

fn print_report(r: &Report) {
    println!("== {} ==", r.policy);
    println!("  duration            {:>10.1} s", r.duration_s);
    println!("  online P99 TTFT     {:>10.1} ms", r.online_p99_ttft_ms);
    println!("  online P99 TPOT     {:>10.1} ms", r.online_p99_tpot_ms);
    println!("  online mean TTFT    {:>10.1} ms", r.online_mean_ttft_ms);
    println!("  gen throughput      {:>10.0} tok/s (online {:.0}, offline {:.0})",
        r.total_gen_tput, r.online_gen_tput, r.offline_gen_tput);
    println!("  processed tput      {:>10.0} tok/s (online {:.0}, offline {:.0})",
        r.total_processed_tput, r.online_processed_tput, r.offline_processed_tput);
    println!("  finished            {:>6} online / {} offline",
        r.online_finished, r.offline_finished);
    println!("  preemptions         {:>6} (layer aborts {})", r.preemptions, r.layer_aborts);
    println!("  ckpt/prefetch blks  {:>6} / {}", r.ckpt_blocks, r.prefetch_blocks);
    println!("  blocking swap       {:>10.1} ms", r.blocking_swap_ms);
    if r.ckpt_flush_records > 0 || r.urgency_restamps > 0 {
        println!(
            "  flush recs/restamps {:>6} / {}",
            r.ckpt_flush_records, r.urgency_restamps
        );
    }
    if r.harvest_decisions > 0 {
        println!(
            "  harvest decisions   {:>6} ({} tighten / {} open)",
            r.harvest_decisions, r.harvest_tightens, r.harvest_opens
        );
    }
    println!("  TTFT SLO violations {:>9.1} %", r.ttft_violations * 100.0);
}
