//! `bench_jobs` — deadline-aware job manager acceptance bench.
//!
//! Serves the FIFO-buster workload (`workload::jobs::mega_plus_tight`):
//! one tenant's mega-job submitted at t=0 — deep enough that each
//! shard's KV pool cannot hold its bucket at once, so a queue persists —
//! followed by small tight-deadline jobs from other tenants, plus
//! bursty online background traffic. The same workload runs twice:
//!
//! * **fifo** — plain FIFO offline admission, affinity placement;
//! * **urgency** — EDF urgency + weighted fair share
//!   (`fair_share=true`), deadline-aware placement, urgency-ordered
//!   steal donation.
//!
//! Acceptance (asserted here):
//!
//! * both modes complete every job (scheduling never loses work);
//! * FIFO misses tight deadlines (the race is real: attainment < 1);
//! * urgency scheduling strictly beats FIFO on job-level deadline
//!   attainment;
//! * the online TTFT-violation rate does not regress under urgency
//!   scheduling (deadline pressure never outranks the SLO class).
//!
//! Results go to `BENCH_jobs.json` (schema: rust/PERF.md §6). Scale
//! with `JOBS_BENCH_MEGA` (mega-job request count, default 160; CI
//! smoke uses 120 — keep `mega / 4 shards` above the ~21-request
//! per-shard KV capacity or FIFO admits everything at once and the
//! modes cannot differ).

use conserve::batch::{run_jobs, JobManager, JobRunOpts, NOMINAL_TOK_PER_S};
use conserve::config::EngineConfig;
use conserve::request::{Class, Request};
use conserve::shard::Placement;
use conserve::util::json::{arr, num, obj, Json};
use conserve::util::rng::Rng;
use conserve::workload::jobs::{mega_plus_tight, MegaTightConfig};
use conserve::workload::trace::onoff_trace;
use std::time::Instant;

const N_SHARDS: usize = 4;

struct ModeRow {
    label: &'static str,
    wall_s: f64,
    attainment: f64,
    jobs_met: usize,
    jobs_missed: usize,
    out: conserve::batch::JobRunOutcome,
}

fn main() {
    let mega: usize = std::env::var("JOBS_BENCH_MEGA")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(160);
    let svc = NOMINAL_TOK_PER_S * N_SHARDS as f64;
    let jobs_cfg = MegaTightConfig {
        mega_requests: mega,
        svc_tok_per_s: svc,
        ..MegaTightConfig::default()
    };
    let inputs = mega_plus_tight(&jobs_cfg);
    let total_job_tokens: u64 = inputs
        .iter()
        .flat_map(|j| &j.requests)
        .map(|r| (r.prompt_len + r.max_new_tokens) as u64)
        .sum();
    let mega_est_s = total_job_tokens as f64 / svc;
    let duration_s = (mega_est_s * 6.0).max(60.0);
    let n_requests: usize = inputs.iter().map(|j| j.requests.len()).sum();

    println!(
        "=== bench_jobs ({} jobs / {n_requests} requests, mega={mega}, {N_SHARDS} shards, est drain {:.1}s) ===",
        inputs.len(),
        mega_est_s
    );

    let modes: [(&str, bool, Placement); 2] = [
        ("fifo", false, Placement::affinity()),
        ("urgency", true, Placement::deadline()),
    ];
    let mut rows: Vec<ModeRow> = Vec::new();
    for (label, fair_share, placement) in modes {
        let mut cfg = EngineConfig::sim_a100_7b();
        cfg.sched.fair_share = fair_share;
        // identical workload per mode: same job manager construction
        // gives identical submission ids and sampler states
        let mut jm = JobManager::new(svc);
        let mut events: Vec<Request> = Vec::new();
        for input in &inputs {
            jm.admit(input, &mut events);
        }
        // bursty online background (ids 1.. are disjoint from job sids)
        let mut rng = Rng::new(7);
        for (i, &t) in onoff_trace(42, duration_s, 30.0, 8.0, 2.0).iter().enumerate() {
            let input = rng.range_usize(64, 256);
            let output = rng.range_usize(8, 24);
            events.push(Request::new(
                1 + i as u64,
                Class::Online,
                vec![],
                input,
                output,
                t,
            ));
        }
        let opts = JobRunOpts {
            placement,
            ..JobRunOpts::new(N_SHARDS, duration_s)
        };
        let t0 = Instant::now();
        let out = run_jobs(&cfg, &opts, jm.board().clone(), events);
        let wall_s = t0.elapsed().as_secs_f64();
        let jobs_met = out
            .jobs
            .iter()
            .filter(|j| j.progress.met_deadline() == Some(true))
            .count();
        let jobs_missed = out
            .jobs
            .iter()
            .filter(|j| j.progress.deadline > 0)
            .count()
            - jobs_met;
        let m = &out.run.merged;
        println!(
            "{label:>8}: wall={wall_s:>6.2}s makespan={:>7.1}s attainment={:>5.1}% (jobs {jobs_met} met / {jobs_missed} missed) p99TTFT={:>8.1}ms viol={:>5.2}% offline_gen={:>6.0} tok/s steals(out/in)={}/{}",
            out.run.makespan_s,
            out.job_attainment * 100.0,
            m.online_p99_ttft_ms,
            m.ttft_violations * 100.0,
            m.offline_gen_tput,
            m.steals_out,
            m.steals_in,
        );
        rows.push(ModeRow {
            label,
            wall_s,
            attainment: out.job_attainment,
            jobs_met,
            jobs_missed,
            out,
        });
    }

    // ---- acceptance ----
    let fifo = &rows[0];
    let urgency = &rows[1];
    for row in &rows {
        assert!(
            row.out.jobs.iter().all(|j| j.progress.done()),
            "{}: every job must complete within the duration cap",
            row.label
        );
        assert_eq!(
            row.out.run.merged.jobs_completed,
            row.out.jobs.len() as u64,
            "{}: board and recorder must agree on completed jobs",
            row.label
        );
    }
    assert!(
        fifo.attainment < 1.0,
        "the workload must make FIFO miss deadlines (attainment {:.2})",
        fifo.attainment
    );
    assert!(
        urgency.attainment > fifo.attainment,
        "urgency scheduling must beat FIFO on deadline attainment: {:.2} vs {:.2}",
        urgency.attainment,
        fifo.attainment
    );
    assert!(
        urgency.out.run.merged.ttft_violations
            <= fifo.out.run.merged.ttft_violations + 0.005,
        "online SLO violations must not regress under urgency scheduling: {:.4} vs {:.4}",
        urgency.out.run.merged.ttft_violations,
        fifo.out.run.merged.ttft_violations
    );
    println!(
        "attainment: urgency {:.1}% vs fifo {:.1}% (+{:.1} pts)",
        urgency.attainment * 100.0,
        fifo.attainment * 100.0,
        (urgency.attainment - fifo.attainment) * 100.0
    );

    // ---- emit BENCH_jobs.json (schema documented in rust/PERF.md §6) ----
    let mode_row = |row: &ModeRow| {
        let m = &row.out.run.merged;
        obj(vec![
            ("mode", Json::Str(row.label.to_string())),
            ("wall_s", num(row.wall_s)),
            ("makespan_s", num(row.out.run.makespan_s)),
            ("job_attainment", num(row.attainment)),
            ("jobs_met", num(row.jobs_met as f64)),
            ("jobs_missed", num(row.jobs_missed as f64)),
            ("request_deadline_met", num(m.deadline_met as f64)),
            ("request_deadline_missed", num(m.deadline_missed as f64)),
            ("online_p99_ttft_ms", num(m.online_p99_ttft_ms)),
            ("online_p99_tpot_ms", num(m.online_p99_tpot_ms)),
            ("ttft_violation_rate", num(m.ttft_violations)),
            ("offline_gen_tok_s", num(m.offline_gen_tput)),
            ("steals_out", num(m.steals_out as f64)),
            ("steals_in", num(m.steals_in as f64)),
            (
                "per_tenant",
                arr(m.per_tenant.iter().map(conserve::metrics::TenantCounters::to_json)),
            ),
        ])
    };
    let json = obj(vec![
        ("jobs", num(inputs.len() as f64)),
        ("requests", num(n_requests as f64)),
        ("mega_requests", num(mega as f64)),
        ("shards", num(N_SHARDS as f64)),
        ("svc_tok_per_s", num(svc)),
        ("est_drain_s", num(mega_est_s)),
        ("modes", arr(rows.iter().map(mode_row))),
        (
            "attainment_urgency_minus_fifo",
            num(urgency.attainment - fifo.attainment),
        ),
    ]);
    let out_path =
        std::env::var("JOBS_BENCH_OUT").unwrap_or_else(|_| "BENCH_jobs.json".into());
    std::fs::write(&out_path, json.to_string()).expect("write BENCH_jobs.json");
    println!("\nwrote {out_path}");
    let _ = Json::parse(&json.to_string()).expect("self-emitted json parses");
    println!("bench_jobs OK");
}
