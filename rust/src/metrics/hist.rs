//! Fixed-bucket log-scale latency histogram: O(1) record, O(buckets)
//! quantile, bounded relative error.
//!
//! Values (µs) are bucketed HDR-style: 64 exact buckets below 64, then
//! 64 sub-buckets per power of two. The widest bucket spans `2^(e-6)`
//! values at magnitude `2^e`, so any reported quantile is within
//! `1/64 ≈ 1.6 %` of the true sample. The bucket array is fixed-size
//! (3 776 entries, ~30 KB) and lazily allocated, so empty histograms —
//! e.g. silent windows of a timeseries — cost nothing.

/// Sub-bucket resolution: 2^6 = 64 sub-buckets per octave.
const SUB_BITS: u32 = 6;
const SUB: u64 = 1 << SUB_BITS;

/// Largest index: `index_of(u64::MAX)` = (63-6)*64 + 127.
pub const N_BUCKETS: usize = ((63 - SUB_BITS as usize) * SUB as usize) + 2 * SUB as usize;

#[inline]
fn index_of(v: u64) -> usize {
    if v < SUB {
        v as usize
    } else {
        let e = 63 - v.leading_zeros(); // floor(log2 v), >= SUB_BITS
        let shift = e - SUB_BITS;
        ((shift as u64 * SUB) + (v >> shift)) as usize
    }
}

/// Midpoint of the bucket at `idx` (its representative value).
#[inline]
fn value_of(idx: usize) -> u64 {
    if idx < SUB as usize {
        idx as u64
    } else {
        let shift = (idx as u64 / SUB) - 1;
        let mantissa = SUB + (idx as u64 % SUB);
        (mantissa << shift) + ((1u64 << shift) >> 1)
    }
}

/// Streaming log-scale histogram over `u64` microsecond samples.
#[derive(Debug, Clone, Default)]
pub struct LogHistogram {
    /// Lazily sized to [`N_BUCKETS`] on first record.
    counts: Vec<u64>,
    total: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// O(1): bump one bucket.
    #[inline]
    pub fn record(&mut self, v: u64) {
        if self.counts.is_empty() {
            self.counts = vec![0; N_BUCKETS];
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.counts[index_of(v)] += 1;
        self.total += 1;
        self.sum = self.sum.saturating_add(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Exact mean of the recorded samples (the running sum is exact).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Nearest-rank quantile, `p` in [0, 100]. Returns the representative
    /// value of the bucket holding the rank, clamped into the observed
    /// [min, max] range; 0 for an empty histogram. O(buckets).
    pub fn quantile(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.total as f64).ceil() as u64;
        let rank = rank.clamp(1, self.total);
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return value_of(idx).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Samples recorded in buckets strictly above the bucket of `v`
    /// (boundary-bucket samples count as "not above": resolution-bounded
    /// approximation of `count(x > v)`).
    pub fn count_above(&self, v: u64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let cut = index_of(v);
        self.counts[cut + 1..].iter().sum()
    }

    pub fn clear(&mut self) {
        self.counts.clear();
        self.total = 0;
        self.sum = 0;
        self.min = 0;
        self.max = 0;
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        if other.total == 0 {
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0; N_BUCKETS];
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum = self.sum.saturating_add(other.sum);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        let mut h = LogHistogram::new();
        for v in 0..64u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 64);
        assert_eq!(h.quantile(100.0), 63);
        assert_eq!(h.quantile(0.0), 0);
        // rank 32 -> value 31 (nearest rank, exact region)
        assert_eq!(h.quantile(50.0), 31);
    }

    #[test]
    fn index_is_monotone_and_in_range() {
        let mut last = 0usize;
        for v in 0..256u64 {
            let idx = index_of(v);
            assert!(idx >= last, "index not monotone at {v}");
            last = idx;
        }
        for e in 8..64u32 {
            for off in [0u64, 1, 3] {
                let v = (1u64 << e) + (off << (e - 3));
                let idx = index_of(v);
                assert!(idx >= last, "index not monotone at {v}");
                assert!(idx < N_BUCKETS, "index {idx} out of range at {v}");
                last = idx;
            }
        }
        assert!(index_of(u64::MAX) < N_BUCKETS);
    }

    #[test]
    fn representative_within_bucket_error() {
        for &v in &[100u64, 1_000, 65_536, 200_000, 1_500_000, u32::MAX as u64] {
            let rep = value_of(index_of(v));
            let err = (rep as f64 - v as f64).abs() / v as f64;
            assert!(err <= 1.0 / 64.0 + 1e-9, "v={v} rep={rep} err={err}");
        }
    }

    #[test]
    fn quantile_tracks_distribution() {
        let mut h = LogHistogram::new();
        for i in 1..=10_000u64 {
            h.record(i * 100); // 100 .. 1_000_000
        }
        let p50 = h.quantile(50.0) as f64;
        let p99 = h.quantile(99.0) as f64;
        assert!((p50 - 500_000.0).abs() / 500_000.0 < 0.02, "p50={p50}");
        assert!((p99 - 990_000.0).abs() / 990_000.0 < 0.02, "p99={p99}");
        assert_eq!(h.quantile(100.0), 1_000_000);
        assert_eq!(h.mean(), 500_050.0);
    }

    #[test]
    fn count_above_threshold() {
        let mut h = LogHistogram::new();
        for v in [90_000u64, 100_000, 200_000, 2_000_000] {
            h.record(v);
        }
        assert_eq!(h.count_above(1_500_000), 1);
        assert_eq!(h.count_above(u64::MAX - 1), 0);
    }

    #[test]
    fn merge_combines() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record(10);
        b.record(1_000_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.quantile(100.0), 1_000_000);
        let empty = LogHistogram::new();
        a.merge(&empty);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn empty_histogram_is_cheap_and_sane() {
        let h = LogHistogram::new();
        assert_eq!(h.quantile(99.0), 0);
        assert_eq!(h.count_above(0), 0);
        assert_eq!(h.mean(), 0.0);
        assert!(h.is_empty());
    }
}
