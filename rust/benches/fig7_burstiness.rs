//! Figure 7 — "Overall serving performance under varying CVs and request
//! rates."
//!
//! Left column: CV sweep at 2 req/s. Right column: rate sweep at CV 1.
//! Paper shape: online P99 TTFT grows superlinearly with CV and rate for
//! every system; ConServe stays within ~25% of Online-Only's ideal
//! latency while vLLM++ is off the chart (>= 4980 ms); ConServe's
//! offline throughput matches or exceeds vLLM++ (whose blocking swaps
//! stall the GPU).

use conserve::config::EngineConfig;
use conserve::report::{compare_policies, Report};
use conserve::scheduler::Policy;
use conserve::workload::{LoadGen, Lengths};

fn run_point(cfg: &EngineConfig, rate: f64, cv: f64, duration: f64) -> Vec<Report> {
    let mut lg = LoadGen::new(cfg.seed, rate, cv);
    let arrivals = lg.arrivals_until(duration);
    compare_policies(
        cfg,
        &[Policy::OnlineOnly, Policy::VllmPP, Policy::ConServe],
        &arrivals,
        Lengths::Fixed {
            input: 1024,
            output: 128,
        },
        |p| if p == Policy::OnlineOnly { 0 } else { 1200 },
        Lengths::offline_paper(),
        duration,
    )
}

fn print_point(label: &str, rs: &[Report]) {
    println!(
        "{label:<14} | TTFT(ms): OO {:>7.0}  vLLM++ {:>8.0}  CS {:>7.0} | TPOT(ms): OO {:>5.0} vLLM++ {:>6.0} CS {:>5.0} | offl proc/s: vLLM++ {:>6.0} CS {:>6.0}",
        rs[0].online_p99_ttft_ms,
        rs[1].online_p99_ttft_ms,
        rs[2].online_p99_ttft_ms,
        rs[0].online_p99_tpot_ms,
        rs[1].online_p99_tpot_ms,
        rs[2].online_p99_tpot_ms,
        rs[1].offline_processed_tput,
        rs[2].offline_processed_tput,
    );
}

fn main() {
    let cfg = EngineConfig::sim_a100_7b();
    let duration = 300.0;

    println!("=== left column: CV sweep @ 2 req/s ===");
    let cvs = [0.5, 1.0, 2.0, 4.0];
    let mut cs_ttft_by_cv = Vec::new();
    let mut oo_ttft_by_cv = Vec::new();
    for &cv in &cvs {
        let rs = run_point(&cfg, 2.0, cv, duration);
        print_point(&format!("cv={cv}"), &rs);
        oo_ttft_by_cv.push(rs[0].online_p99_ttft_ms);
        cs_ttft_by_cv.push(rs[2].online_p99_ttft_ms);
        assert!(
            rs[1].online_p99_ttft_ms > 2.0 * rs[2].online_p99_ttft_ms,
            "vLLM++ must be far above ConServe at cv={cv}"
        );
        // ConServe stays within the SLO at moderate burstiness (the
        // gap-to-ideal check lives in the rate sweep; at very low CV the
        // ideal P99 is so small that ratios are uninformative)
        if cv <= 1.0 {
            assert!(
                rs[2].online_p99_ttft_ms < 1500.0,
                "cv={cv}: ConServe {:.0}ms over SLO",
                rs[2].online_p99_ttft_ms
            );
        }
        assert!(
            rs[2].offline_processed_tput >= 0.7 * rs[1].offline_processed_tput,
            "ConServe offline throughput must be competitive at cv={cv}"
        );
    }
    // superlinear growth with burstiness
    assert!(
        cs_ttft_by_cv[3] > cs_ttft_by_cv[0],
        "TTFT must grow with CV: {cs_ttft_by_cv:?}"
    );

    println!("\n=== right column: rate sweep @ cv=1 ===");
    // rate 4 is this testbed's saturation knee (EXPERIMENTS.md): every
    // policy collapses there, so the sweep stops at 3 like the paper's
    // sweep stops below their knee
    let rates = [1.0, 2.0, 3.0];
    let mut cs_ttft_by_rate = Vec::new();
    for &rate in &rates {
        let rs = run_point(&cfg, rate, 1.0, duration);
        print_point(&format!("rate={rate}/s"), &rs);
        cs_ttft_by_rate.push(rs[2].online_p99_ttft_ms);
        // ConServe tracks the ideal latency at the paper's load points
        // (paper: within 25%; we allow 2x for percentile noise). At
        // near-capacity rates the gap widens because the SLO-aware budget
        // rides TPOT at its cap (EXPERIMENTS.md); there the robust claim
        // is staying orders of magnitude below vLLM++.
        let gap = rs[2].online_p99_ttft_ms / rs[0].online_p99_ttft_ms.max(1.0);
        if rate <= 2.0 {
            assert!(
                gap < 2.0,
                "ConServe must track Online-Only at rate={rate} (gap {gap:.2}x)"
            );
        } else {
            assert!(
                rs[2].online_p99_ttft_ms < rs[1].online_p99_ttft_ms / 3.0,
                "ConServe must stay far below vLLM++ at rate={rate}"
            );
        }
    }
    assert!(
        cs_ttft_by_rate[2] > cs_ttft_by_rate[0],
        "TTFT must grow with rate: {cs_ttft_by_rate:?}"
    );
    println!("\nfig7 shape OK");
}
