//! Harvesting under ON/OFF bursts (paper §6.3.1) on the simulated
//! A100/Llama-2-7B testbed: online load alternates between near-capacity
//! and zero; ConServe harvests the OFF phases for offline work and
//! scales back within milliseconds when the ON phase returns.
//!
//! This example demonstrates the simulation API — the same experiment
//! the fig6 bench runs, but as a user-facing driver with a compact
//! phase-by-phase printout.
//!
//! ```bash
//! cargo run --release --example burst_onoff
//! ```

use conserve::config::EngineConfig;
use conserve::report::SimExperiment;
use conserve::workload::trace::onoff_trace;
use conserve::workload::Lengths;

fn main() {
    let cfg = EngineConfig::sim_a100_7b();
    let duration = 360.0;
    let phase = 90.0;
    let arrivals = onoff_trace(7, duration, phase, 3.0, 1.0);

    println!(
        "ON/OFF experiment: {}s, {}s phases, {} online arrivals, offline pool 2000\n",
        duration,
        phase,
        arrivals.len()
    );

    let report = SimExperiment {
        cfg: cfg.clone(),
        online_arrivals: arrivals,
        online_lengths: Lengths::Fixed {
            input: 1024,
            output: 128,
        },
        offline_pool: 2000,
        offline_lengths: Lengths::offline_paper(),
        duration_s: duration,
    }
    .run();

    println!(
        "{:>6} {:>6} {:>12} {:>12} {:>13} {:>13}",
        "t_s", "phase", "p99TTFT_ms", "p99TPOT_ms", "online_tok/s", "offline_tok/s"
    );
    for (w_on, w_all) in report.online_timeseries.iter().zip(&report.all_timeseries) {
        let on = ((w_on.start_s / phase) as u64) % 2 == 0;
        println!(
            "{:>6.0} {:>6} {:>12.0} {:>12.0} {:>13.0} {:>13.0}",
            w_on.start_s,
            if on { "ON" } else { "OFF" },
            w_on.p99_ttft_ms,
            w_on.p99_tpot_ms,
            w_on.processed_per_s,
            w_all.processed_per_s - w_on.processed_per_s
        );
    }

    println!(
        "\noverall: P99 TTFT {:.0} ms (SLO {}), P99 TPOT {:.0} ms (SLO {}), \
         offline harvest {:.0} tok/s, {} preemptions ({} layer aborts)",
        report.online_p99_ttft_ms,
        cfg.sched.slo.ttft_ms,
        report.online_p99_tpot_ms,
        cfg.sched.slo.tpot_ms,
        report.offline_processed_tput,
        report.preemptions,
        report.layer_aborts
    );
    // transition windows dominate the overall p99 at this phase length
    assert!(report.online_p99_ttft_ms < cfg.sched.slo.ttft_ms * 2.0);
    assert!(report.offline_processed_tput > 500.0);
    println!("burst_onoff OK");
}
