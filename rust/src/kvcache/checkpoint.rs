//! Adaptive checkpointing controller (paper §4.4 "Adaptive Checkpointing
//! Policy"): decides *how many* KV blocks to checkpoint per iteration.
//!
//! Inspired by asynchronous-swap OS designs (Hermit) and random early
//! detection: checkpointing starts when free GPU memory drops below a
//! watermark (default 50%), begins with a small quota, ramps up while
//! memory usage keeps rising (to match the consumption rate), and decays
//! when pressure subsides — bounding host-memory and PCIe usage when the
//! GPU is not actually under pressure.

/// Iteration-scoped controller state.
#[derive(Debug, Clone)]
pub struct CkptController {
    /// Free-fraction watermark below which checkpointing activates.
    pub watermark: f64,
    /// Current per-iteration block quota.
    quota: usize,
    /// Quota bounds.
    min_quota: usize,
    max_quota: usize,
    /// Free fraction observed last iteration.
    last_free: f64,
}

impl CkptController {
    pub fn new(watermark: f64, max_quota: usize) -> Self {
        Self {
            watermark,
            quota: 0,
            min_quota: 1,
            max_quota: max_quota.max(1),
            last_free: 1.0,
        }
    }

    /// Called once per scheduling iteration with the current free GPU
    /// fraction; returns the number of blocks that may be checkpointed
    /// this iteration.
    pub fn step(&mut self, free_frac: f64) -> usize {
        if free_frac >= self.watermark {
            // no pressure: decay quota quickly, stop checkpointing
            self.quota = 0;
        } else if self.quota == 0 {
            // activation: start with a small quota (§4.4 "only checkpoint
            // a small number of offline requests first")
            self.quota = self.min_quota;
        } else if free_frac < self.last_free - 1e-9 {
            // pressure rising: ramp up multiplicatively to catch up with
            // the consumption rate
            self.quota = (self.quota * 2).min(self.max_quota);
        } else if free_frac > self.last_free + 1e-9 {
            // pressure easing: back off additively
            self.quota = self.quota.saturating_sub(1).max(self.min_quota);
        }
        self.last_free = free_frac;
        self.quota
    }

    pub fn active(&self) -> bool {
        self.quota > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_above_watermark() {
        let mut c = CkptController::new(0.5, 64);
        assert_eq!(c.step(0.9), 0);
        assert_eq!(c.step(0.6), 0);
        assert!(!c.active());
    }

    #[test]
    fn ramps_up_under_rising_pressure() {
        let mut c = CkptController::new(0.5, 64);
        let q1 = c.step(0.45);
        let q2 = c.step(0.40);
        let q3 = c.step(0.30);
        let q4 = c.step(0.20);
        assert!(q1 >= 1);
        assert!(q2 > q1 && q3 > q2 && q4 > q3, "{q1} {q2} {q3} {q4}");
    }

    #[test]
    fn caps_at_max_quota() {
        let mut c = CkptController::new(0.5, 8);
        let mut free = 0.49;
        let mut q = 0;
        for _ in 0..20 {
            free -= 0.02;
            q = c.step(free);
        }
        assert_eq!(q, 8);
    }

    #[test]
    fn backs_off_when_pressure_eases() {
        let mut c = CkptController::new(0.5, 64);
        c.step(0.4);
        c.step(0.3);
        c.step(0.2);
        let high = c.step(0.1);
        let lower = c.step(0.15); // freeing memory
        assert!(lower < high);
        // fully recovered: stops
        assert_eq!(c.step(0.8), 0);
        assert!(!c.active());
    }

    #[test]
    fn steady_pressure_keeps_trickle() {
        let mut c = CkptController::new(0.5, 64);
        assert_eq!(c.step(0.4), 1);
        assert_eq!(c.step(0.4), 1);
    }
}
