//! # ConServe — GPU harvesting for LLM online/offline co-serving
//!
//! A reproduction of *"ConServe: Harvesting GPUs for Low-Latency and
//! High-Throughput Large Language Model Serving"* (Qiao et al., 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time, Python)** — Pallas kernels + a layered JAX
//!   Llama-architecture model, AOT-lowered to HLO text artifacts
//!   (`python/compile/`, `make artifacts`).
//! * **L3 (this crate)** — the serving system: a unified preemptive
//!   scheduler (paper Alg. 1/2), an SLO-aware batch-budget policy, a paged
//!   KV-cache manager with incremental checkpointing and background
//!   prefetching, a preemptible layer-stepped execution engine, workload
//!   generation, metrics, and baselines (`Online-Only`, `vLLM++`).
//!
//! Python never runs on the request path: the PJRT backend (cargo
//! feature `pjrt`, requires the `xla` crate) loads the AOT artifacts
//! through the PJRT C API and serves requests end-to-end from Rust. A
//! calibrated discrete-event backend ([`backend::SimBackend`]) models
//! the paper's A100/Llama-2-7B testbed and regenerates every evaluation
//! figure (see `rust/benches/`) — the simulator and all policy machinery
//! build dependency-light (`anyhow` only) with default features.
//!
//! One engine is one worker shard; [`shard`] scales the same machinery
//! to N workers behind a placement layer with nothing shared on any hot
//! path (ids carry their shard index, so routing is a mask+shift).
//! [`batch`] layers an offline *job manager* on top: tenants, priority
//! tiers, soft deadlines with EDF urgency (driving placement, work
//! stealing and a fair-share pick order), and a durable JSONL store
//! that makes batch jobs survive restarts with byte-identical outputs.
//!
//! Quickstart: `examples/quickstart.rs`; architecture (module map, the
//! schedule→execute→commit loop, the id layout, shard ownership):
//! `rust/ARCHITECTURE.md`; hot-path design (slab arenas, scratch
//! buffers, streaming metrics): `rust/PERF.md`.

pub mod backend;
pub mod batch;
pub mod clock;
pub mod config;
pub mod kvcache;
pub mod metrics;
pub mod profiler;
pub mod report;
pub mod request;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod shard;
pub mod trace;
pub mod util;
pub mod workload;

/// Microsecond timestamps; all scheduling math is integer µs to keep the
/// discrete-event simulation deterministic.
pub type TimeUs = u64;

/// Microseconds per second (`TimeUs` scale factor).
pub const US_PER_SEC: u64 = 1_000_000;
/// Microseconds per millisecond (`TimeUs` scale factor).
pub const US_PER_MS: u64 = 1_000;

// ---- curated re-export surface ----
// The types an embedder touches to stand up a serving stack, one hop
// from the crate root; everything else stays module-qualified.

/// Engine + memory + model-length configuration (presets:
/// [`EngineConfig::sim_a100_7b`], [`EngineConfig::real_tiny`]).
pub use config::EngineConfig;
/// A request's packed (generation, shard, slot) handle.
pub use request::RequestId;
/// One worker's serving loop: schedule → execute → commit.
pub use server::ServingEngine;
/// Multi-worker routing: trace partitioning and live placement.
pub use shard::{Placement, ShardRouter, ShardedClient};
/// Cross-shard offline work stealing (checkpoint-backed migration).
pub use shard::{StealConfig, StealCoordinator};
/// Deadline-aware offline job management: admission, EDF urgency,
/// poll-able progress, durable resume (`--state-dir` / `--resume`).
pub use batch::{JobBoard, JobManager, JobSpec, JobStore};
