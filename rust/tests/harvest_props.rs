//! Property tests for the closed-loop harvest controller
//! (`conserve::scheduler::harvest`):
//!
//! * **Replay** — the audit trail of a full engine run replays
//!   byte-identically through the pure decision core, and the whole
//!   run is deterministic (two identical runs, identical trails);
//! * **Clamps & audit completeness** — the live budget never leaves
//!   `[min_budget, max_budget]` and never changes without a logged
//!   decision (consecutive records chain exactly);
//! * **Lockstep spike trace** — with the controller on, the online
//!   TTFT-violation rate stays no worse than a static-tight baseline
//!   while offline throughput is at least as high;
//! * **Monotonicity** — a strictly worse observed percentile never
//!   raises the budget within one window (pure-core property).

use conserve::backend::{CostModel, SimBackend};
use conserve::clock::Clock;
use conserve::config::EngineConfig;
use conserve::profiler::LatencyProfile;
use conserve::report::{Report, SimExperiment};
use conserve::scheduler::harvest::{decide, replay, CtlState, Observation, Rule, Trigger};
use conserve::server::{ArrivalSource, ServingEngine};
use conserve::util::rng::Rng;
use conserve::workload::{flash_crowd_trace, Lengths};
use conserve::US_PER_SEC;

// ---------------------------------------------------------------------------
// Fixtures
// ---------------------------------------------------------------------------

/// Harvest-enabled simulation config. Layerwise preemption is off so
/// the offline token budget is the lever that bounds how long an online
/// arrival can wait behind a running offline batch — the regime the
/// controller exists for.
fn harvest_cfg() -> EngineConfig {
    let mut cfg = EngineConfig::sim_a100_7b();
    cfg.sched.harvest = true;
    cfg.sched.layerwise_preempt = false;
    cfg
}

const SPIKE_DURATION_S: f64 = 150.0;

/// The shared spike workload: steady 2 req/s online with a 3x flash
/// crowd mid-run, plus a deep offline pool submitted at t=0.
fn spike_experiment(cfg: &EngineConfig) -> SimExperiment {
    SimExperiment {
        cfg: cfg.clone(),
        online_arrivals: flash_crowd_trace(0x5B1CE, SPIKE_DURATION_S, 2.0, 75.0, 20.0, 3.0, 1.0),
        online_lengths: Lengths::online_paper(),
        offline_pool: 200,
        offline_lengths: Lengths::offline_paper(),
        duration_s: SPIKE_DURATION_S,
    }
}

/// Run the experiment's exact event trace on a single engine and return
/// it (tests need the controller's audit trail, which `Report` does not
/// carry). Mirrors `SimExperiment::run`.
fn run_engine(exp: &SimExperiment) -> ServingEngine<SimBackend> {
    let clock = Clock::virtual_at(0);
    let cost = CostModel::a100_llama2_7b();
    let backend = SimBackend::new(cost, clock.clone(), exp.cfg.sched.safepoint_layers);
    let profile = {
        let pclock = Clock::virtual_at(0);
        let mut pb = SimBackend::new(cost, pclock, exp.cfg.sched.safepoint_layers);
        LatencyProfile::profile(&mut pb, 4096, 128, 2048).expect("profiling failed")
    };
    let arrivals = ArrivalSource::from_trace(exp.events());
    let mut engine = ServingEngine::new(exp.cfg.clone(), backend, clock, profile, arrivals);
    engine.run((exp.duration_s * US_PER_SEC as f64) as u64);
    engine
}

// ---------------------------------------------------------------------------
// (a) deterministic byte-identical audit replay
// ---------------------------------------------------------------------------

#[test]
fn audit_trail_replays_byte_identically_and_runs_are_deterministic() {
    let exp = spike_experiment(&harvest_cfg());
    let engine = run_engine(&exp);
    let ctl = engine
        .harvest_controller()
        .expect("harvest on must attach a controller");
    let trail = ctl.audit_log();
    assert!(
        trail.len() > 20,
        "a {SPIKE_DURATION_S}s run with 1s windows must decide often, got {}",
        trail.len()
    );

    // replay through the pure decision core: byte-for-byte identical
    let replayed = replay(ctl.config(), trail);
    assert_eq!(replayed.len(), trail.len());
    for (i, (a, b)) in trail.iter().zip(&replayed).enumerate() {
        assert_eq!(a.line(), b.line(), "replay diverged at decision {i}");
    }

    // the whole engine run is deterministic: a second identical run
    // produces the identical serialized trail
    let engine2 = run_engine(&exp);
    let text: Vec<String> = trail.iter().map(|r| r.line()).collect();
    let text2: Vec<String> = engine2
        .harvest_controller()
        .unwrap()
        .audit_log()
        .iter()
        .map(|r| r.line())
        .collect();
    assert_eq!(text.join("\n"), text2.join("\n"));
}

// ---------------------------------------------------------------------------
// (b) clamps, chaining, and no unaudited budget change
// ---------------------------------------------------------------------------

#[test]
fn budget_stays_clamped_and_every_change_is_audited() {
    let engine = run_engine(&spike_experiment(&harvest_cfg()));
    let ctl = engine.harvest_controller().unwrap();
    let cfg = ctl.config();
    let trail = ctl.audit_log();
    assert!(!trail.is_empty());

    // safe-start: the first decision departs from the tight end
    assert_eq!(trail[0].old_budget, cfg.min_budget);

    let mut prev_budget = cfg.min_budget;
    for (i, r) in trail.iter().enumerate() {
        // consecutive records chain exactly: the budget can only move
        // through logged decisions
        assert_eq!(
            r.old_budget, prev_budget,
            "unaudited budget change before decision {i}"
        );
        assert!(
            (cfg.min_budget..=cfg.max_budget).contains(&r.new_budget),
            "decision {i} left the clamp: {}",
            r.line()
        );
        assert!(
            (cfg.min_chunk..=cfg.max_chunk).contains(&r.new_chunk),
            "decision {i} chunk left the clamp: {}",
            r.line()
        );
        // Hold is what it says
        if r.rule == Rule::Hold {
            assert_eq!(r.old_budget, r.new_budget, "Hold changed the budget: {}", r.line());
        }
        prev_budget = r.new_budget;
    }
    // the live budget is the last audited one
    assert_eq!(ctl.budget(), prev_budget);

    // recorder counters agree with the trail
    let tightens = trail.iter().filter(|r| r.rule == Rule::Tighten).count() as u64;
    let opens = trail.iter().filter(|r| r.rule == Rule::Open).count() as u64;
    assert_eq!(engine.rec.harvest_decisions, trail.len() as u64);
    assert_eq!(engine.rec.harvest_tightens, tightens);
    assert_eq!(engine.rec.harvest_opens, opens);
    assert!(opens > 0, "calm stretches of the trace must open the budget");
}

// ---------------------------------------------------------------------------
// (c) lockstep spike trace: controller vs static-tight baseline
// ---------------------------------------------------------------------------

#[test]
fn controller_matches_tight_baseline_slo_with_more_offline_work() {
    // static-tight baseline: the controller's own floor, fixed
    let mut tight = harvest_cfg();
    tight.sched.harvest = false;
    tight.sched.max_batch_tokens = tight.sched.min_chunk;
    let tight_report: Report = spike_experiment(&tight).run();

    let ctl_report: Report = spike_experiment(&harvest_cfg()).run();
    assert!(ctl_report.harvest_decisions > 0, "controller never decided");

    // online SLO: no worse than the safest static point...
    assert!(
        ctl_report.ttft_violations <= tight_report.ttft_violations,
        "controller violated more than static-tight: {} > {}",
        ctl_report.ttft_violations,
        tight_report.ttft_violations
    );
    // ...while harvesting at least as much offline work (the budget
    // never drops below the baseline's static setting)
    assert!(
        ctl_report.offline_processed_tput >= tight_report.offline_processed_tput,
        "controller harvested less than static-tight: {} < {}",
        ctl_report.offline_processed_tput,
        tight_report.offline_processed_tput
    );
}

// ---------------------------------------------------------------------------
// (d) monotone: strictly worse percentiles never raise the budget
// ---------------------------------------------------------------------------

#[test]
fn worse_percentiles_never_raise_the_budget() {
    let cfg = conserve::scheduler::harvest::HarvestConfig::from_sched(&harvest_cfg().sched);
    let mut rng = Rng::new(0x4A12E57);
    for _ in 0..5_000 {
        let state = CtlState {
            budget: rng.range(cfg.min_budget as u64, cfg.max_budget as u64 + 1) as usize,
            calm: rng.range(0, u64::from(cfg.calm_windows) + 1) as u32,
        };
        let base = Observation {
            p99_ttft_us: rng.range(0, 3_000_000),
            p99_tpot_us: rng.range(0, 300_000),
            ttft_samples: rng.range(1, 500),
            online_waiting: rng.range(0, 8),
        };
        // strictly worse: same window population, higher percentiles
        let worse = Observation {
            p99_ttft_us: base.p99_ttft_us + rng.range(1, 2_000_000),
            p99_tpot_us: base.p99_tpot_us + rng.range(0, 200_000),
            ..base
        };
        let (next_base, _) = decide(&cfg, state, Trigger::Window, &base);
        let (next_worse, rule_worse) = decide(&cfg, state, Trigger::Window, &worse);
        assert!(
            next_worse.budget <= next_base.budget,
            "worse percentiles raised the budget: {base:?} -> {} vs {worse:?} -> {} (state {state:?})",
            next_base.budget,
            next_worse.budget
        );
        // and never open the budget above where it started
        if rule_worse == Rule::Open {
            assert!(
                next_base.budget >= state.budget,
                "worse obs opened while better obs did not hold/open"
            );
        }
        // spike trigger: deeper queues never raise the budget either
        let deeper = Observation {
            online_waiting: base.online_waiting + rng.range(1, 64),
            ..base
        };
        let (spike_base, _) = decide(&cfg, state, Trigger::Spike, &base);
        let (spike_deep, _) = decide(&cfg, state, Trigger::Spike, &deeper);
        assert!(spike_deep.budget <= spike_base.budget);
        assert!(spike_deep.budget <= state.budget, "a spike decision must never open");
    }
}
