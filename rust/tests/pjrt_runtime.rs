//! Integration tests of the real PJRT runtime: artifact loading, layered
//! execution numerics, KV residency (checkpoint/prefetch data paths),
//! preemption aborts, and a miniature end-to-end co-serving run.
//!
//! These require `make artifacts` and the `pjrt` cargo feature; they are
//! skipped (pass trivially) when artifacts/ is absent so `cargo test`
//! works pre-build.
#![cfg(feature = "pjrt")]

use conserve::backend::{ExecBackend, IterationPlan, PjrtBackend, SafepointAction};
use conserve::config::EngineConfig;
use conserve::profiler::LatencyProfile;
use conserve::request::{Class, Phase, Request};
use conserve::server::{ArrivalSource, ServingEngine};
use conserve::util::rng::Rng;
use conserve::workload::datasets::synth_prompt;

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn backend() -> Option<PjrtBackend> {
    artifacts_dir().map(|d| PjrtBackend::load(&d, 7, 1).expect("load artifacts"))
}

/// Build a plan from `(req, tokens, ctx)` item specs (tokens are staged
/// into the plan's shared buffer, as the scheduler does).
fn plan_of(specs: &[(u64, &[u16], usize)]) -> IterationPlan {
    let mut p = IterationPlan::default();
    for &(req, tokens, ctx) in specs {
        let phase = if tokens.len() > 1 {
            Phase::Prefill
        } else {
            Phase::Decode
        };
        p.push_item(req, Class::Offline, phase, ctx, tokens.len(), tokens);
    }
    p
}

fn run(b: &mut PjrtBackend, plan: &IterationPlan) -> conserve::backend::ExecOutcome {
    b.execute(plan, &mut |_| SafepointAction::Continue).unwrap()
}

#[test]
fn prefill_then_decode_produces_tokens() {
    let Some(mut b) = backend() else { return };
    let prompt: Vec<u16> = b"The serving system".iter().map(|&c| c as u16).collect();
    let n = prompt.len();
    let out = run(&mut b, &plan_of(&[(1, &prompt, 0)]));
    assert!(out.completed);
    let tok1 = out.new_tokens[0].expect("prefill completion samples a token");
    assert!(tok1 < 256);

    // decode continues from the committed cache
    let out2 = run(&mut b, &plan_of(&[(1, &[tok1], n)]));
    assert!(out2.completed);
    assert!(out2.new_tokens[0].is_some());
}

#[test]
fn chunked_prefill_equals_single_shot() {
    // The serving-path invariant: chunked prefill and one-shot prefill
    // must sample the same next token (greedy would be identical; the
    // sampler is seeded identically per backend instance).
    let Some(dir) = artifacts_dir() else { return };
    let prompt: Vec<u16> = (0..48u16).map(|i| 32 + (i * 7) % 90).collect();

    let mut b1 = PjrtBackend::load(&dir, 7, 1).unwrap();
    b1.set_temperature(0.0); // greedy: sampler draw counts differ by path
    let one = run(&mut b1, &plan_of(&[(1, &prompt, 0)]));

    let mut b2 = PjrtBackend::load(&dir, 7, 1).unwrap();
    b2.set_temperature(0.0);
    let _ = run(&mut b2, &plan_of(&[(1, &prompt[..16], 0)]));
    let _ = run(&mut b2, &plan_of(&[(1, &prompt[16..32], 16)]));
    let two = run(&mut b2, &plan_of(&[(1, &prompt[32..], 32)]));
    assert_eq!(
        one.new_tokens[0], two.new_tokens[0],
        "chunked and one-shot prefill must agree"
    );
}

#[test]
fn batched_execution_matches_solo() {
    let Some(dir) = artifacts_dir() else { return };
    let p1: Vec<u16> = (0..32u16).map(|i| 40 + (i * 3) % 80).collect();
    let p2: Vec<u16> = (0..32u16).map(|i| 35 + (i * 11) % 85).collect();

    let mut solo = PjrtBackend::load(&dir, 7, 1).unwrap();
    solo.set_temperature(0.0);
    let a = run(&mut solo, &plan_of(&[(1, &p1, 0)]));

    let mut both = PjrtBackend::load(&dir, 7, 1).unwrap();
    both.set_temperature(0.0);
    let ab = run(&mut both, &plan_of(&[(1, &p1, 0), (2, &p2, 0)]));
    // row 0 of the batched run sees the same tokens/cache as the solo run;
    // sampler state differs (two draws vs one) only for the second item,
    // and item order is deterministic, so item 0 must match exactly.
    assert_eq!(a.new_tokens[0], ab.new_tokens[0]);
}

#[test]
fn abort_discards_partial_work() {
    let Some(mut b) = backend() else { return };
    let prompt: Vec<u16> = (0..64u16).map(|i| 33 + i % 90).collect();
    let plan = {
        let mut p = plan_of(&[(1, &prompt, 0)]);
        p.preemptible = true;
        p
    };
    let out = b.execute(&plan, &mut |_| SafepointAction::Abort).unwrap();
    assert!(!out.completed);
    assert!(out.new_tokens[0].is_none());
    assert!(out.safepoint_checks >= 1);

    // after the abort, running the same prefill from scratch still works
    let out2 = run(&mut b, &plan);
    assert!(out2.completed);
}

#[test]
fn checkpoint_prefetch_roundtrip_preserves_decode() {
    let Some(dir) = artifacts_dir() else { return };
    let prompt: Vec<u16> = (0..32u16).map(|i| 50 + (i * 5) % 70).collect();

    // reference: prefill then decode directly
    let mut b1 = PjrtBackend::load(&dir, 7, 1).unwrap();
    b1.set_temperature(0.0);
    let o1 = run(&mut b1, &plan_of(&[(1, &prompt, 0)]));
    let t1 = o1.new_tokens[0].unwrap();
    let d1 = run(&mut b1, &plan_of(&[(1, &[t1], prompt.len())]));

    // same, but checkpoint every block D2H, drop the slab, prefetch back
    let mut b2 = PjrtBackend::load(&dir, 7, 1).unwrap();
    b2.set_temperature(0.0);
    let o2 = run(&mut b2, &plan_of(&[(1, &prompt, 0)]));
    let t2 = o2.new_tokens[0].unwrap();
    assert_eq!(t1, t2);
    let blocks = prompt.len().div_ceil(16);
    for i in 0..blocks {
        b2.copy_block_d2h(1, i, 16);
    }
    // wipe the "GPU" copy entirely, then restore from the host mirror
    b2.wipe_device_slab(1);
    for i in 0..blocks {
        b2.copy_block_h2d(1, i, 16);
    }
    let d2 = run(&mut b2, &plan_of(&[(1, &[t2], prompt.len())]));
    assert_eq!(
        d1.new_tokens[0], d2.new_tokens[0],
        "decode after checkpoint/restore must match direct decode"
    );
}

#[test]
fn mini_co_serving_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = EngineConfig::real_tiny();
    let mut backend = PjrtBackend::load(&dir, cfg.seed, 1).unwrap();
    let clock = backend.clock();
    let profile = LatencyProfile::profile(&mut backend, 64, 4, 64).unwrap();

    let mut rng = Rng::new(5);
    let mut events = Vec::new();
    for i in 0..3u64 {
        let prompt = synth_prompt(&mut rng, 40);
        events.push(Request::new(i + 1, Class::Online, prompt, 40, 6, i * 200_000));
    }
    for i in 0..4u64 {
        let prompt = synth_prompt(&mut rng, 80);
        events.push(Request::new(i + 10, Class::Offline, prompt, 80, 6, 0));
    }

    let mut engine = ServingEngine::new(
        cfg,
        backend,
        clock,
        profile,
        ArrivalSource::from_trace(events),
    );
    engine.run(60_000_000);
    assert_eq!(engine.rec.finished[0], 3, "all online finished");
    assert_eq!(engine.rec.finished[1], 4, "all offline finished");
    for r in engine.table.values() {
        assert_eq!(r.output.len(), 6, "req {} output", r.id);
    }
    assert!(engine.kv.check_conservation());
}
