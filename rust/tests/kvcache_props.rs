//! Property tests on KV-cache invariants: random interleavings of grow /
//! commit / checkpoint / evict / prefetch / discard / release must never
//! violate block conservation, double-own a block, or lose committed
//! tokens without an explicit discard. With the prefix cache enabled the
//! same invariants must hold over *refcounted* blocks: trie + sequence
//! references always sum to the pool refcount, shared blocks survive any
//! one owner's eviction, and migration never detaches a shared block.

use conserve::kvcache::manager::KvManager;
use conserve::request::TokenId;
use conserve::util::rng::Rng;

#[derive(Debug)]
enum Op {
    Grow(u64, usize),
    Commit(u64, usize),
    Ckpt(u64),
    FinishCkpt(u64),
    Evict(u64),
    Prefetch(u64),
    Discard(u64),
    Release(u64, bool),
}

fn random_op(rng: &mut Rng, ids: &[u64]) -> Op {
    let id = ids[rng.range_usize(0, ids.len())];
    match rng.range(0, 8) {
        0 => Op::Grow(id, rng.range_usize(1, 200)),
        1 => Op::Commit(id, rng.range_usize(1, 40)),
        2 => Op::Ckpt(id),
        3 => Op::FinishCkpt(id),
        4 => Op::Evict(id),
        5 => Op::Prefetch(id),
        6 => Op::Discard(id),
        _ => Op::Release(id, rng.range(0, 2) == 0),
    }
}

#[test]
fn conservation_under_random_interleavings() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed);
        let mut kv = KvManager::new(64, 128, 16);
        let ids: Vec<u64> = (1..=6).collect();
        let mut committed: std::collections::HashMap<u64, usize> =
            ids.iter().map(|&i| (i, 0)).collect();
        let mut inflight: std::collections::HashMap<u64, Vec<usize>> = Default::default();
        for id in &ids {
            kv.register(*id);
        }

        for step in 0..400 {
            let op = random_op(&mut rng, &ids);
            match op {
                Op::Grow(id, tokens) => {
                    let target = committed[&id] + tokens;
                    let _ = kv.grow(id, target);
                }
                Op::Commit(id, n) => {
                    let cap = kv.seq(id).map(|s| s.gpu.len() * 16).unwrap_or(0);
                    // only commit within grown, GPU-resident capacity
                    let cur = committed[&id];
                    let fully_resident = kv
                        .seq(id)
                        .map(|s| s.gpu_blocks() == s.gpu.len())
                        .unwrap_or(false);
                    if fully_resident && cur + n <= cap {
                        kv.commit(id, n).unwrap();
                        *committed.get_mut(&id).unwrap() += n;
                    }
                }
                Op::Ckpt(id) => {
                    if let Some(&idx) = kv.checkpoint_candidates(id).first() {
                        if kv.begin_ckpt(id, idx).is_ok() {
                            inflight.entry(id).or_default().push(idx);
                        }
                    }
                }
                Op::FinishCkpt(id) => {
                    if let Some(v) = inflight.get_mut(&id) {
                        if let Some(idx) = v.pop() {
                            kv.finish_ckpt(id, idx);
                        }
                    }
                }
                Op::Evict(id) => {
                    // only legal when nothing is in flight for the seq
                    if inflight.get(&id).is_none_or(|v| v.is_empty()) {
                        kv.evict_gpu(id);
                    }
                }
                Op::Prefetch(id) => {
                    for (idx, _hb) in kv.prefetch_candidates(id) {
                        if kv.begin_prefetch(id, idx).is_err() {
                            break;
                        }
                    }
                }
                Op::Discard(id) => {
                    if inflight.get(&id).is_none_or(|v| v.is_empty()) {
                        kv.discard(id);
                        *committed.get_mut(&id).unwrap() = 0;
                    }
                }
                Op::Release(id, keep) => {
                    if inflight.get(&id).is_none_or(|v| v.is_empty()) {
                        kv.release(id, keep);
                        if !keep {
                            *committed.get_mut(&id).unwrap() = 0;
                            kv.register(id);
                        }
                    }
                }
            }
            assert!(
                kv.check_conservation(),
                "conservation violated at seed {seed} step {step}"
            );
            // committed tokens never silently lost
            for (&id, &c) in &committed {
                let have = kv.seq(id).map(|s| s.tokens).unwrap_or(0);
                assert_eq!(have, c, "token count drift for {id} at seed {seed} step {step}");
            }
        }
    }
}

/// Per-id prompts with overlapping block-aligned prefixes: ids share
/// 2..=5 leading blocks of one base prompt, then diverge into a private
/// tail — so prefix attach genuinely hits across ids.
fn overlapping_prompts(ids: &[u64], block_tokens: usize) -> Vec<Vec<TokenId>> {
    let mut base_rng = Rng::new(0xBEEF);
    let base: Vec<TokenId> = (0..6 * block_tokens)
        .map(|_| base_rng.range(0, 256) as TokenId)
        .collect();
    ids.iter()
        .map(|&id| {
            let shared = (2 + (id as usize % 4)) * block_tokens;
            let mut p = base[..shared].to_vec();
            let mut tail = Rng::new(id);
            for _ in 0..block_tokens + 5 {
                p.push(tail.range(0, 256) as TokenId);
            }
            p
        })
        .collect()
}

/// The conservation property extended over refcounted shared blocks:
/// the grow/commit/ckpt/evict/prefetch/discard/release mix plus prefix
/// attach (admission sharing), publish (indexing), and export (steal
/// migration), under random interleavings. Checks after every step that
/// sequence-table + trie references sum exactly to pool refcounts and
/// committed tokens never drift — i.e. a shared block is never freed
/// under a surviving owner, never double-freed by the last one, and
/// never torn out by migration.
#[test]
fn conservation_with_prefix_sharing_under_hostile_interleavings() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed);
        let mut kv = KvManager::new(64, 128, 16);
        kv.enable_prefix_cache();
        let ids: Vec<u64> = (1..=6).collect();
        let prompts = overlapping_prompts(&ids, 16);
        let mut committed: std::collections::HashMap<u64, usize> =
            ids.iter().map(|&i| (i, 0)).collect();
        let mut inflight: std::collections::HashMap<u64, Vec<usize>> = Default::default();
        for id in &ids {
            kv.register(*id);
        }

        for step in 0..400 {
            let i = rng.range_usize(0, ids.len());
            let id = ids[i];
            let prompt = &prompts[i];
            match rng.range(0, 11) {
                0 => {
                    let target = committed[&id] + rng.range_usize(1, 200);
                    let _ = kv.grow(id, target);
                }
                1 => {
                    let n = rng.range_usize(1, 40);
                    let cap = kv.seq(id).map(|s| s.gpu.len() * 16).unwrap_or(0);
                    let cur = committed[&id];
                    let fully_resident = kv
                        .seq(id)
                        .map(|s| s.gpu_blocks() == s.gpu.len())
                        .unwrap_or(false);
                    if fully_resident && cur + n <= cap {
                        kv.commit(id, n).unwrap();
                        *committed.get_mut(&id).unwrap() += n;
                    }
                }
                2 => {
                    if let Some(&idx) = kv.checkpoint_candidates(id).first() {
                        if kv.begin_ckpt(id, idx).is_ok() {
                            inflight.entry(id).or_default().push(idx);
                        }
                    }
                }
                3 => {
                    if let Some(v) = inflight.get_mut(&id) {
                        if let Some(idx) = v.pop() {
                            kv.finish_ckpt(id, idx);
                        }
                    }
                }
                4 => {
                    // preempt: drops only this sequence's references;
                    // shared ancestors must survive under other owners
                    if inflight.get(&id).is_none_or(|v| v.is_empty()) {
                        kv.evict_gpu(id);
                    }
                }
                5 => {
                    for (idx, _hb) in kv.prefetch_candidates(id) {
                        if kv.begin_prefetch(id, idx).is_err() {
                            break;
                        }
                    }
                }
                6 => {
                    if inflight.get(&id).is_none_or(|v| v.is_empty()) {
                        kv.discard(id);
                        *committed.get_mut(&id).unwrap() = 0;
                    }
                }
                7 => {
                    if inflight.get(&id).is_none_or(|v| v.is_empty()) {
                        let keep = rng.range(0, 2) == 0;
                        kv.release(id, keep);
                        if !keep {
                            *committed.get_mut(&id).unwrap() = 0;
                            kv.register(id);
                        }
                    }
                }
                8 => {
                    // admission-time attach: only a fresh sequence may
                    // map onto shared blocks, and it jumps committed
                    let got = kv.prefix_attach(id, prompt);
                    if got > 0 {
                        assert_eq!(committed[&id], 0, "attach over live state");
                        *committed.get_mut(&id).unwrap() = got;
                    }
                }
                9 => kv.prefix_publish(id, prompt),
                _ => {
                    // steal migration round-trip: export must refuse
                    // while any GPU block (shared ones included) is
                    // resident; a legal export re-imports losslessly
                    if let Ok(tokens) = kv.export_host(id) {
                        if kv.import_host(id, tokens).is_err() {
                            *committed.get_mut(&id).unwrap() = 0;
                        }
                    }
                }
            }
            assert!(
                kv.check_conservation(),
                "conservation violated at seed {seed} step {step}"
            );
            for (&id, &c) in &committed {
                let have = kv.seq(id).map(|s| s.tokens).unwrap_or(0);
                assert_eq!(have, c, "token count drift for {id} at seed {seed} step {step}");
            }
        }

        // teardown: every owner releases; cache-only trie references
        // must be the sole survivors and still conserve
        for id in &ids {
            kv.release(*id, false);
        }
        assert!(kv.check_conservation(), "teardown violated at seed {seed}");
        assert_eq!(kv.shared_gpu_blocks(), 0, "no owners left => nothing shared");
    }
}

#[test]
fn pool_never_over_allocates() {
    let mut rng = Rng::new(99);
    let mut kv = KvManager::new(16, 16, 16);
    for id in 1..=4u64 {
        kv.register(id);
    }
    for _ in 0..200 {
        let id = rng.range(1, 5);
        let want = rng.range_usize(1, 300);
        let _ = kv.grow(id, want);
        let used: usize = (1..=4u64)
            .filter_map(|i| kv.seq(i))
            .map(|s| s.gpu_blocks())
            .sum();
        assert!(used <= 16);
        assert_eq!(kv.gpu_free(), 16 - used);
    }
}

#[test]
fn ckpt_tokens_monotone_until_invalidated() {
    let mut kv = KvManager::new(32, 64, 16);
    kv.register(1);
    kv.grow(1, 64).unwrap();
    kv.commit(1, 64).unwrap();
    let mut last = 0;
    for idx in kv.checkpoint_candidates(1) {
        kv.begin_ckpt(1, idx).unwrap();
        kv.finish_ckpt(1, idx);
        let now = kv.seq(1).unwrap().ckpt_tokens(16);
        assert!(now >= last);
        last = now;
    }
    assert_eq!(last, 64);
    assert!(kv.seq(1).unwrap().fully_checkpointed(16));
}
