//! Fault-injection properties: the headline crash-recovery guarantee.
//!
//! A run that loses a shard to a deterministic injected kill
//! ([`conserve::util::fault::FaultPlan`]) and recovers through the
//! durable [`JobStore`] must end with the **same completed set and
//! byte-identical token streams** as a crash-free run of the identical
//! workload — keyed sampling ties every stream to its submission id,
//! not to the shard (or the process) that happened to serve it.
//! Steal-channel faults (delayed polls, dropped deliveries) must lose
//! nothing even without a kill, torn checkpoint writes must be skipped
//! without poisoning the load, and online requests routed to a dead
//! shard must surface exactly in the fail-fast set.

use conserve::batch::{
    run_jobs, run_jobs_with_recovery, run_jobs_with_store, FinishedOutput, JobInput,
    JobManager, JobRequest, JobRunOpts, JobStore,
};
use conserve::config::EngineConfig;
use conserve::request::{Class, Request, TokenId};
use conserve::scheduler::harvest::{HarvestConfig, HarvestController};
use conserve::shard::ShardRouter;
use conserve::util::fault::{silence_injected_panics, FaultPlan, INJECTED_PANIC_MARKER};
use conserve::util::rng::Rng;
use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

const N_SHARDS: usize = 2;
const DURATION_S: f64 = 600.0;
const N_REQUESTS: usize = 12;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "conserve-faultprops-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// The job mix every test serves: two batches of short requests plus a
/// long-decode job, so a mid-run kill reliably strands work on the dead
/// shard while the survivors stay busy long enough to steal.
fn job_inputs() -> Vec<JobInput> {
    let mut rng = Rng::new(0xFA17);
    let mut jobs = Vec::new();
    for (n, in_lo, in_hi, out) in [(5, 128, 512, 12), (4, 256, 768, 16), (3, 2048, 3072, 384)] {
        jobs.push(JobInput {
            tenant: 1 + jobs.len() as u32,
            tier: (jobs.len() % 3) as u8,
            submitted_at: 0,
            deadline: 0,
            requests: (0..n)
                .map(|_| JobRequest {
                    prompt: Vec::new(),
                    prompt_len: rng.range_usize(in_lo, in_hi),
                    max_new_tokens: out,
                })
                .collect(),
        });
    }
    jobs
}

fn admit_all(jm: &mut JobManager) -> Vec<Request> {
    let mut events = Vec::new();
    for input in job_inputs() {
        jm.admit(&input, &mut events);
    }
    events
}

fn opts(ckpt_every: u64) -> JobRunOpts {
    JobRunOpts {
        collect_state: true,
        synth_tokens: true,
        ckpt_every,
        ..JobRunOpts::new(N_SHARDS, DURATION_S)
    }
}

fn outputs_by_sid(fins: &[FinishedOutput]) -> BTreeMap<u64, Vec<TokenId>> {
    fins.iter().map(|f| (f.sid, f.output.clone())).collect()
}

/// One crash-free run of the workload: the ground truth every faulted
/// variant must reproduce.
fn reference_outputs(cfg: &EngineConfig) -> BTreeMap<u64, Vec<TokenId>> {
    let mut jm = JobManager::new(5_000.0);
    let events = admit_all(&mut jm);
    let out = run_jobs(cfg, &opts(0), jm.board().clone(), events);
    assert!(out.deaths.is_empty(), "the reference run must be healthy");
    let want = outputs_by_sid(&out.finished);
    assert_eq!(want.len(), N_REQUESTS, "reference run finishes everything");
    assert!(want.values().all(|o| !o.is_empty()));
    want
}

/// Open a store in `dir` and persist the job specs (what the CLI does
/// at admission time) so recovery can rebuild never-checkpointed work.
fn store_with_specs(
    dir: &std::path::Path,
    jm: &JobManager,
    events: &[Request],
) -> Arc<Mutex<JobStore>> {
    let mut store = JobStore::open(dir).unwrap();
    for spec in jm.specs().to_vec() {
        store.record_spec(&spec, events).unwrap();
    }
    Arc::new(Mutex::new(store))
}

fn durable_outputs(dir: &std::path::Path) -> BTreeMap<u64, Vec<TokenId>> {
    JobStore::load(dir)
        .unwrap()
        .outputs
        .values()
        .map(|f| (f.sid, f.output.clone()))
        .collect()
}

#[test]
fn injected_kill_recovery_matches_crash_free_run() {
    silence_injected_panics();
    let cfg = EngineConfig::sim_a100_7b();
    let want = reference_outputs(&cfg);

    // three kill points: early (mid-prefill), mid (short jobs landing),
    // late (deep in the long job's decode tail)
    for kill_iter in [20u64, 35, 50] {
        let dir = tmp_dir(&format!("kill{kill_iter}"));
        let mut jm = JobManager::new(5_000.0);
        let events = admit_all(&mut jm);
        let store = store_with_specs(&dir, &jm, &events);
        let plan = FaultPlan::parse(&format!("kill=1@{kill_iter},delay-steals=2")).unwrap();
        let rec = run_jobs_with_recovery(
            &cfg,
            &opts(10),
            jm.board().clone(),
            events,
            store.clone(),
            Some(&plan),
        )
        .unwrap();

        assert_eq!(
            rec.first.deaths.len(),
            1,
            "kill@{kill_iter}: exactly one shard dies"
        );
        let d = &rec.first.deaths[0];
        assert_eq!(d.shard, 1, "the planned shard dies");
        assert!(
            d.payload.contains(INJECTED_PANIC_MARKER),
            "structured death carries the injected payload: {}",
            d.payload
        );
        assert!(
            rec.first.failed_online.is_empty(),
            "no online traffic, no fail-fast set"
        );
        assert!(rec.recovery.is_some(), "a death must trigger a recovery round");
        assert!(
            rec.resumed_requests > 0,
            "kill@{kill_iter}: the dead shard must strand work for recovery to replay"
        );

        drop(store);
        assert_eq!(
            durable_outputs(&dir),
            want,
            "kill@{kill_iter}: completed set + token streams must match the \
             crash-free run byte for byte"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn kill_mid_harvest_recovers_byte_identically_with_safe_restart_budget() {
    silence_injected_panics();
    let mut cfg = EngineConfig::sim_a100_7b();
    cfg.sched.harvest = true;
    let want = reference_outputs(&cfg);

    // the controller only reschedules work — sampling is keyed by
    // submission id, so harvest on/off runs are byte-identical too
    assert_eq!(
        want,
        reference_outputs(&EngineConfig::sim_a100_7b()),
        "the harvest controller must not perturb token streams"
    );

    let dir = tmp_dir("harvest");
    let mut jm = JobManager::new(5_000.0);
    let events = admit_all(&mut jm);
    let store = store_with_specs(&dir, &jm, &events);
    let plan = FaultPlan::parse("kill=1@35,delay-steals=2").unwrap();
    let rec = run_jobs_with_recovery(
        &cfg,
        &opts(10),
        jm.board().clone(),
        events,
        store.clone(),
        Some(&plan),
    )
    .unwrap();

    assert_eq!(rec.first.deaths.len(), 1, "the planned mid-harvest kill lands");
    assert!(rec.recovery.is_some(), "a death must trigger a recovery round");
    assert!(
        rec.resumed_requests > 0,
        "the dead shard must strand work for recovery to replay"
    );

    // The recovered fleet's controllers restart from the safe *tight*
    // initial budget, not the dead shard's last operating point:
    // recovery constructs fresh engines, and a fresh controller always
    // starts at the floor of its clamp — the invariant the recovery
    // path leans on, checked directly here.
    let hcfg = HarvestConfig::from_sched(&cfg.sched);
    let fresh = HarvestController::new(hcfg.clone());
    assert_eq!(fresh.budget(), hcfg.min_budget);
    assert_eq!(fresh.chunk(), hcfg.min_chunk);

    drop(store);
    assert_eq!(
        durable_outputs(&dir),
        want,
        "kill mid-harvest: completed set + token streams must match the \
         crash-free run byte for byte"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn steal_faults_without_a_kill_lose_nothing() {
    let cfg = EngineConfig::sim_a100_7b();
    let want = reference_outputs(&cfg);

    // delay every steal poll and drop (divert to the orphan pool) the
    // first few deliveries: the protocol must re-adopt, never lose
    let mut jm = JobManager::new(5_000.0);
    let events = admit_all(&mut jm);
    let plan = FaultPlan::parse("delay-steals=4,drop-steals=2").unwrap();
    let out = run_jobs_with_store(
        &cfg,
        &opts(0),
        jm.board().clone(),
        events,
        None,
        Some(&plan),
    );

    assert!(out.deaths.is_empty(), "steal faults alone kill nobody");
    assert!(out.failed_online.is_empty());
    assert!(
        out.unfinished.is_empty(),
        "dropped deliveries must be re-adopted from the orphan pool, not lost"
    );
    assert_eq!(
        outputs_by_sid(&out.finished),
        want,
        "a degraded steal channel must not change a single byte"
    );
}

#[test]
fn torn_checkpoint_writes_are_skipped_and_recovered() {
    silence_injected_panics();
    let cfg = EngineConfig::sim_a100_7b();
    let want = reference_outputs(&cfg);

    let dir = tmp_dir("torn");
    let mut jm = JobManager::new(5_000.0);
    let events = admit_all(&mut jm);
    let store = store_with_specs(&dir, &jm, &events);
    // tight flush cadence so the armed torn write lands well before the
    // kill, and later appends merge into the fragment
    let plan = FaultPlan::parse("kill=1@50,torn-ckpt=1").unwrap();
    let rec = run_jobs_with_recovery(
        &cfg,
        &opts(5),
        jm.board().clone(),
        events,
        store.clone(),
        Some(&plan),
    )
    .unwrap();

    assert_eq!(rec.first.deaths.len(), 1);
    assert!(
        rec.torn_checkpoint_lines >= 1,
        "the armed torn write must surface as a skipped checkpoint line \
         (got {})",
        rec.torn_checkpoint_lines
    );
    drop(store);
    assert_eq!(
        durable_outputs(&dir),
        want,
        "a torn checkpoint costs at most one flush interval, never correctness"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn online_requests_routed_to_the_dead_shard_fail_fast() {
    silence_injected_panics();
    let cfg = EngineConfig::sim_a100_7b();

    let dir = tmp_dir("online");
    let mut jm = JobManager::new(5_000.0);
    let mut events = admit_all(&mut jm);
    // specs are recorded against the job-only event list — online
    // background traffic is not durable-store material
    let store = store_with_specs(&dir, &jm, &events);
    let mut rng = Rng::new(11);
    for i in 0..16u64 {
        let input = rng.range_usize(64, 256);
        let output = rng.range_usize(4, 12);
        events.push(Request::new(
            1 + i,
            Class::Online,
            vec![],
            input,
            output,
            i * 200_000,
        ));
    }

    // placement is deterministic (admission-time estimates, lowest-index
    // ties), so an identical router predicts exactly which online sids
    // land on the doomed shard
    let o = opts(10);
    let mut router = ShardRouter::new(o.n_shards, o.placement, &cfg);
    for r in events.clone() {
        router.push(r);
    }
    let expected: BTreeSet<u64> = router.into_traces()[1]
        .iter()
        .filter(|r| r.class == Class::Online)
        .map(|r| r.submitted_id)
        .collect();
    assert!(
        !expected.is_empty(),
        "the workload must route some online work to shard 1"
    );

    let plan = FaultPlan::parse("kill=1@30").unwrap();
    let rec = run_jobs_with_recovery(
        &cfg,
        &o,
        jm.board().clone(),
        events,
        store.clone(),
        Some(&plan),
    )
    .unwrap();

    let failed: BTreeSet<u64> = rec.first.failed_online.iter().copied().collect();
    assert_eq!(
        failed, expected,
        "the fail-fast set is exactly the dead shard's online routing"
    );
    // offline work still fully recovers with online traffic in the mix
    drop(rec);
    drop(store);
    assert_eq!(
        durable_outputs(&dir).len(),
        N_REQUESTS,
        "every job request's output is durable after recovery"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
