//! Offline profiler + latency model (paper §4.5).
//!
//! The profiler runs before serving and measures iteration latency across
//! a grid of batch shapes — "the execution time of different input batch
//! sizes and input lengths for requests in different stages" — then fits
//! a linear model
//!
//! `t  =  c0 + c1 * prefill_tokens + c2 * decode_seqs + c3 * ctx_tokens`
//!
//! The SLO-aware scheduler inverts this model to turn TTFT/TPOT
//! objectives into per-iteration token budgets, and the preemption
//! handler (Alg. 2) uses it to estimate remaining/queued execution time.
//! Profiles serialize to JSON so a server start can reuse them
//! ("saved locally and automatically loaded", §4.5).

use crate::backend::{ExecBackend, PlanSummary};
use crate::util::json::{arr, num, obj, Json};
use anyhow::{anyhow, Result};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyProfile {
    /// [c0(µs), c1(µs/prefill tok), c2(µs/decode seq), c3(µs/ctx tok)]
    pub c: [f64; 4],
}

impl LatencyProfile {
    /// Estimated iteration latency in µs.
    pub fn estimate_us(&self, s: &PlanSummary) -> u64 {
        let t = self.c[0]
            + self.c[1] * s.prefill_tokens as f64
            + self.c[2] * s.decode_seqs as f64
            + self.c[3] * s.ctx_tokens as f64;
        t.max(0.0) as u64
    }

    /// Largest number of additional prefill tokens that keeps a batch
    /// with the given decode composition within `budget_us` (the §4.5
    /// budget inversion).
    pub fn max_prefill_tokens(
        &self,
        budget_us: u64,
        decode_seqs: usize,
        ctx_tokens: usize,
    ) -> usize {
        let fixed =
            self.c[0] + self.c[2] * decode_seqs as f64 + self.c[3] * ctx_tokens as f64;
        let slack = budget_us as f64 - fixed;
        if slack <= 0.0 || self.c[1] <= 0.0 {
            return 0;
        }
        (slack / self.c[1]) as usize
    }

    /// Least-squares fit over (shape, measured µs) samples via the 4x4
    /// normal equations.
    pub fn fit(samples: &[(PlanSummary, u64)]) -> Result<Self> {
        if samples.len() < 4 {
            return Err(anyhow!("need >= 4 profile samples, got {}", samples.len()));
        }
        let mut ata = [[0.0f64; 4]; 4];
        let mut atb = [0.0f64; 4];
        for (s, t) in samples {
            let x = [
                1.0,
                s.prefill_tokens as f64,
                s.decode_seqs as f64,
                s.ctx_tokens as f64,
            ];
            for i in 0..4 {
                for j in 0..4 {
                    ata[i][j] += x[i] * x[j];
                }
                atb[i] += x[i] * *t as f64;
            }
        }
        let c = solve4(ata, atb).ok_or_else(|| anyhow!("singular profile fit"))?;
        Ok(Self { c })
    }

    /// Build the measurement grid and fit. Grid scales are expressed in
    /// fractions of the provided maxima so the same code profiles both
    /// the tiny real model and the simulated 7B.
    pub fn profile(
        backend: &mut dyn ExecBackend,
        max_prefill: usize,
        max_decode: usize,
        max_ctx_per_seq: usize,
    ) -> Result<Self> {
        let mut samples = Vec::new();
        let prefills = [0.0, 0.125, 0.5, 1.0];
        let decodes = [0.0, 0.25, 1.0];
        let ctxs = [0.25, 1.0];
        for &pf in &prefills {
            for &df in &decodes {
                let p = (max_prefill as f64 * pf) as usize;
                let d = (max_decode as f64 * df) as usize;
                if p == 0 && d == 0 {
                    continue;
                }
                for &cf in &ctxs {
                    let ctx = d * (max_ctx_per_seq as f64 * cf) as usize;
                    let s = PlanSummary {
                        prefill_tokens: p,
                        decode_seqs: d,
                        ctx_tokens: ctx,
                        n_seqs: d + p.div_ceil(512).max(if p > 0 { 1 } else { 0 }),
                    };
                    let t = backend.probe_us(&s);
                    samples.push((s, t));
                }
            }
        }
        Self::fit(&samples)
    }

    // ------------------------------------------------------ persistence
    pub fn to_json(&self) -> String {
        obj(vec![("coeffs", arr(self.c.iter().map(|&x| num(x))))]).to_string()
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let cs = j
            .req("coeffs")
            .as_arr()
            .ok_or_else(|| anyhow!("coeffs not an array"))?;
        if cs.len() != 4 {
            return Err(anyhow!("expected 4 coeffs"));
        }
        let mut c = [0.0; 4];
        for (i, v) in cs.iter().enumerate() {
            c[i] = v.as_f64().ok_or_else(|| anyhow!("bad coeff"))?;
        }
        Ok(Self { c })
    }
}

/// Gaussian elimination with partial pivoting for the 4x4 system.
fn solve4(mut a: [[f64; 4]; 4], mut b: [f64; 4]) -> Option<[f64; 4]> {
    for col in 0..4 {
        let piv = (col..4).max_by(|&i, &j| {
            a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap()
        })?;
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for row in 0..4 {
            if row == col {
                continue;
            }
            let f = a[row][col] / a[col][col];
            for k in col..4 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    Some([
        b[0] / a[0][0],
        b[1] / a[1][1],
        b[2] / a[2][2],
        b[3] / a[3][3],
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{CostModel, SimBackend};
    use crate::clock::Clock;

    fn sim_profile() -> LatencyProfile {
        let mut b = SimBackend::new(CostModel::a100_llama2_7b(), Clock::virtual_at(0), 8);
        LatencyProfile::profile(&mut b, 4096, 128, 2048).unwrap()
    }

    #[test]
    fn fit_recovers_exact_linear_model() {
        let truth = LatencyProfile {
            c: [1000.0, 96.0, 40.0, 0.4],
        };
        let mut samples = Vec::new();
        for p in [0usize, 256, 1024] {
            for d in [0usize, 8, 64] {
                for ctx in [0usize, 4096, 65536] {
                    let s = PlanSummary {
                        prefill_tokens: p,
                        decode_seqs: d,
                        ctx_tokens: ctx,
                        n_seqs: d + 1,
                    };
                    samples.push((s, truth.estimate_us(&s)));
                }
            }
        }
        let fit = LatencyProfile::fit(&samples).unwrap();
        for i in 0..4 {
            assert!(
                (fit.c[i] - truth.c[i]).abs() / truth.c[i].max(1.0) < 0.02,
                "c[{i}]={} vs {}",
                fit.c[i],
                truth.c[i]
            );
        }
    }

    #[test]
    fn profiled_sim_estimates_track_cost_model() {
        let prof = sim_profile();
        let cm = CostModel::a100_llama2_7b();
        // mid-grid probe points: within 30% of ground truth
        for (p, d, cps) in [(1024usize, 16usize, 1024usize), (256, 64, 512), (2048, 0, 0)]
        {
            let s = PlanSummary {
                prefill_tokens: p,
                decode_seqs: d,
                ctx_tokens: d * cps,
                n_seqs: d + 1,
            };
            let truth = cm.iter_us(p, d, d * cps, d + 1);
            let est = prof.estimate_us(&s);
            let err = (est as f64 - truth as f64).abs() / truth as f64;
            assert!(err < 0.30, "p={p} d={d}: est={est} truth={truth}");
        }
    }

    #[test]
    fn budget_inversion_consistent() {
        let prof = sim_profile();
        let budget = 110_000; // TPOT SLO 110 ms
        let max_p = prof.max_prefill_tokens(budget, 32, 32 * 1024);
        assert!(max_p > 0);
        let s = PlanSummary {
            prefill_tokens: max_p,
            decode_seqs: 32,
            ctx_tokens: 32 * 1024,
            n_seqs: 33,
        };
        assert!(prof.estimate_us(&s) <= budget + 2_000);
        // tighter budget => smaller allowance
        assert!(prof.max_prefill_tokens(30_000, 32, 32 * 1024) < max_p);
    }

    #[test]
    fn json_roundtrip() {
        let p = LatencyProfile {
            c: [1.5, 2.5, -3.0, 0.125],
        };
        let q = LatencyProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(p, q);
    }
}
