//! `bench_admission` — front-door overload/drain acceptance bench.
//!
//! Runs the live HTTP front door ([`conserve::server::http`]) against a
//! deliberately small fleet (2 shards, shrunken KV) under a sped-up
//! cost model and measures the online TTFT-violation rate in four
//! scenarios:
//!
//! * **baseline** — light closed-loop traffic (4 workers), admission on:
//!   the unloaded violation rate;
//! * **overload_off** — a 3× burst (24 workers against 8 KV-resident
//!   slots) with `AdmissionConfig::admit_all()`: queueing delay lands on
//!   every request and the violation rate blows past the baseline;
//! * **overload_on** — the same burst with the queue-depth gate armed:
//!   excess load is shed with structured `429 Retry-After` responses
//!   (every shed carries a positive `retry_after_ms` — counted here)
//!   and the *accepted* requests keep a violation rate within 5 points
//!   of the unloaded baseline;
//! * **drain_resume** — an offline job is submitted, online burst
//!   traffic runs, and `/drain` lands mid-flight: zero accepted-request
//!   loss, unfinished offline work checkpointed, and after a restart the
//!   job's final outputs are byte-identical to an undrained reference
//!   run.
//!
//! Acceptance (asserted here):
//!
//! * `overload_off` violation rate ≥ baseline + 0.05 (the overload is
//!   real);
//! * `overload_on` violation rate ≤ baseline + 0.05 (admission defends
//!   the SLO);
//! * every shed response carries a positive retry hint;
//! * every drain ends with `lost_online == 0`; the mid-burst drain
//!   checkpoints offline progress and the restarted server resumes it to
//!   byte-identical outputs.
//!
//! Results go to `BENCH_admission.json` (schema: rust/PERF.md §8).
//! Scale with `ADMIT_BENCH_SECS` (seconds per load phase, default 3).

use conserve::backend::CostModel;
use conserve::batch::JobStore;
use conserve::config::EngineConfig;
use conserve::request::TokenId;
use conserve::server::admission::AdmissionConfig;
use conserve::server::http::{HttpServer, ServeOptions, ServeSummary};
use conserve::util::json::{num, obj, Json};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const N_SHARDS: usize = 2;
/// Shrunken per-shard KV so a couple dozen workers constitute genuine
/// overload: (256+512) tokens / 16 per block = 48 blocks per request,
/// 4 resident per shard, 8 fleet-wide.
const GPU_BLOCKS: usize = 192;
const PROMPT_LEN: usize = 256;
const MAX_TOKENS: usize = 512;
const SLO_TTFT_MS: f64 = 50.0;
const BASE_WORKERS: usize = 4;
const BURST_WORKERS: usize = 24;

/// Same shape as the A100 model, ~50x faster (see the loopback tests).
fn fast_cost() -> CostModel {
    CostModel {
        fixed_us: 50.0,
        us_per_token: 1.0,
        weights_load_us: 200.0,
        us_per_ctx_token: 0.01,
        us_per_seq: 1.0,
        ..CostModel::a100_llama2_7b()
    }
}

/// Admission for the measured phases: rate bucket neutralized (the
/// queue-depth gate is the lever under test), shallow online queue.
fn tuned_admission() -> AdmissionConfig {
    AdmissionConfig {
        online_rate: 100_000.0,
        online_burst: 100_000.0,
        max_waiting_online: 2,
        ..AdmissionConfig::default()
    }
}

fn start(
    admission: AdmissionConfig,
    state_dir: Option<PathBuf>,
) -> (SocketAddr, JoinHandle<ServeSummary>) {
    let mut cfg = EngineConfig::sim_a100_7b();
    cfg.mem.gpu_blocks = GPU_BLOCKS;
    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        shards: N_SHARDS,
        cost: fast_cost(),
        admission,
        state_dir,
        ckpt_every: 10,
        ..ServeOptions::default()
    };
    let server = HttpServer::bind(cfg, opts).expect("bind front door");
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().expect("serve run"));
    wait_healthy(addr);
    (addr, handle)
}

fn wait_healthy(addr: SocketAddr) {
    let t0 = Instant::now();
    loop {
        if let Some((200, _)) = try_http(addr, "GET", "/healthz", "") {
            return;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "server never came up");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn try_http(addr: SocketAddr, method: &str, path: &str, body: &str) -> Option<(u16, String)> {
    let mut s = TcpStream::connect(addr).ok()?;
    s.set_read_timeout(Some(Duration::from_secs(60))).ok()?;
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: b\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).ok()?;
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).ok()?;
    let text = String::from_utf8_lossy(&raw).to_string();
    let status: u16 = text.split(' ').nth(1)?.parse().ok()?;
    let body = text
        .find("\r\n\r\n")
        .map(|i| text[i + 4..].to_string())
        .unwrap_or_default();
    Some((status, body))
}

fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    try_http(addr, method, path, body).expect("http round trip")
}

fn drain_and_join(addr: SocketAddr, handle: JoinHandle<ServeSummary>) -> ServeSummary {
    let (status, _) = http(addr, "POST", "/drain", "");
    assert_eq!(status, 202);
    let summary = handle.join().expect("serve thread");
    assert_eq!(
        summary.lost_online, 0,
        "accepted-request loss after drain: {summary:?}"
    );
    summary
}

enum Outcome {
    Accepted { ttft_ms: f64 },
    Shed { has_hint: bool },
    Other,
    Gone,
}

/// One streaming completion; TTFT is wall-clock from request write to
/// the first `"token"` line on the wire.
fn stream_once(addr: SocketAddr) -> Outcome {
    let Ok(mut s) = TcpStream::connect(addr) else {
        return Outcome::Gone;
    };
    let _ = s.set_read_timeout(Some(Duration::from_secs(60)));
    let body =
        format!(r#"{{"prompt_len": {PROMPT_LEN}, "max_tokens": {MAX_TOKENS}, "stream": true}}"#);
    let req = format!(
        "POST /v1/completions HTTP/1.1\r\nHost: b\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let t0 = Instant::now();
    if s.write_all(req.as_bytes()).is_err() {
        return Outcome::Gone;
    }
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut ttft: Option<f64> = None;
    loop {
        match s.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if ttft.is_none() && buf.windows(7).any(|w| w == b"\"token\"") {
                    ttft = Some(t0.elapsed().as_secs_f64() * 1e3);
                }
            }
            Err(_) => break,
        }
    }
    let text = String::from_utf8_lossy(&buf).into_owned();
    let status: u16 = match text.split(' ').nth(1).and_then(|c| c.parse().ok()) {
        Some(c) => c,
        None => return Outcome::Gone,
    };
    match status {
        200 => match ttft {
            Some(ttft_ms) => Outcome::Accepted { ttft_ms },
            None => Outcome::Other, // stream ended without a token (drain race)
        },
        429 => {
            let hint = text
                .find("\r\n\r\n")
                .and_then(|i| Json::parse(text[i + 4..].trim()).ok())
                .and_then(|j| j.req("error").req("retry_after_ms").as_f64())
                .is_some_and(|ms| ms >= 1.0);
            Outcome::Shed { has_hint: hint }
        }
        _ => Outcome::Other,
    }
}

#[derive(Default)]
struct PhaseStats {
    accepted: u64,
    shed: u64,
    sheds_without_hint: u64,
    other: u64,
    violations: u64,
    ttfts: Vec<f64>,
}

impl PhaseStats {
    fn violation_rate(&self) -> f64 {
        if self.accepted == 0 {
            1.0
        } else {
            self.violations as f64 / self.accepted as f64
        }
    }

    fn p99_ttft_ms(&self) -> f64 {
        if self.ttfts.is_empty() {
            return 0.0;
        }
        let mut v = self.ttfts.clone();
        v.sort_by(f64::total_cmp);
        v[(v.len() - 1).min(v.len() * 99 / 100)]
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("accepted", num(self.accepted as f64)),
            ("shed", num(self.shed as f64)),
            ("sheds_without_hint", num(self.sheds_without_hint as f64)),
            ("other", num(self.other as f64)),
            ("ttft_violation_rate", num(self.violation_rate())),
            ("p99_ttft_ms", num(self.p99_ttft_ms())),
        ])
    }
}

/// Closed-loop load: `workers` threads each looping requests until the
/// deadline, finishing their in-flight request before exiting.
fn run_phase(addr: SocketAddr, workers: usize, secs: f64) -> PhaseStats {
    let deadline = Instant::now() + Duration::from_secs_f64(secs);
    let stats = Arc::new(Mutex::new(PhaseStats::default()));
    let handles: Vec<_> = (0..workers)
        .map(|_| {
            let stats = Arc::clone(&stats);
            std::thread::spawn(move || {
                while Instant::now() < deadline {
                    let o = stream_once(addr);
                    let mut st = stats.lock().unwrap();
                    match o {
                        Outcome::Accepted { ttft_ms } => {
                            st.accepted += 1;
                            if ttft_ms > SLO_TTFT_MS {
                                st.violations += 1;
                            }
                            st.ttfts.push(ttft_ms);
                        }
                        Outcome::Shed { has_hint } => {
                            st.shed += 1;
                            if !has_hint {
                                st.sheds_without_hint += 1;
                            }
                            drop(st);
                            // back off as the Retry-After contract asks
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Outcome::Other => st.other += 1,
                        Outcome::Gone => break,
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("load worker");
    }
    Arc::try_unwrap(stats)
        .unwrap_or_else(|_| panic!("stats still shared"))
        .into_inner()
        .unwrap()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "conserve-bench-admission-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

const JOB_BODY: &str = r#"{"n_requests": 4, "prompt_len": 64, "max_tokens": 3000}"#;

fn submit_job(addr: SocketAddr) -> u64 {
    let (status, body) = http(addr, "POST", "/v1/batches", JOB_BODY);
    assert_eq!(status, 202, "job submit: {body}");
    Json::parse(body.trim()).unwrap().req("id").as_f64().unwrap() as u64
}

fn poll_job_done(addr: SocketAddr, id: u64) {
    let t0 = Instant::now();
    loop {
        let (status, body) = http(addr, "GET", &format!("/v1/batches/{id}"), "");
        // a completed job may already be garbage-collected (404)
        if status == 404
            || (status == 200
                && Json::parse(body.trim()).unwrap().req("done").as_bool() == Some(true))
        {
            return;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(120),
            "job {id} never finished: {body}"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn job_outputs(dir: &Path) -> BTreeMap<u64, (u64, Vec<TokenId>)> {
    let rs = JobStore::load(dir).expect("load job store");
    rs.outputs
        .iter()
        .map(|(&sid, f)| (sid, (f.generated, f.output.clone())))
        .collect()
}

fn main() {
    let secs: f64 = std::env::var("ADMIT_BENCH_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3.0);
    println!(
        "=== bench_admission ({N_SHARDS} shards, {GPU_BLOCKS} KV blocks/shard, \
         {secs:.1}s/phase, SLO {SLO_TTFT_MS}ms TTFT) ==="
    );

    // ---- baseline: light load, admission on ----
    let (addr, handle) = start(tuned_admission(), None);
    let base = run_phase(addr, BASE_WORKERS, secs);
    drain_and_join(addr, handle);
    println!(
        "baseline:     {} accepted, {} shed, violation rate {:.3}, p99 TTFT {:.1}ms",
        base.accepted,
        base.shed,
        base.violation_rate(),
        base.p99_ttft_ms()
    );
    assert!(base.accepted > 0, "baseline produced no accepted requests");

    // ---- 3x burst, admission off: the overload is real ----
    let (addr, handle) = start(AdmissionConfig::admit_all(), None);
    let off = run_phase(addr, BURST_WORKERS, secs);
    drain_and_join(addr, handle);
    println!(
        "overload off: {} accepted, {} shed, violation rate {:.3}, p99 TTFT {:.1}ms",
        off.accepted,
        off.shed,
        off.violation_rate(),
        off.p99_ttft_ms()
    );

    // ---- same burst, admission on: the SLO holds, excess is shed ----
    let (addr, handle) = start(tuned_admission(), None);
    let on = run_phase(addr, BURST_WORKERS, secs);
    drain_and_join(addr, handle);
    println!(
        "overload on:  {} accepted, {} shed ({} without hint), violation rate {:.3}, p99 TTFT {:.1}ms",
        on.accepted,
        on.shed,
        on.sheds_without_hint,
        on.violation_rate(),
        on.p99_ttft_ms()
    );

    let gap_off = off.violation_rate() - base.violation_rate();
    let gap_on = on.violation_rate() - base.violation_rate();
    assert!(
        gap_off >= 0.05,
        "admission-off burst should violate the TTFT SLO: gap {gap_off:.3} \
         (off {:.3} vs base {:.3})",
        off.violation_rate(),
        base.violation_rate()
    );
    assert!(
        gap_on <= 0.05,
        "admission-on burst must stay within 5 points of the unloaded baseline: \
         gap {gap_on:.3} (on {:.3} vs base {:.3})",
        on.violation_rate(),
        base.violation_rate()
    );
    assert!(on.shed > 0, "the burst should shed under admission control");
    assert_eq!(
        on.sheds_without_hint, 0,
        "every shed must carry a positive retry_after_ms"
    );

    // ---- drain mid-burst: zero loss, byte-identical offline resume ----
    // reference: same job, no drain
    let ref_dir = tmp_dir("ref");
    let (addr, handle) = start(tuned_admission(), Some(ref_dir.clone()));
    let ref_id = submit_job(addr);
    poll_job_done(addr, ref_id);
    drain_and_join(addr, handle);
    let ref_outputs = job_outputs(&ref_dir);
    assert_eq!(ref_outputs.len(), 4, "reference run outputs");

    // drained: job first (identical submission ids), then burst, then a
    // mid-burst /drain, then restart + resume
    let drain_dir = tmp_dir("drain");
    let (addr, handle) = start(tuned_admission(), Some(drain_dir.clone()));
    let drain_id = submit_job(addr);
    assert_eq!(drain_id, ref_id, "submission order must match the reference run");
    let burst = {
        let deadline = Instant::now() + Duration::from_millis(400);
        let hs: Vec<_> = (0..BASE_WORKERS)
            .map(|_| {
                std::thread::spawn(move || {
                    while Instant::now() < deadline {
                        if matches!(stream_once(addr), Outcome::Gone) {
                            break;
                        }
                    }
                })
            })
            .collect();
        hs
    };
    std::thread::sleep(Duration::from_millis(150));
    let summary = drain_and_join(addr, handle); // mid-burst
    for h in burst {
        h.join().expect("burst worker");
    }
    assert!(
        summary.drain_checkpoints > 0,
        "mid-flight offline work should checkpoint on drain: {summary:?}"
    );
    let (addr, handle) = start(tuned_admission(), Some(drain_dir.clone()));
    poll_job_done(addr, drain_id);
    let resumed = drain_and_join(addr, handle);
    assert!(
        resumed.resumed_requests > 0,
        "restart should re-dispatch the unfinished job: {resumed:?}"
    );
    let drained_outputs = job_outputs(&drain_dir);
    let outputs_match = ref_outputs == drained_outputs;
    assert!(
        outputs_match,
        "resumed outputs diverge from the undrained reference: \
         ref {:?} vs drained {:?}",
        ref_outputs.iter().map(|(s, (g, _))| (*s, *g)).collect::<Vec<_>>(),
        drained_outputs.iter().map(|(s, (g, _))| (*s, *g)).collect::<Vec<_>>()
    );
    println!(
        "drain:        {} checkpoints at drain, {} requests resumed, outputs byte-identical",
        summary.drain_checkpoints, resumed.resumed_requests
    );
    std::fs::remove_dir_all(&ref_dir).ok();
    std::fs::remove_dir_all(&drain_dir).ok();

    // ---- emit BENCH_admission.json (schema: rust/PERF.md §8) ----
    let json = obj(vec![
        ("shards", num(N_SHARDS as f64)),
        ("gpu_blocks", num(GPU_BLOCKS as f64)),
        ("phase_secs", num(secs)),
        ("slo_ttft_ms", num(SLO_TTFT_MS)),
        ("burst_workers", num(BURST_WORKERS as f64)),
        ("baseline", base.to_json()),
        ("overload_off", off.to_json()),
        ("overload_on", on.to_json()),
        ("violation_gap_off_minus_base", num(gap_off)),
        ("violation_gap_on_minus_base", num(gap_on)),
        (
            "drain",
            obj(vec![
                ("lost_online", num(summary.lost_online as f64)),
                ("drain_checkpoints", num(summary.drain_checkpoints as f64)),
                ("resumed_requests", num(resumed.resumed_requests as f64)),
                ("outputs_match", num(f64::from(u8::from(outputs_match)))),
            ]),
        ),
    ]);
    let out_path =
        std::env::var("ADMIT_BENCH_OUT").unwrap_or_else(|_| "BENCH_admission.json".into());
    std::fs::write(&out_path, json.to_string()).expect("write BENCH_admission.json");
    println!("\nwrote {out_path}");
    let _ = Json::parse(&json.to_string()).expect("self-emitted json parses");
    println!("bench_admission OK");
}
