//! Byte-level tokenizer: one token per byte, vocab 256. Trivially
//! reversible, no external vocabulary files — the right altitude for a
//! serving-system reproduction where tokenization is not the subject.

use crate::request::TokenId;

pub fn tokenize(text: &str) -> Vec<TokenId> {
    text.as_bytes().iter().map(|&b| b as TokenId).collect()
}

/// Lossy reverse mapping (invalid UTF-8 sequences become U+FFFD).
pub fn detokenize(tokens: &[TokenId]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| t as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let text = "Hello, ConServe! 123";
        assert_eq!(detokenize(&tokenize(text)), text);
    }

    #[test]
    fn roundtrip_utf8() {
        let text = "héllo ∑ 世界";
        assert_eq!(detokenize(&tokenize(text)), text);
    }

    #[test]
    fn tokens_in_vocab() {
        assert!(tokenize("any text ⚙").iter().all(|&t| t < 256));
    }
}
