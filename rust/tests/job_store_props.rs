//! Durable job-store properties: randomized JSONL round-trips,
//! resume-after-partial-write tolerance, and the headline guarantee —
//! a run that is killed mid-flight and resumed from the store produces
//! **byte-identical** token streams to an uninterrupted run (keyed
//! sampling + same submission ids ⇒ same draws at every position).

use conserve::batch::{
    run_jobs, FinishedOutput, JobInput, JobManager, JobRequest, JobRunOpts, JobStore,
};
use conserve::config::EngineConfig;
use conserve::request::{PortableRequest, TokenId};
use conserve::util::json::Json;
use conserve::util::rng::Rng;
use std::collections::BTreeMap;
use std::path::PathBuf;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "conserve-jobprops-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// The job mix both runs serve: a couple of medium jobs plus a slow
/// one, so a tight time cap reliably leaves work unfinished.
fn job_inputs() -> Vec<JobInput> {
    let mut rng = Rng::new(0xD00D);
    let mut jobs = Vec::new();
    for (n, in_lo, in_hi, out) in [(5, 128, 512, 12), (4, 256, 768, 16), (3, 2048, 3072, 384)] {
        jobs.push(JobInput {
            tenant: 1 + jobs.len() as u32,
            tier: (jobs.len() % 3) as u8,
            submitted_at: 0,
            deadline: 0,
            requests: (0..n)
                .map(|_| JobRequest {
                    prompt: Vec::new(),
                    prompt_len: rng.range_usize(in_lo, in_hi),
                    max_new_tokens: out,
                })
                .collect(),
        });
    }
    jobs
}

fn admit_all(jm: &mut JobManager) -> Vec<conserve::request::Request> {
    let mut events = Vec::new();
    for input in job_inputs() {
        jm.admit(&input, &mut events);
    }
    events
}

fn opts(duration_s: f64) -> JobRunOpts {
    JobRunOpts {
        steal: None,
        collect_state: true,
        synth_tokens: true,
        ..JobRunOpts::new(1, duration_s)
    }
}

fn outputs_by_sid(fins: &[FinishedOutput]) -> BTreeMap<u64, Vec<TokenId>> {
    fins.iter().map(|f| (f.sid, f.output.clone())).collect()
}

#[test]
fn kill_and_resume_token_streams_are_byte_identical() {
    let cfg = EngineConfig::sim_a100_7b();

    // ---- reference: one uninterrupted run ----
    let mut jm = JobManager::new(5_000.0);
    let events = admit_all(&mut jm);
    let reference = run_jobs(&cfg, &opts(600.0), jm.board().clone(), events);
    let want = outputs_by_sid(&reference.finished);
    assert_eq!(want.len(), 12, "reference run finishes everything");
    assert!(want.values().all(|o| !o.is_empty()));

    // ---- crash run: same admission, killed at 2.5 s — late enough
    // that the small jobs finished, early enough that the slow job's
    // long decode tail has not ----
    let dir = tmp_dir("resume");
    let mut jm2 = JobManager::new(5_000.0);
    let events2 = admit_all(&mut jm2);
    {
        let mut store = JobStore::open(&dir).unwrap();
        // persist specs at admission (group requests per job)
        for spec in jm2.specs().to_vec() {
            store.record_spec(&spec, &events2).unwrap();
        }
        let partial = run_jobs(&cfg, &opts(2.5), jm2.board().clone(), events2);
        assert!(
            !partial.unfinished.is_empty(),
            "the tight cap must leave work unfinished (got {} finished)",
            partial.finished.len()
        );
        for f in &partial.finished {
            store.record_output(f).unwrap();
        }
        for p in &partial.unfinished {
            store.record_checkpoint(p).unwrap();
        }
    } // store dropped = process "death"

    // ---- restart: rebuild from disk, replay what's missing ----
    let state = JobStore::load(&dir).unwrap();
    let mut jm3 = JobManager::new(5_000.0);
    let mut replay = Vec::new();
    let n = jm3.resume(&state, &mut replay);
    assert_eq!(n, replay.len());
    assert!(n > 0 && n < 12, "resume replays exactly the unfinished work");
    // a checkpointed request resumes with its output prefix intact
    assert!(replay
        .iter()
        .any(|r| r.generated > 0 && !r.output.is_empty() && r.ctx_len == 0));
    let resumed = run_jobs(&cfg, &opts(600.0), jm3.board().clone(), replay);
    assert_eq!(resumed.finished.len(), n, "replayed work completes");

    // ---- union of pre-crash + post-resume == uninterrupted, bytewise ----
    let mut got: BTreeMap<u64, Vec<TokenId>> = state
        .outputs
        .values()
        .map(|f| (f.sid, f.output.clone()))
        .collect();
    for (sid, out) in outputs_by_sid(&resumed.finished) {
        let prev = got.insert(sid, out);
        assert!(prev.is_none(), "request {sid} served in both runs");
    }
    assert_eq!(got, want, "kill-and-resume must be byte-identical");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn portable_request_json_round_trip_property() {
    // randomized round-trips: every field survives, including full
    // 64-bit sampler states and ticket-bit submission ids
    let mut rng = Rng::new(0xF00D);
    for case in 0..200 {
        let sid = rng.next_u64() | if case % 2 == 0 { 1 << 63 } else { 0 };
        let prompt: Vec<TokenId> = (0..rng.range_usize(0, 20))
            .map(|_| rng.range_usize(0, 256) as TokenId)
            .collect();
        let prompt_len = prompt.len();
        let mut r = conserve::request::Request::new(
            sid,
            if case % 3 == 0 {
                conserve::request::Class::Online
            } else {
                conserve::request::Class::Offline
            },
            prompt,
            prompt_len,
            1 + rng.range_usize(0, 100),
            rng.range_usize(0, 1_000_000) as u64,
        );
        r.generated = rng.range_usize(0, 50);
        r.output = (0..r.generated)
            .map(|_| rng.range_usize(0, 256) as TokenId)
            .collect();
        r.preemptions = rng.range_usize(0, 5) as u32;
        r.recomputed_tokens = rng.range_usize(0, 1000);
        r.first_token_at = (case % 4 == 0).then(|| rng.range_usize(0, 1 << 40) as u64);
        r.last_token_at = r.first_token_at.map(|t| t + 17);
        r.job = rng.range_usize(0, 1000) as u64;
        r.tenant = rng.range_usize(0, 64) as u32;
        r.urgency = rng.range_usize(0, 1001) as u32;
        r.fair_weight = 1 + rng.range_usize(0, 4) as u32;
        r.deadline = rng.range_usize(0, 1 << 40) as u64;

        let p = PortableRequest::snapshot_cold(&r);
        let parsed = Json::parse(&p.to_json().to_string()).unwrap();
        let q = PortableRequest::from_json(&parsed).unwrap();
        assert_eq!(q.submitted_id, p.submitted_id);
        assert_eq!(q.sampler_state, p.sampler_state);
        assert_eq!(q.class, p.class);
        assert_eq!(q.prompt, p.prompt);
        assert_eq!(q.prompt_len, p.prompt_len);
        assert_eq!(q.max_new_tokens, p.max_new_tokens);
        assert_eq!(q.arrival, p.arrival);
        assert_eq!(q.output, p.output);
        assert_eq!(q.generated, p.generated);
        assert_eq!(q.preemptions, p.preemptions);
        assert_eq!(q.recomputed_tokens, p.recomputed_tokens);
        assert_eq!(q.first_token_at, p.first_token_at);
        assert_eq!(q.last_token_at, p.last_token_at);
        assert_eq!(
            (q.job, q.tenant, q.urgency, q.fair_weight, q.deadline),
            (p.job, p.tenant, p.urgency, p.fair_weight, p.deadline)
        );
    }
}

#[test]
fn resume_after_partial_spec_write() {
    // a torn final spec line loses only that job; everything durable
    // before it resumes normally
    let dir = tmp_dir("torn-spec");
    let mut jm = JobManager::new(5_000.0);
    let mut events = Vec::new();
    let spec = jm.admit(
        &JobInput {
            tenant: 1,
            tier: 1,
            submitted_at: 0,
            deadline: 0,
            requests: vec![JobRequest {
                prompt: vec![1, 2],
                prompt_len: 2,
                max_new_tokens: 3,
            }],
        },
        &mut events,
    );
    {
        let mut store = JobStore::open(&dir).unwrap();
        store.record_spec(&spec, &events).unwrap();
    }
    // simulate a torn append of a second spec line
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .open(dir.join("specs.jsonl"))
        .unwrap();
    f.write_all(b"{\"job\":2,\"tenant\":9,\"tier\":0,\"dead").unwrap();
    drop(f);

    let state = JobStore::load(&dir).unwrap();
    assert_eq!(state.jobs.len(), 1, "only the durable job survives");
    let mut jm2 = JobManager::new(5_000.0);
    let mut replay = Vec::new();
    assert_eq!(jm2.resume(&state, &mut replay), 1);
    assert_eq!(replay[0].submitted_id, events[0].submitted_id);
    assert_eq!(replay[0].prompt, vec![1, 2]);
    std::fs::remove_dir_all(&dir).unwrap();
}
