//! Time source abstraction: the same engine/scheduler code runs against
//! wall-clock time (real PJRT serving) and a discrete-event virtual clock
//! (the calibrated A100 simulation used by the benches).
//!
//! The clock is a shared handle: the execution backend *advances* virtual
//! time as it models compute, while the scheduler, checkpoint engine and
//! metrics only *read* it. In real mode `advance` is a no-op (wall time
//! advances on its own).

use crate::TimeUs;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone)]
pub enum Clock {
    Real(Arc<RealClock>),
    Virtual(Arc<AtomicU64>),
}

pub struct RealClock {
    origin: Instant,
}

impl Clock {
    pub fn real() -> Self {
        Clock::Real(Arc::new(RealClock {
            origin: Instant::now(),
        }))
    }

    pub fn virtual_at(start: TimeUs) -> Self {
        Clock::Virtual(Arc::new(AtomicU64::new(start)))
    }

    #[inline]
    pub fn now(&self) -> TimeUs {
        match self {
            Clock::Real(c) => c.origin.elapsed().as_micros() as TimeUs,
            Clock::Virtual(t) => t.load(Ordering::Relaxed),
        }
    }

    /// Advance virtual time by `dt` µs; no-op on the real clock.
    pub fn advance(&self, dt: TimeUs) {
        if let Clock::Virtual(t) = self {
            t.fetch_add(dt, Ordering::Relaxed);
        }
    }

    /// Jump virtual time forward to `to` (never backwards); no-op on the
    /// real clock.
    pub fn advance_to(&self, to: TimeUs) {
        if let Clock::Virtual(t) = self {
            t.fetch_max(to, Ordering::Relaxed);
        }
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_advances() {
        let c = Clock::virtual_at(100);
        assert_eq!(c.now(), 100);
        c.advance(50);
        assert_eq!(c.now(), 150);
        c.advance_to(120); // never backwards
        assert_eq!(c.now(), 150);
        c.advance_to(500);
        assert_eq!(c.now(), 500);
    }

    #[test]
    fn clones_share_state() {
        let c = Clock::virtual_at(0);
        let c2 = c.clone();
        c.advance(77);
        assert_eq!(c2.now(), 77);
    }

    #[test]
    fn real_clock_monotone() {
        let c = Clock::real();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        c.advance(1_000_000); // no-op
        assert!(c.now() < 1_000_000_000);
    }
}
