//! Robustness properties across the substrates: randomized fuzzing of
//! the JSON parser and config overrides (must never panic), swap-engine
//! bandwidth/ordering invariants, metrics consistency, and engine
//! failure-injection (mid-run abort storms must not corrupt state).

use conserve::backend::{CostModel, ExecBackend, SimBackend};
use conserve::clock::Clock;
use conserve::config::EngineConfig;
use conserve::kvcache::{Direction, SwapEngine};
use conserve::metrics::{percentile, Recorder};
use conserve::profiler::LatencyProfile;
use conserve::report::SimExperiment;
use conserve::request::Class;
use conserve::scheduler::Policy;
use conserve::util::json::Json;
use conserve::util::rng::Rng;
use conserve::workload::Lengths;

#[test]
fn json_parser_never_panics_on_garbage() {
    let mut rng = Rng::new(100);
    for _ in 0..3000 {
        let len = rng.range_usize(0, 64);
        let bytes: Vec<u8> = (0..len)
            .map(|_| b" {}[]\",:0123456789truefalsnl\\x"[rng.range_usize(0, 30)])
            .collect();
        let s = String::from_utf8_lossy(&bytes).into_owned();
        let _ = Json::parse(&s); // Ok or Err, never panic
    }
}

#[test]
fn json_roundtrip_fuzz() {
    // generate random values, emit, re-parse, compare
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 2 { rng.range(0, 4) } else { rng.range(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.range(0, 2) == 0),
            2 => Json::Num((rng.range(0, 2_000_000) as f64 - 1e6) / 8.0),
            3 => Json::Str(format!("s{}~\"\\\n", rng.range(0, 1000))),
            4 => Json::Arr((0..rng.range_usize(0, 4)).map(|_| gen(rng, depth + 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.range_usize(0, 4) {
                    m.insert(format!("k{i}"), gen(rng, depth + 1));
                }
                Json::Obj(m)
            }
        }
    }
    let mut rng = Rng::new(7);
    for _ in 0..500 {
        let v = gen(&mut rng, 0);
        let parsed = Json::parse(&v.to_string()).expect("emitted json must parse");
        assert_eq!(parsed, v);
    }
}

#[test]
fn config_set_never_panics() {
    let keys = [
        "policy", "chunk_size", "ttft_ms", "tpot_ms", "slo_aware", "gpu_blocks",
        "block_tokens", "seed", "bogus_key", "max_batch_tokens",
    ];
    let vals = ["", "0", "-1", "abc", "true", "1e9", "conserve", "999999999999999999999"];
    let mut cfg = EngineConfig::sim_a100_7b();
    for k in keys {
        for v in vals {
            let _ = cfg.set(k, v); // Ok or Err, never panic
        }
    }
}

#[test]
fn swap_engine_bandwidth_conservation() {
    // N enqueued blocks on one channel must complete no faster than
    // bytes / bandwidth allows, in FIFO order
    let mut e = SwapEngine::new(8 << 20, 32 << 30);
    let per = e.block_transfer_us();
    let mut last = 0;
    let n = 50;
    for i in 0..n {
        let t = e.enqueue(0, 1, i, Direction::D2H);
        assert!(t >= last + per, "op {i} finished too fast");
        last = t;
    }
    assert_eq!(last, per * n as u64);
    // draining in two ticks yields FIFO block order
    let done1 = e.tick(per * 10);
    assert_eq!(done1.len(), 10);
    assert!(done1.windows(2).all(|w| w[0].block_idx < w[1].block_idx));
    let done2 = e.tick(u64::MAX);
    assert_eq!(done2.len(), n - 10);
}

#[test]
fn swap_next_completion_tracks_front() {
    let mut e = SwapEngine::new(1 << 20, 1 << 30);
    assert_eq!(e.next_completion(), None);
    let t1 = e.enqueue(1000, 1, 0, Direction::D2H);
    let _t2 = e.enqueue(1000, 1, 1, Direction::H2D);
    assert_eq!(e.next_completion(), Some(t1.min(_t2)));
    e.tick(t1.max(_t2));
    assert_eq!(e.next_completion(), None);
}

#[test]
fn percentile_is_monotone_in_p() {
    let mut rng = Rng::new(11);
    let xs: Vec<f64> = (0..500).map(|_| rng.f64() * 100.0).collect();
    let mut last = f64::MIN;
    for p in [1.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
        let v = percentile(&xs, p);
        assert!(v >= last, "p{p}");
        last = v;
    }
}

#[test]
fn recorder_windows_partition_totals() {
    // sum of per-window processed tokens == overall count
    let mut r = Recorder::new();
    let mut rng = Rng::new(12);
    let mut total = 0usize;
    for _ in 0..2000 {
        let t = rng.range(0, 60_000_000);
        let n = rng.range_usize(1, 100);
        r.record_processed(t, Class::Offline, n);
        total += n;
    }
    let overall = r.processed_throughput(None, 0, 60_000_000) * 60.0;
    assert!((overall - total as f64).abs() < 1.0);
    let windows = r.timeseries(None, 15_000_000, 60_000_000);
    let sum: f64 = windows.iter().map(|w| w.processed_per_s * 15.0).sum();
    assert!((sum - total as f64).abs() < 1.0);
}

#[test]
fn abort_storms_do_not_corrupt_state() {
    // force very tight TTFT so Alg.-2 aborts fire constantly; the engine
    // must stay consistent and still finish the online work
    let mut cfg = EngineConfig::sim_a100_7b();
    cfg.sched.slo.ttft_ms = 400.0; // aggressive
    let online = conserve::workload::trace::onoff_trace(9, 120.0, 30.0, 2.0, 1.0);
    let r = SimExperiment {
        cfg,
        online_arrivals: online,
        online_lengths: Lengths::Fixed {
            input: 512,
            output: 32,
        },
        offline_pool: 600,
        offline_lengths: Lengths::OfflineDocs {
            min_input: 1024,
            max_input: 4096,
            max_output: 64,
        },
        duration_s: 120.0,
    }
    .run();
    assert!(r.layer_aborts > 0, "aborts must fire under a tight SLO");
    assert!(r.online_finished > 0);
    assert!(r.offline_finished > 0, "offline still progresses between aborts");
}

#[test]
fn zero_offline_pool_equals_online_only_shape() {
    // ConServe with nothing to harvest must behave like Online-Only
    let online = conserve::workload::LoadGen::new(3, 2.0, 1.0).arrivals_until(60.0);
    let mk = |policy: Policy| {
        let mut cfg = EngineConfig::sim_a100_7b();
        cfg.sched.policy = policy;
        SimExperiment {
            cfg,
            online_arrivals: online.clone(),
            online_lengths: Lengths::online_paper(),
            offline_pool: 0,
            offline_lengths: Lengths::offline_paper(),
            duration_s: 60.0,
        }
        .run()
    };
    let oo = mk(Policy::OnlineOnly);
    let cs = mk(Policy::ConServe);
    assert_eq!(oo.online_finished, cs.online_finished);
    assert_eq!(cs.offline_finished, 0);
    // same budget machinery => near-identical latency
    let gap = (cs.online_p99_ttft_ms - oo.online_p99_ttft_ms).abs()
        / oo.online_p99_ttft_ms.max(1.0);
    assert!(gap < 0.25, "gap {gap:.2}");
}

#[test]
fn profiler_fit_rejects_degenerate_samples() {
    assert!(LatencyProfile::fit(&[]).is_err());
    let s = conserve::backend::PlanSummary::default();
    // identical points => singular system
    let samples = vec![(s, 100u64); 10];
    assert!(LatencyProfile::fit(&samples).is_err());
}

#[test]
fn sim_backend_zero_work_is_free() {
    let clock = Clock::virtual_at(0);
    let mut b = SimBackend::new(CostModel::a100_llama2_7b(), clock.clone(), 8);
    let out = b
        .execute(
            &conserve::backend::IterationPlan::default(),
            &mut |_| conserve::backend::SafepointAction::Continue,
        )
        .unwrap();
    assert!(out.completed);
    assert_eq!(out.elapsed_us, 0);
    assert_eq!(clock.now(), 0);
}
