//! Closed-loop harvest controller: adaptive offline token budgets from
//! live latency feedback.
//!
//! The static `max_batch_tokens` budget fixes one operating point at
//! startup; under bursty traces any single point either starves offline
//! throughput in troughs or blows the online TTFT/TPOT tail under
//! spikes. This per-shard controller closes the loop the paper's
//! harvesting story implies: it observes windowed online TTFT/TPOT
//! percentiles (O(1) per sample via [`LogHistogram`]) and retunes the
//! offline token budget and prefill chunk each window with an
//! AIMD-style rule —
//!
//! * **Tighten** (multiplicative): the observed p99 crossed the
//!   headroom fraction of the SLO — halve the budget.
//! * **Open** (additive): the window was calm for
//!   [`HarvestConfig::calm_windows`] consecutive windows (hysteresis
//!   against single-window noise), or saw no online pressure at all —
//!   grow the budget by one step.
//! * **Hold**: calm but still inside the hysteresis streak.
//!
//! A **spike fast-path** runs every engine iteration, ahead of window
//! boundaries: when the online waiting queue reaches
//! [`HarvestConfig::spike_depth`], the budget tightens immediately —
//! one iteration of reaction, not one window — so a flash crowd never
//! waits out a calm window while a mega-batch forms.
//!
//! Budget and chunk are clamped to `[min_chunk, max_batch_tokens]` /
//! `[min_chunk, chunk_size]`; a fresh controller starts at the *tight*
//! end (safe-start — also what a crash-recovered shard resumes with).
//!
//! ## Audit trail
//!
//! Every decision — including Hold, so hysteresis state is
//! reconstructible — appends an [`AuditRecord`]: the trigger (window
//! boundary or spike), the observed percentiles, the old → new budget
//! and chunk, and the rule fired. The decision core
//! ([`decide`]) is a pure function of (config, state, trigger,
//! observation), so [`replay`] can re-run a recorded trail
//! decision-for-decision and reproduce it byte-identically
//! ([`AuditRecord::line`] is the canonical serialization) — the
//! audited-scheduler property tests in `tests/harvest_props.rs` hold
//! the controller to exactly that.

use crate::config::SchedConfig;
use crate::metrics::LogHistogram;
use crate::TimeUs;

/// Controller tuning, derived from [`SchedConfig`] at engine
/// construction ([`HarvestConfig::from_sched`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HarvestConfig {
    /// p99 TTFT target (µs) the controller holds online traffic under.
    pub slo_ttft_us: u64,
    /// p99 TPOT target (µs).
    pub slo_tpot_us: u64,
    /// Budget clamp: `[min_budget, max_budget]` tokens per iteration.
    pub min_budget: usize,
    pub max_budget: usize,
    /// Offline chunk clamp: `[min_chunk, max_chunk]` tokens.
    pub min_chunk: usize,
    pub max_chunk: usize,
    /// Observation window width (µs of engine time).
    pub window_us: TimeUs,
    /// Tighten when the observed p99 reaches this percentage of the
    /// SLO (headroom — react before the SLO is breached, not after).
    pub headroom_pct: u64,
    /// Multiplicative tighten divisor (budget /= this).
    pub tighten_div: usize,
    /// Additive open step (tokens).
    pub open_step: usize,
    /// Consecutive calm windows required before opening (hysteresis).
    pub calm_windows: u32,
    /// Online waiting-queue depth that trips the spike fast-path.
    pub spike_depth: usize,
}

impl HarvestConfig {
    /// Derive the controller tuning from a scheduler config: SLO
    /// targets from `slo` (TTFT overridable via `harvest_slo_us`),
    /// clamps from `[min_chunk, max_batch_tokens]` / `chunk_size`.
    pub fn from_sched(s: &SchedConfig) -> Self {
        let min = s.min_chunk.max(1);
        let max_budget = s.max_batch_tokens.max(min);
        HarvestConfig {
            slo_ttft_us: if s.harvest_slo_us > 0 {
                s.harvest_slo_us
            } else {
                (s.slo.ttft_ms * 1000.0) as u64
            },
            slo_tpot_us: (s.slo.tpot_ms * 1000.0) as u64,
            min_budget: min,
            max_budget,
            min_chunk: min,
            max_chunk: s.chunk_size.max(min),
            window_us: 1_000_000,
            headroom_pct: 80,
            tighten_div: 2,
            open_step: (max_budget / 16).max(min),
            calm_windows: 2,
            spike_depth: 4,
        }
    }
}

/// What fired a decision: the periodic window boundary, or the
/// per-iteration spike fast-path. Part of the recorded event (an
/// *input* to the rule), distinct from the [`Rule`] that resulted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trigger {
    Window,
    Spike,
}

impl Trigger {
    pub fn as_str(self) -> &'static str {
        match self {
            Trigger::Window => "window",
            Trigger::Spike => "spike",
        }
    }
}

/// The rule a decision fired (the *output* of [`decide`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// Multiplicative budget cut (p99 near SLO, or spike).
    Tighten,
    /// Additive budget growth (sustained calm / trough).
    Open,
    /// No change (calm, but inside the hysteresis streak).
    Hold,
}

impl Rule {
    pub fn as_str(self) -> &'static str {
        match self {
            Rule::Tighten => "tighten",
            Rule::Open => "open",
            Rule::Hold => "hold",
        }
    }
}

/// What the controller saw when it decided (window aggregates for a
/// [`Trigger::Window`], the running partial window for a spike).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Observation {
    pub p99_ttft_us: u64,
    pub p99_tpot_us: u64,
    /// Online TTFT samples inside the window (0 + empty queue = trough).
    pub ttft_samples: u64,
    /// Online waiting-queue depth at decision time.
    pub online_waiting: u64,
}

/// The replayable decision state: everything [`decide`] reads besides
/// the immutable config and the observation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CtlState {
    /// Current offline token budget (actuates `max_batch_tokens`).
    pub budget: usize,
    /// Consecutive calm windows seen (hysteresis counter).
    pub calm: u32,
}

/// One audited controller decision. `line()` is the canonical
/// serialization the replay test byte-compares.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AuditRecord {
    /// Engine iteration the decision fired on.
    pub iter: u64,
    /// Engine time (µs) of the decision.
    pub now: TimeUs,
    pub trigger: Trigger,
    pub obs: Observation,
    pub old_budget: usize,
    pub new_budget: usize,
    pub old_chunk: usize,
    pub new_chunk: usize,
    pub rule: Rule,
}

impl AuditRecord {
    /// Canonical one-line serialization (deterministic: fixed field
    /// order, integer-only values).
    pub fn line(&self) -> String {
        format!(
            "iter={} now={} trig={} p99_ttft_us={} p99_tpot_us={} samples={} waiting={} budget={}->{} chunk={}->{} rule={}",
            self.iter,
            self.now,
            self.trigger.as_str(),
            self.obs.p99_ttft_us,
            self.obs.p99_tpot_us,
            self.obs.ttft_samples,
            self.obs.online_waiting,
            self.old_budget,
            self.new_budget,
            self.old_chunk,
            self.new_chunk,
            self.rule.as_str(),
        )
    }
}

/// Chunk actuation is derived from the budget (one degree of freedom,
/// two clamped actuators): the offline prefill chunk follows the
/// budget down into `[min_chunk, max_chunk]`.
pub fn chunk_for(cfg: &HarvestConfig, budget: usize) -> usize {
    budget.clamp(cfg.min_chunk, cfg.max_chunk)
}

/// The pure decision core: next state + rule from (config, state,
/// trigger, observation). No clocks, no histograms, no I/O — replay
/// and the monotonicity property test call exactly this.
pub fn decide(
    cfg: &HarvestConfig,
    state: CtlState,
    trigger: Trigger,
    obs: &Observation,
) -> (CtlState, Rule) {
    let tighten = |b: usize| (b / cfg.tighten_div.max(2)).max(cfg.min_budget);
    let open = |b: usize| b.saturating_add(cfg.open_step).min(cfg.max_budget);
    match trigger {
        Trigger::Spike => {
            // emergency path: queue depth says a burst is forming NOW;
            // cut ahead of the window boundary. Only meaningful while
            // there is budget left to cut.
            if obs.online_waiting >= cfg.spike_depth as u64 && state.budget > cfg.min_budget {
                (
                    CtlState {
                        budget: tighten(state.budget),
                        calm: 0,
                    },
                    Rule::Tighten,
                )
            } else {
                (state, Rule::Hold)
            }
        }
        Trigger::Window => {
            let ttft_limit = cfg.slo_ttft_us.saturating_mul(cfg.headroom_pct) / 100;
            let tpot_limit = cfg.slo_tpot_us.saturating_mul(cfg.headroom_pct) / 100;
            let hot = (obs.ttft_samples > 0 && obs.p99_ttft_us >= ttft_limit)
                || obs.p99_tpot_us >= tpot_limit;
            if hot {
                (
                    CtlState {
                        budget: tighten(state.budget),
                        calm: 0,
                    },
                    Rule::Tighten,
                )
            } else if obs.ttft_samples == 0 && obs.online_waiting == 0 {
                // trough: no online traffic at all — open without
                // waiting out the hysteresis streak
                (
                    CtlState {
                        budget: open(state.budget),
                        calm: 0,
                    },
                    Rule::Open,
                )
            } else {
                let calm = state.calm + 1;
                if calm >= cfg.calm_windows {
                    (
                        CtlState {
                            budget: open(state.budget),
                            calm: 0,
                        },
                        Rule::Open,
                    )
                } else {
                    (
                        CtlState {
                            budget: state.budget,
                            calm,
                        },
                        Rule::Hold,
                    )
                }
            }
        }
    }
}

/// Re-run a recorded audit trail decision-for-decision from the
/// initial state: feed each record's (trigger, observation) into
/// [`decide`] and emit the records that produces. A faithful recording
/// replays byte-identically (`line()` for `line()`); any divergence
/// means the controller read state outside its audited inputs.
pub fn replay(cfg: &HarvestConfig, trail: &[AuditRecord]) -> Vec<AuditRecord> {
    let mut state = CtlState {
        budget: cfg.min_budget,
        calm: 0,
    };
    let mut out = Vec::with_capacity(trail.len());
    for r in trail {
        let old_budget = state.budget;
        let old_chunk = chunk_for(cfg, old_budget);
        let (next, rule) = decide(cfg, state, r.trigger, &r.obs);
        state = next;
        out.push(AuditRecord {
            iter: r.iter,
            now: r.now,
            trigger: r.trigger,
            obs: r.obs,
            old_budget,
            new_budget: state.budget,
            old_chunk,
            new_chunk: chunk_for(cfg, state.budget),
            rule,
        });
    }
    out
}

/// The per-shard controller: windowed online-latency histograms plus
/// the replayable decision state, with the audit trail of every
/// decision taken.
#[derive(Debug)]
pub struct HarvestController {
    cfg: HarvestConfig,
    state: CtlState,
    ttft: LogHistogram,
    tpot: LogHistogram,
    window_start: TimeUs,
    audit: Vec<AuditRecord>,
}

impl HarvestController {
    /// A fresh controller starts at the *tight* end of the clamp
    /// (safe-start): budget opens additively only as observed calm
    /// earns it. A crash-recovered shard constructing a fresh engine
    /// therefore resumes harvesting from the safe initial budget, not
    /// the dead shard's last operating point.
    pub fn new(cfg: HarvestConfig) -> Self {
        let state = CtlState {
            budget: cfg.min_budget,
            calm: 0,
        };
        Self {
            cfg,
            state,
            ttft: LogHistogram::new(),
            tpot: LogHistogram::new(),
            window_start: 0,
            audit: Vec::new(),
        }
    }

    pub fn config(&self) -> &HarvestConfig {
        &self.cfg
    }

    /// Current offline token budget (tokens per iteration).
    pub fn budget(&self) -> usize {
        self.state.budget
    }

    /// Current offline prefill chunk (derived from the budget).
    pub fn chunk(&self) -> usize {
        chunk_for(&self.cfg, self.state.budget)
    }

    /// Budget as a fraction of the static maximum, in permille —
    /// the effective-capacity signal published to the shard load board
    /// for placement and admission.
    pub fn budget_permille(&self) -> u64 {
        (self.state.budget as u64 * 1000 / self.cfg.max_budget.max(1) as u64).min(1000)
    }

    /// The audit trail so far (every decision, including Holds).
    pub fn audit_log(&self) -> &[AuditRecord] {
        &self.audit
    }

    /// Feed one online TTFT sample (µs) into the current window.
    pub fn observe_ttft(&mut self, ttft_us: u64) {
        self.ttft.record(ttft_us);
    }

    /// Feed one online inter-token gap (µs) into the current window.
    pub fn observe_tpot(&mut self, gap_us: u64) {
        self.tpot.record(gap_us);
    }

    /// One controller tick, called every engine iteration before
    /// scheduling. Returns the rule fired if a decision was taken
    /// (spike fast-path, or a window boundary elapsed); `None` on the
    /// overwhelmingly common no-decision iterations. The caller
    /// re-reads [`budget`](Self::budget) / [`chunk`](Self::chunk)
    /// after a `Some` and actuates the scheduler config.
    pub fn tick(&mut self, iter: u64, now: TimeUs, online_waiting: usize) -> Option<Rule> {
        // spike fast-path: fires between window boundaries, at most
        // once per budget level (each fire strictly shrinks the budget
        // until the floor disarms it)
        if online_waiting >= self.cfg.spike_depth && self.state.budget > self.cfg.min_budget {
            let obs = Observation {
                p99_ttft_us: self.ttft.quantile(99.0),
                p99_tpot_us: self.tpot.quantile(99.0),
                ttft_samples: self.ttft.count(),
                online_waiting: online_waiting as u64,
            };
            return Some(self.apply(iter, now, Trigger::Spike, obs));
        }
        if now < self.window_start.saturating_add(self.cfg.window_us) {
            return None;
        }
        let obs = Observation {
            p99_ttft_us: self.ttft.quantile(99.0),
            p99_tpot_us: self.tpot.quantile(99.0),
            ttft_samples: self.ttft.count(),
            online_waiting: online_waiting as u64,
        };
        let rule = self.apply(iter, now, Trigger::Window, obs);
        self.ttft.clear();
        self.tpot.clear();
        self.window_start = now;
        Some(rule)
    }

    fn apply(&mut self, iter: u64, now: TimeUs, trigger: Trigger, obs: Observation) -> Rule {
        let old_budget = self.state.budget;
        let old_chunk = chunk_for(&self.cfg, old_budget);
        let (next, rule) = decide(&self.cfg, self.state, trigger, &obs);
        self.state = next;
        self.audit.push(AuditRecord {
            iter,
            now,
            trigger,
            obs,
            old_budget,
            new_budget: self.state.budget,
            old_chunk,
            new_chunk: chunk_for(&self.cfg, self.state.budget),
            rule,
        });
        rule
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;

    fn cfg() -> HarvestConfig {
        let mut s = EngineConfig::sim_a100_7b();
        s.sched.harvest = true;
        HarvestConfig::from_sched(&s.sched)
    }

    #[test]
    fn from_sched_derives_targets_and_clamps() {
        let c = cfg();
        assert_eq!(c.slo_ttft_us, 1_500_000);
        assert_eq!(c.slo_tpot_us, 110_000);
        assert_eq!(c.min_budget, 64);
        assert_eq!(c.max_budget, 8192);
        assert_eq!(c.max_chunk, 512);
        // explicit override wins over the derived TTFT target
        let mut s = EngineConfig::sim_a100_7b();
        s.sched.harvest_slo_us = 250_000;
        assert_eq!(HarvestConfig::from_sched(&s.sched).slo_ttft_us, 250_000);
    }

    #[test]
    fn fresh_controller_starts_tight() {
        let h = HarvestController::new(cfg());
        assert_eq!(h.budget(), h.config().min_budget);
        assert_eq!(h.chunk(), h.config().min_chunk);
        assert!(h.audit_log().is_empty());
    }

    #[test]
    fn hot_window_tightens_calm_windows_open_with_hysteresis() {
        let c = cfg();
        let mut h = HarvestController::new(c.clone());
        // trough windows open the budget without traffic
        let mut t = c.window_us;
        let mut opens = 0;
        while h.budget() < c.max_budget {
            assert_eq!(h.tick(opens, t, 0), Some(Rule::Open));
            t += c.window_us;
            opens += 1;
        }
        assert_eq!(h.budget(), c.max_budget);
        // a hot window (p99 at the SLO) halves it
        h.observe_ttft(c.slo_ttft_us);
        assert_eq!(h.tick(opens, t, 1), Some(Rule::Tighten));
        assert_eq!(h.budget(), c.max_budget / 2);
        // calm-but-loaded windows hold for calm_windows - 1, then open
        t += c.window_us;
        h.observe_ttft(1_000);
        assert_eq!(h.tick(opens + 1, t, 1), Some(Rule::Hold));
        t += c.window_us;
        h.observe_ttft(1_000);
        assert_eq!(h.tick(opens + 2, t, 1), Some(Rule::Open));
        assert_eq!(h.budget(), c.max_budget / 2 + c.open_step);
    }

    #[test]
    fn spike_fast_path_fires_between_windows_until_floor() {
        let c = cfg();
        let mut h = HarvestController::new(c.clone());
        // open up first
        let mut t = c.window_us;
        for i in 0..40 {
            h.tick(i, t, 0);
            t += c.window_us;
        }
        assert_eq!(h.budget(), c.max_budget);
        // mid-window spike: tightens immediately, repeatedly, to floor
        let mid = t + 10; // far from the next boundary
        let mut iters = 100;
        while h.budget() > c.min_budget {
            assert_eq!(h.tick(iters, mid, c.spike_depth), Some(Rule::Tighten));
            iters += 1;
        }
        // at the floor the fast-path disarms (no decision, no record)
        let n = h.audit_log().len();
        assert_eq!(h.tick(iters, mid, c.spike_depth), None);
        assert_eq!(h.audit_log().len(), n);
    }

    #[test]
    fn no_decision_without_audit_record_and_vice_versa() {
        let c = cfg();
        let mut h = HarvestController::new(c.clone());
        let mut budget_changes = 0;
        let mut last = h.budget();
        let mut t = 0;
        for i in 0..10_000u64 {
            t += 7_321; // irregular iteration cadence
            let waiting = (i % 11) as usize; // crosses spike_depth often
            if i % 3 == 0 {
                h.observe_ttft(5_000 + (i * 977) % 2_000_000);
            }
            h.tick(i, t, waiting);
            if h.budget() != last {
                budget_changes += 1;
                last = h.budget();
            }
            assert!(h.budget() >= c.min_budget && h.budget() <= c.max_budget);
            assert!(h.chunk() >= c.min_chunk && h.chunk() <= c.max_chunk);
        }
        let logged_changes = h
            .audit_log()
            .iter()
            .filter(|r| r.new_budget != r.old_budget)
            .count();
        assert_eq!(budget_changes, logged_changes);
        assert!(budget_changes > 0, "the walk must exercise the loop");
    }

    #[test]
    fn replay_reproduces_the_trail_byte_identically() {
        let c = cfg();
        let mut h = HarvestController::new(c.clone());
        let mut t = 0;
        for i in 0..5_000u64 {
            t += 9_173;
            if i % 2 == 0 {
                h.observe_ttft((i * 6_151) % 3_000_000);
            }
            if i % 5 == 0 {
                h.observe_tpot((i * 431) % 200_000);
            }
            h.tick(i, t, (i % 9) as usize);
        }
        assert!(!h.audit_log().is_empty());
        let replayed = replay(&c, h.audit_log());
        assert_eq!(replayed.len(), h.audit_log().len());
        for (a, b) in h.audit_log().iter().zip(&replayed) {
            assert_eq!(a.line(), b.line());
        }
    }

    #[test]
    fn budget_permille_tracks_the_clamp_range() {
        let c = cfg();
        let mut h = HarvestController::new(c.clone());
        assert_eq!(h.budget_permille(), 1000 * c.min_budget as u64 / c.max_budget as u64);
        let mut t = c.window_us;
        for i in 0..40 {
            h.tick(i, t, 0);
            t += c.window_us;
        }
        assert_eq!(h.budget_permille(), 1000);
    }
}
