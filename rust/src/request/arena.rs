//! Slab arena for live requests.
//!
//! The request table is the hottest data structure in the engine: every
//! scheduling decision, KV accounting step and commit touches it, often
//! several times per request per iteration. A `HashMap<RequestId,
//! Request>` pays hashing + probing on each touch; this arena stores
//! requests in a dense `Vec` and makes [`RequestId`] the index, so every
//! lookup is one bounds-checked array access.
//!
//! Slots are recycled through a free list. Each slot carries a
//! *generation* counter that is bumped on removal and baked into the ids
//! it hands out (see [`rid_pack_sharded`]); a stale id whose generation
//! no longer matches the slot resolves to `None` instead of aliasing the
//! slot's next occupant. Slot 0 is reserved so that id 0 is never issued
//! and can be used as a sentinel.
//!
//! Every arena belongs to one worker *shard* ([`RequestArena::for_shard`];
//! the default is shard 0). Issued ids carry the shard index in bits
//! 24..32 and every lookup checks it, so an id from another shard's arena
//! misses here even if its slot and generation happen to coincide with a
//! live occupant — the cross-shard analogue of the generation guard.

use super::{
    rid_gen, rid_pack_sharded, rid_shard, rid_slot, Request, RequestId, MAX_SHARDS,
    SLOTS_PER_SHARD,
};

#[derive(Debug, Default)]
struct Slot {
    generation: u32,
    req: Option<Request>,
}

/// Vec-backed request slab with free-list recycling and generation- and
/// shard-guarded ids.
#[derive(Debug)]
pub struct RequestArena {
    shard: u32,
    slots: Vec<Slot>,
    free: Vec<u32>,
    live: usize,
}

impl Default for RequestArena {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestArena {
    /// Single-worker arena (shard 0).
    pub fn new() -> Self {
        Self::for_shard(0)
    }

    /// Arena for worker shard `shard`: every id it issues carries the
    /// shard index, and lookups reject ids from other shards.
    pub fn for_shard(shard: usize) -> Self {
        assert!(shard < MAX_SHARDS, "shard {shard} out of range");
        Self {
            shard: shard as u32,
            // slot 0 reserved: ids start at 1
            slots: vec![Slot::default()],
            free: Vec::new(),
            live: 0,
        }
    }

    pub fn with_capacity(n: usize) -> Self {
        let mut a = Self::new();
        a.slots.reserve(n);
        a
    }

    /// The worker shard this arena belongs to.
    pub fn shard(&self) -> usize {
        self.shard as usize
    }

    /// Insert a request, assigning (and writing into `req.id`) its arena
    /// id. Recycled slots hand out a fresh generation.
    pub fn insert(&mut self, mut req: Request) -> RequestId {
        let slot = match self.free.pop() {
            Some(s) => s as usize,
            None => {
                assert!(
                    self.slots.len() < SLOTS_PER_SHARD,
                    "shard {} arena exhausted its 24-bit slot space",
                    self.shard
                );
                self.slots.push(Slot::default());
                self.slots.len() - 1
            }
        };
        let id = rid_pack_sharded(self.shard as usize, slot, self.slots[slot].generation);
        req.id = id;
        self.slots[slot].req = Some(req);
        self.live += 1;
        id
    }

    #[inline]
    fn slot_of(&self, id: RequestId) -> Option<&Slot> {
        if rid_shard(id) != self.shard as usize {
            return None;
        }
        self.slots
            .get(rid_slot(id))
            .filter(|s| s.generation == rid_gen(id))
    }

    #[inline]
    pub fn get(&self, id: RequestId) -> Option<&Request> {
        self.slot_of(id).and_then(|s| s.req.as_ref())
    }

    #[inline]
    pub fn get_mut(&mut self, id: RequestId) -> Option<&mut Request> {
        if rid_shard(id) != self.shard as usize {
            return None;
        }
        self.slots
            .get_mut(rid_slot(id))
            .filter(|s| s.generation == rid_gen(id))
            .and_then(|s| s.req.as_mut())
    }

    #[inline]
    pub fn contains(&self, id: RequestId) -> bool {
        self.slot_of(id).is_some_and(|s| s.req.is_some())
    }

    /// Remove a request, recycling its slot under a bumped generation.
    /// Stale or foreign-shard ids are a no-op returning `None`.
    pub fn remove(&mut self, id: RequestId) -> Option<Request> {
        if rid_shard(id) != self.shard as usize {
            return None;
        }
        let slot = rid_slot(id);
        let s = self.slots.get_mut(slot)?;
        if s.generation != rid_gen(id) || s.req.is_none() {
            return None;
        }
        let req = s.req.take();
        s.generation = s.generation.wrapping_add(1);
        self.free.push(slot as u32);
        self.live -= 1;
        req
    }

    /// Number of live requests.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Total slots ever allocated (arena footprint; includes free slots
    /// and the reserved slot 0).
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Iterate live `(id, request)` pairs in slot order (deterministic).
    pub fn iter(&self) -> impl Iterator<Item = (RequestId, &Request)> {
        self.slots
            .iter()
            .filter_map(|s| s.req.as_ref().map(|r| (r.id, r)))
    }

    /// Iterate live ids in slot order.
    pub fn ids(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.iter().map(|(id, _)| id)
    }

    pub fn values(&self) -> impl Iterator<Item = &Request> {
        self.slots.iter().filter_map(|s| s.req.as_ref())
    }

    pub fn values_mut(&mut self) -> impl Iterator<Item = &mut Request> {
        self.slots.iter_mut().filter_map(|s| s.req.as_mut())
    }
}

impl std::ops::Index<RequestId> for RequestArena {
    type Output = Request;

    fn index(&self, id: RequestId) -> &Request {
        self.get(id).expect("stale or unknown request id")
    }
}

impl std::ops::Index<&RequestId> for RequestArena {
    type Output = Request;

    fn index(&self, id: &RequestId) -> &Request {
        self.get(*id).expect("stale or unknown request id")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::Class;

    fn req() -> Request {
        Request::new(0, Class::Online, vec![], 8, 2, 0)
    }

    #[test]
    fn ids_start_at_one_and_are_dense() {
        let mut a = RequestArena::new();
        let i1 = a.insert(req());
        let i2 = a.insert(req());
        assert_eq!(i1, 1);
        assert_eq!(i2, 2);
        assert_eq!(a.len(), 2);
        assert_eq!(a[i1].id, i1);
    }

    #[test]
    fn removal_recycles_with_fresh_generation() {
        let mut a = RequestArena::new();
        let i1 = a.insert(req());
        let i2 = a.insert(req());
        assert!(a.remove(i1).is_some());
        assert_eq!(a.len(), 1);
        // stale id no longer resolves
        assert!(a.get(i1).is_none());
        assert!(!a.contains(i1));
        assert!(a.remove(i1).is_none());
        // slot reused under a new generation: same slot, different id
        let i3 = a.insert(req());
        assert_eq!(rid_slot(i3), rid_slot(i1));
        assert_ne!(i3, i1);
        assert_eq!(rid_gen(i3), rid_gen(i1) + 1);
        // the stale id still misses after reuse
        assert!(a.get(i1).is_none());
        assert!(a.get(i3).is_some());
        assert!(a.get(i2).is_some());
    }

    #[test]
    fn iteration_is_slot_ordered_and_live_only() {
        let mut a = RequestArena::new();
        let i1 = a.insert(req());
        let i2 = a.insert(req());
        let i3 = a.insert(req());
        a.remove(i2);
        let ids: Vec<_> = a.ids().collect();
        assert_eq!(ids, vec![i1, i3]);
        assert_eq!(a.values().count(), 2);
        assert_eq!(a.slot_count(), 4); // reserved slot 0 + 3
    }

    #[test]
    fn cross_shard_ids_never_resolve() {
        let mut a = RequestArena::for_shard(1);
        let mut b = RequestArena::for_shard(2);
        let ia = a.insert(req());
        let ib = b.insert(req());
        // identical (slot, generation) halves, different shard bits
        assert_eq!(rid_slot(ia), rid_slot(ib));
        assert_eq!(rid_gen(ia), rid_gen(ib));
        assert_ne!(ia, ib);
        assert_eq!(rid_shard(ia), 1);
        assert_eq!(a.shard(), 1);
        // foreign-shard ids miss every accessor
        assert!(a.get(ib).is_none());
        assert!(b.get(ia).is_none());
        assert!(a.get_mut(ib).is_none());
        assert!(!a.contains(ib));
        assert!(a.remove(ib).is_none());
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn get_mut_respects_generation() {
        let mut a = RequestArena::new();
        let i1 = a.insert(req());
        a.get_mut(i1).unwrap().generated = 1;
        assert_eq!(a[i1].generated, 1);
        a.remove(i1);
        let i2 = a.insert(req());
        assert!(a.get_mut(i1).is_none());
        assert_eq!(a[i2].generated, 0, "recycled slot must not leak state");
    }
}
