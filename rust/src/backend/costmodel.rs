//! Calibrated analytical cost model of the paper's testbed: one NVIDIA
//! A100-40G serving Llama-2-7B in fp16 (paper §6.1).
//!
//! Constants derive from public hardware/model figures (DESIGN.md
//! §Calibration); absolute values matter less than the *ratios* the
//! paper's results hinge on — decode (HBM-bound) vs prefill
//! (compute-bound) time, PCIe transfer vs compute, KV growth vs reclaim:
//!
//! * fp16 dense peak 312 TFLOP/s at ~45% sustained efficiency; 6.74e9
//!   params => ~96 µs of GEMM time per token (prefill or decode).
//! * HBM 1555 GB/s: a decode step must stream the 13.5 GB weights
//!   (~8.7 ms floor) plus each sequence's KV context (0.5 MB/token).
//! * PCIe 4.0 x16 => 32 GB/s per direction; a 16-token KV block is 8 MB
//!   (~250 µs per block transfer).
//! * Per-iteration fixed cost (launch/schedule) ~1.2 ms; per-sequence
//!   sampling/bookkeeping ~25 µs.
//! * Safepoint barrier: 988 µs (paper §6.4.2 measured), amortized every
//!   `safepoint_layers` of the model's 32 layers.

#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Fixed per-iteration overhead (µs).
    pub fixed_us: f64,
    /// GEMM time per new token (µs), prefill or decode.
    pub us_per_token: f64,
    /// Weight-streaming floor per iteration (µs).
    pub weights_load_us: f64,
    /// KV re-read cost per context token per iteration (µs).
    pub us_per_ctx_token: f64,
    /// Per-sequence overhead (µs).
    pub us_per_seq: f64,
    /// Device<->host link bandwidth (bytes/s per direction).
    pub pcie_bytes_per_sec: u64,
    /// KV bytes per token (2 * n_layers * kv_dim * 2 bytes).
    pub kv_bytes_per_token: u64,
    /// Tokens per KV block.
    pub block_tokens: usize,
    /// Model depth (safepoint placement).
    pub n_layers: usize,
    /// Distributed-barrier cost per safepoint (µs).
    pub safepoint_us: u64,
}

impl CostModel {
    pub fn a100_llama2_7b() -> Self {
        CostModel {
            fixed_us: 1200.0,
            us_per_token: 96.0,
            weights_load_us: 8700.0,
            us_per_ctx_token: 0.385, // 0.5 MB / 1300 GB/s effective
            us_per_seq: 25.0,
            pcie_bytes_per_sec: 32 << 30,
            kv_bytes_per_token: 512 << 10, // 0.5 MB
            block_tokens: 16,
            n_layers: 32,
            safepoint_us: 988,
        }
    }

    /// Iteration latency (µs) for a plan shape. Compute and weight
    /// streaming overlap (max); KV reads and per-seq overheads add.
    pub fn iter_us(
        &self,
        prefill_tokens: usize,
        decode_seqs: usize,
        ctx_tokens: usize,
        n_seqs: usize,
    ) -> u64 {
        if prefill_tokens == 0 && decode_seqs == 0 {
            return 0;
        }
        let new_tokens = (prefill_tokens + decode_seqs) as f64;
        let compute = new_tokens * self.us_per_token;
        let t = self.fixed_us
            + compute.max(self.weights_load_us)
            + ctx_tokens as f64 * self.us_per_ctx_token
            + n_seqs as f64 * self.us_per_seq;
        t as u64
    }

    pub fn block_bytes(&self) -> u64 {
        self.kv_bytes_per_token * self.block_tokens as u64
    }

    /// µs to move one KV block across PCIe.
    pub fn block_transfer_us(&self) -> u64 {
        self.block_bytes() * 1_000_000 / self.pcie_bytes_per_sec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel::a100_llama2_7b()
    }

    #[test]
    fn prefill_is_compute_bound() {
        // 1024-token prefill ~= 100 ms (compute dwarfs the weight floor)
        let t = cm().iter_us(1024, 0, 0, 1);
        assert!((95_000..115_000).contains(&t), "t={t}");
    }

    #[test]
    fn small_decode_is_weight_bound() {
        // single-seq decode: ~10 ms dominated by weight streaming
        let t = cm().iter_us(0, 1, 1024, 1);
        assert!((9_000..12_000).contains(&t), "t={t}");
        // batching decodes amortizes the weight load: 32 seqs is far less
        // than 32x slower
        let t32 = cm().iter_us(0, 32, 32 * 1024, 32);
        assert!(t32 < 4 * t, "t32={t32} t={t}");
    }

    #[test]
    fn kv_context_costs_scale_linearly() {
        let short = cm().iter_us(0, 16, 16 * 256, 16);
        let long = cm().iter_us(0, 16, 16 * 4096, 16);
        assert!(long > short + 20_000, "short={short} long={long}");
    }

    #[test]
    fn decode_generation_rate_plausible() {
        // 64-way decode at ctx 1024: step ~35 ms => ~1.9k generated tok/s,
        // the regime behind the paper's Online-Only 1999 tok/s
        let t = cm().iter_us(0, 64, 64 * 1024, 64);
        let tput = 64.0 / (t as f64 / 1e6);
        assert!((1_200.0..3_200.0).contains(&tput), "tput={tput}");
    }

    #[test]
    fn pcie_block_transfer_calibration() {
        // 8 MB / 32 GB/s ~= 244 µs
        let t = cm().block_transfer_us();
        assert!((230..260).contains(&t), "t={t}");
    }

    #[test]
    fn empty_plan_is_free() {
        assert_eq!(cm().iter_us(0, 0, 0, 0), 0);
    }
}
