//! Runtime support for the real serving path: AOT artifact loading
//! (manifest, weights, HLO executables), the byte-level tokenizer, and
//! token sampling.
//!
//! Artifact loading talks to the PJRT C API through the `xla` crate and
//! is gated behind the `pjrt` cargo feature (the CI image does not
//! vendor the crate); the tokenizer and sampler are dependency-free and
//! always available.

#[cfg(feature = "pjrt")]
pub mod artifacts;
pub mod sampler;
pub mod tokenizer;

#[cfg(feature = "pjrt")]
pub use artifacts::{Artifacts, ModelDims};
pub use sampler::Sampler;
pub use tokenizer::{detokenize, tokenize};
