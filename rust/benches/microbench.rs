//! L3 hot-path microbenchmarks (the §Perf criterion-style suite):
//! scheduler step latency, request-table lookup (slab arena vs the
//! HashMap it replaced), KV block alloc/free, swap-engine ops, streaming
//! histogram record/quantile vs sort-based percentile, gamma sampling,
//! and JSON parsing. Each reports ns/op over a fixed iteration budget;
//! EXPERIMENTS.md §Perf records before/after for the optimization pass.

use conserve::config::EngineConfig;
use conserve::kvcache::{Direction, KvManager, SwapEngine};
use conserve::metrics::{percentile, LogHistogram};
use conserve::profiler::LatencyProfile;
use conserve::request::{Class, Request, RequestArena, RequestId};
use conserve::scheduler::{Ctx, ScheduleOutcome, UnifiedScheduler};
use conserve::util::json::Json;
use conserve::util::rng::Rng;
use std::collections::HashMap;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("{name:<44} {ns:>12.0} ns/op   ({iters} iters)");
    ns
}

fn main() {
    println!("=== L3 hot-path microbenchmarks ===");

    // ---- KV block alloc/free ----
    let mut kv = KvManager::new(4096, 8192, 16);
    kv.register(1);
    bench("kv: grow+commit+release 32-block seq", 20_000, || {
        kv.grow(1, 512).unwrap();
        kv.commit(1, 512).unwrap();
        kv.release(1, false);
        kv.register(1);
    });

    // ---- request table: slab arena vs HashMap ----
    let mut arena = RequestArena::new();
    let mut map: HashMap<RequestId, Request> = HashMap::new();
    let mut ids = Vec::new();
    for i in 0..1024u64 {
        let id = arena.insert(Request::new(0, Class::Offline, vec![], 1024, 128, i));
        map.insert(id, Request::new(id, Class::Offline, vec![], 1024, 128, i));
        ids.push(id);
    }
    let mut k = 0usize;
    bench("table: arena lookup", 1_000_000, || {
        k = (k + 7) & 1023;
        std::hint::black_box(arena.get(ids[k]).unwrap().ctx_len);
    });
    k = 0;
    bench("table: hashmap lookup (pre-arena baseline)", 1_000_000, || {
        k = (k + 7) & 1023;
        std::hint::black_box(map.get(&ids[k]).unwrap().ctx_len);
    });

    // ---- swap engine enqueue/tick ----
    let mut swap = SwapEngine::new(8 << 20, 32 << 30);
    let mut io = Vec::new();
    let mut t = 0u64;
    bench("swap: enqueue + drain one op", 100_000, || {
        swap.enqueue(t, 1, 0, Direction::D2H);
        t += 300;
        swap.tick_into(t, &mut io);
    });

    // ---- scheduler step on a loaded table ----
    let cfg = EngineConfig::sim_a100_7b();
    let profile = LatencyProfile {
        c: [1200.0, 96.0, 40.0, 0.385],
    };
    let mut sched = UnifiedScheduler::new(cfg.sched.clone());
    let mut table = RequestArena::new();
    let mut kv2 = KvManager::new(cfg.mem.gpu_blocks, cfg.mem.host_blocks, 16);
    for i in 0..128u64 {
        let class = if i % 4 == 0 {
            Class::Online
        } else {
            Class::Offline
        };
        let id = table.insert(Request::new(0, class, vec![], 1024, 128, 0));
        sched.enqueue(id, class);
    }
    let mut now = 0u64;
    let mut out = ScheduleOutcome::default();
    bench("scheduler: full Algorithm-1 step (128 reqs)", 2_000, || {
        now += 50_000;
        let mut ctx = Ctx {
            table: &mut table,
            kv: &mut kv2,
            profile: &profile,
            now,
            max_model_len: 4096,
        };
        sched.schedule(&mut ctx, &mut out);
        // commit so the state advances realistically
        for item in &out.plan.items {
            kv2.commit(item.req, item.n_tokens).unwrap();
            let r = table.get_mut(item.req).unwrap();
            r.ctx_len += item.n_tokens;
            if r.ctx_len == r.feed_target() {
                r.generated += 1;
                if r.is_done() {
                    r.state = conserve::request::State::Finished;
                    kv2.release(item.req, false);
                }
            }
        }
    });

    // ---- metrics: streaming histogram vs sort-based percentile ----
    let mut rng = Rng::new(7);
    let samples: Vec<f64> = (0..65_536).map(|_| rng.f64() * 2_000_000.0).collect();
    let mut h = LogHistogram::new();
    let mut si = 0usize;
    bench("metrics: histogram record", 1_000_000, || {
        si = (si + 1) & 65_535;
        h.record(samples[si] as u64);
    });
    bench("metrics: histogram p99 query", 100_000, || {
        std::hint::black_box(h.quantile(99.0));
    });
    bench("metrics: percentile (select_nth, 64k)", 200, || {
        std::hint::black_box(percentile(&samples, 99.0));
    });

    // ---- workload sampling ----
    let mut rng = Rng::new(1);
    bench("rng: gamma inter-arrival sample", 1_000_000, || {
        std::hint::black_box(rng.gamma_interarrival(2.0, 2.0));
    });

    // ---- profiler estimate (inner loop of budget calc) ----
    let s = conserve::backend::PlanSummary {
        prefill_tokens: 1024,
        decode_seqs: 32,
        ctx_tokens: 32 * 1024,
        n_seqs: 33,
    };
    bench("profiler: estimate_us", 1_000_000, || {
        std::hint::black_box(profile.estimate_us(&s));
    });

    // ---- manifest JSON parse ----
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        bench("json: parse manifest.json", 2_000, || {
            std::hint::black_box(Json::parse(&text).unwrap());
        });
    }

    println!("\nmicrobench OK");
}
