//! Offline batch serving (the OpenAI-Batch-style frontend, paper §4.1):
//! submit a pool of document-summarization jobs, let the engine run in
//! offline batching mode — large batches, layer-wise preemption armed —
//! and collect the asynchronous results.
//!
//! ```bash
//! cargo run --release --example offline_batch
//! ```

use conserve::backend::PjrtBackend;
use conserve::config::EngineConfig;
use conserve::profiler::LatencyProfile;
use conserve::request::{Class, Request};
use conserve::runtime::tokenizer::{detokenize, tokenize};
use conserve::server::{ArrivalSource, ServingEngine};
use conserve::util::rng::Rng;
use conserve::workload::datasets;

const DOCS: &[&str] = &[
    "The serving cluster processed record load this quarter while keeping tail latency within objectives.",
    "Incremental checkpointing amortizes device-to-host traffic across generation iterations.",
    "Layer-granularity safepoints balance preemption responsiveness against barrier overhead.",
    "Background prefetching overlaps swap-in with the prefill of freshly admitted batches.",
];

fn main() -> anyhow::Result<()> {
    let mut cfg = EngineConfig::real_tiny();
    // pure offline deployment: crank the batch caps, keep safepoints on
    cfg.sched.max_batch_tokens = 1024;

    let mut backend = PjrtBackend::load("artifacts", cfg.seed, cfg.sched.safepoint_layers)?;
    let clock = backend.clock();
    let profile = LatencyProfile::profile(&mut backend, 128, 8, 128)?;

    // build the batch: the fixed docs plus synthetic filler documents
    let mut rng = Rng::new(42);
    let mut events = Vec::new();
    let mut id = 1u64;
    for d in DOCS {
        let prompt = tokenize(d);
        let plen = prompt.len().min(200);
        let prompt = prompt[..plen].to_vec();
        events.push(Request::new(id, Class::Offline, prompt, plen, 16, 0));
        id += 1;
    }
    for _ in 0..12 {
        let n = 48 + rng.range_usize(0, 120);
        let prompt = datasets::synth_prompt(&mut rng, n);
        events.push(Request::new(id, Class::Offline, prompt, n, 16, 0));
        id += 1;
    }
    let n_jobs = events.len();

    let mut engine = ServingEngine::new(
        cfg,
        backend,
        clock,
        profile,
        ArrivalSource::from_trace(events),
    );
    let t0 = std::time::Instant::now();
    let end = engine.run(120_000_000);
    let wall = t0.elapsed().as_secs_f64();

    println!("=== batch results ({n_jobs} jobs, {wall:.1}s wall) ===");
    let mut ids: Vec<_> = engine.table.ids().collect();
    ids.sort_unstable();
    for rid in ids.iter().take(4) {
        let r = &engine.table[rid];
        println!(
            "job {rid}: {:?} -> {:?}",
            detokenize(&r.prompt[..r.prompt.len().min(48)]),
            detokenize(&r.output)
        );
    }
    let done = engine.rec.finished[1];
    let tput = engine.rec.processed_throughput(None, 0, end.max(1));
    println!("\nfinished {done}/{n_jobs} jobs; processed throughput {tput:.0} tok/s");
    assert_eq!(done as usize, n_jobs, "every batch job must complete");
    println!("offline_batch OK");
    Ok(())
}
