//! Figure 2 — "99th-percentile TTFT and TPOT of online requests when
//! co-served with offline requests using a priority-based scheduler."
//!
//! The motivation experiment: naive priority co-serving (vLLM++) vs
//! Online-Only on the bursty trace. The paper reports P99 TTFT inflated
//! 59.7x and P99 TPOT 3.16x. Absolute factors differ on the simulated
//! testbed; the qualitative claim asserted here is *orders-of-magnitude
//! TTFT inflation and multi-x TPOT inflation*.

use conserve::config::EngineConfig;
use conserve::report::compare_policies;
use conserve::scheduler::Policy;
use conserve::workload::trace::burstgpt_like_arrivals;
use conserve::workload::Lengths;

fn main() {
    let cfg = EngineConfig::sim_a100_7b();
    let duration = 450.0;
    let arrivals = burstgpt_like_arrivals(42, duration, 1.2, 1.0);
    println!(
        "online requests: {} over {duration}s (BurstGPT-like trace)",
        arrivals.len()
    );

    let reports = compare_policies(
        &cfg,
        &[Policy::OnlineOnly, Policy::VllmPP],
        &arrivals,
        Lengths::online_paper(),
        |p| if p == Policy::OnlineOnly { 0 } else { 1500 },
        Lengths::offline_paper(),
        duration,
    );
    for r in &reports {
        println!("{}", r.row());
    }

    let base = &reports[0];
    let naive = &reports[1];
    let ttft_x = naive.online_p99_ttft_ms / base.online_p99_ttft_ms.max(1.0);
    let tpot_x = naive.online_p99_tpot_ms / base.online_p99_tpot_ms.max(1.0);
    println!("\nP99 TTFT inflation: {ttft_x:>8.1}x   (paper: 59.7x)");
    println!("P99 TPOT inflation: {tpot_x:>8.1}x   (paper: 3.16x)");

    assert!(
        ttft_x > 10.0,
        "naive co-serving must inflate TTFT by an order of magnitude (got {ttft_x:.1}x)"
    );
    // TPOT inflation is not asserted: in this memory model vLLM++'s
    // class-blind preemption stalls *admission* (so its decode batches
    // stay small and TPOT low) while the paper's testbed showed 3.16x —
    // the deviation and its cause are recorded in EXPERIMENTS.md.
    let _ = tpot_x;
    assert!(
        naive.ttft_violations > 0.5,
        "naive co-serving must blow the TTFT SLO for most requests"
    );
    println!("\nfig2 shape OK");
}
