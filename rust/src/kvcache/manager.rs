//! Block-granular KV accounting: GPU and host pools, per-sequence block
//! tables, and the GPU<->host checkpoint mapping (§5: "keeping track of
//! the mapping between each GPU KV block and its corresponding CPU KV
//! block ... recorded in an extended field of the virtual page table").
//!
//! Sequences are keyed by the *slot* field of [`RequestId`] (the same
//! dense index the request arena uses), so `grow`/`commit`/`seq` are
//! plain array accesses with a generation check — no hashing on the
//! schedule→execute→commit path. A lookup with a stale generation
//! resolves to "unknown sequence", never to another request's KV.
//!
//! Like the arena, each manager belongs to one worker shard
//! ([`KvManager::for_shard`]; default shard 0) and checks the shard bits
//! of every id, so a request id from another shard can never read or
//! mutate this shard's block tables.

use super::prefix::{chain_hash, PrefixIndex, PREFIX_DIGEST_WORDS, PREFIX_SEED};
use super::BlockId;
use crate::request::{rid_gen, rid_shard, rid_slot, RequestId, TokenId, MAX_SHARDS};

/// A pool of fixed-size blocks; O(1) alloc/free via a free list, with a
/// per-block reference count so prefix-shared blocks survive until the
/// last owner (a sequence or the prefix trie) drops them.
#[derive(Debug)]
pub struct BlockPool {
    total: usize,
    free: Vec<BlockId>,
    /// Per-block reference count: 0 = free, 1 = exclusively owned,
    /// >= 2 = shared across owners.
    refs: Vec<u32>,
    /// Blocks with refs >= 2 (O(1) shared-residency gauge).
    shared: usize,
}

impl BlockPool {
    pub fn new(total: usize) -> Self {
        Self {
            total,
            free: (0..total as BlockId).rev().collect(),
            refs: vec![0; total],
            shared: 0,
        }
    }

    pub fn alloc(&mut self) -> Option<BlockId> {
        let b = self.free.pop()?;
        self.refs[b as usize] = 1;
        Some(b)
    }

    /// Free an exclusively-owned block. Paths that may hold shared
    /// blocks go through [`release`](Self::release) instead, which frees
    /// only on the last drop.
    pub fn free(&mut self, b: BlockId) {
        debug_assert!(!self.free.contains(&b), "double free of block {b}");
        debug_assert_eq!(self.refs[b as usize], 1, "free of shared block {b}");
        self.refs[b as usize] = 0;
        self.free.push(b);
    }

    /// Add a reference to a live block (prefix-cache sharing).
    pub fn retain(&mut self, b: BlockId) {
        let r = &mut self.refs[b as usize];
        debug_assert!(*r > 0, "retain of free block {b}");
        *r += 1;
        if *r == 2 {
            self.shared += 1;
        }
    }

    /// Drop one reference; the last dropper frees. Returns whether the
    /// block actually went back to the free list.
    pub fn release(&mut self, b: BlockId) -> bool {
        let r = &mut self.refs[b as usize];
        debug_assert!(*r > 0, "release of free block {b}");
        if *r == 2 {
            self.shared -= 1;
        }
        *r -= 1;
        if *r == 0 {
            self.free.push(b);
            true
        } else {
            false
        }
    }

    pub fn refcount(&self, b: BlockId) -> u32 {
        self.refs[b as usize]
    }

    /// Blocks currently referenced by more than one owner (O(1)).
    pub fn shared_count(&self) -> usize {
        self.shared
    }

    /// Free-list/refcount agreement (conservation-check support): every
    /// free-listed block has refcount 0, every block is free or
    /// referenced, and the shared gauge matches the refcounts.
    fn consistent(&self) -> bool {
        self.free.iter().all(|&b| self.refs[b as usize] == 0)
            && self.free.len() + self.refs.iter().filter(|&&r| r > 0).count() == self.total
            && self.shared == self.refs.iter().filter(|&&r| r >= 2).count()
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn used(&self) -> usize {
        self.total - self.free.len()
    }
}

/// Per-logical-block checkpoint state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockCkpt {
    /// No host copy.
    None,
    /// D2H copy in flight.
    InFlight(BlockId),
    /// Host copy valid at `BlockId`.
    Done(BlockId),
}

/// Block table for one sequence.
#[derive(Debug)]
pub struct SeqKv {
    /// Logical block i -> GPU physical block (None after GPU eviction).
    pub gpu: Vec<Option<BlockId>>,
    /// Logical block i -> host checkpoint state.
    pub host: Vec<BlockCkpt>,
    /// Committed tokens (== the owning request's ctx_len).
    pub tokens: usize,
    /// GPU-resident block count, maintained on alloc/evict so the victim
    /// scan does not rescan the block table.
    resident: usize,
    /// Completed host checkpoints, maintained on finish/invalidate so
    /// `fully_checkpointed` is O(1).
    host_done: usize,
    /// Prompt blocks already published to (or attached from) the shard's
    /// prefix trie — the next candidate index for
    /// [`KvManager::prefix_publish`]. Monotone within a registration.
    published: usize,
    /// Rolling prefix hash through `published` blocks, so publishing the
    /// next block is O(block_tokens), not O(prefix).
    chain: u64,
}

impl SeqKv {
    fn new() -> Self {
        Self {
            gpu: Vec::new(),
            host: Vec::new(),
            tokens: 0,
            resident: 0,
            host_done: 0,
            published: 0,
            chain: PREFIX_SEED,
        }
    }

    /// GPU-resident blocks (O(1): maintained counter).
    pub fn gpu_blocks(&self) -> usize {
        self.resident
    }

    /// All logical blocks that hold committed tokens have valid host
    /// copies (the "cheap to evict" condition of §4.4). O(1): completed
    /// checkpoints can only cover blocks holding committed tokens, so
    /// counting them suffices.
    pub fn fully_checkpointed(&self, block_tokens: usize) -> bool {
        self.host_done >= self.tokens.div_ceil(block_tokens)
    }

    /// Tokens covered by completed host checkpoints (prefix).
    pub fn ckpt_tokens(&self, block_tokens: usize) -> usize {
        let mut n = 0;
        for (i, c) in self.host.iter().enumerate() {
            if matches!(c, BlockCkpt::Done(_)) {
                n = (i + 1) * block_tokens;
            } else {
                break;
            }
        }
        n.min(self.tokens)
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum KvError {
    OutOfGpu { need: usize, free: usize },
    OutOfHost,
    UnknownSeq(RequestId),
    /// The sequence is not in a migratable state: it still holds GPU
    /// blocks, has checkpoints in flight, or its committed tokens are not
    /// fully covered by completed host checkpoints (§4.4: only fully
    /// checkpointed, evicted sequences move for free).
    NotPortable(RequestId),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfGpu { need, free } => {
                write!(f, "out of GPU KV blocks (need {need}, free {free})")
            }
            KvError::OutOfHost => write!(f, "out of host KV blocks"),
            KvError::UnknownSeq(id) => write!(f, "unknown sequence {id}"),
            KvError::NotPortable(id) => {
                write!(f, "sequence {id} is not fully host-checkpointed")
            }
        }
    }
}

impl std::error::Error for KvError {}

/// One dense sequence-table entry. `generation` mirrors the request
/// arena's slot generation; a lookup only hits when both halves of the
/// id match.
#[derive(Debug, Default)]
struct SeqEntry {
    generation: u32,
    kv: Option<SeqKv>,
}

/// The KV-cache manager: pools + tables. All scheduler memory decisions
/// (admission, eviction, checkpoint selection) query this.
#[derive(Debug)]
pub struct KvManager {
    pub block_tokens: usize,
    shard: u32,
    gpu: BlockPool,
    host: BlockPool,
    seqs: Vec<SeqEntry>,
    /// Cross-request prefix sharing index (None = sharing off, the
    /// default: every path below behaves exactly as before).
    prefix: Option<PrefixIndex>,
}

impl KvManager {
    /// Single-worker manager (shard 0).
    pub fn new(gpu_blocks: usize, host_blocks: usize, block_tokens: usize) -> Self {
        Self::for_shard(0, gpu_blocks, host_blocks, block_tokens)
    }

    /// Manager for worker shard `shard`: only ids carrying this shard
    /// index resolve; everything else misses as an unknown sequence.
    pub fn for_shard(
        shard: usize,
        gpu_blocks: usize,
        host_blocks: usize,
        block_tokens: usize,
    ) -> Self {
        assert!(shard < MAX_SHARDS, "shard {shard} out of range");
        Self {
            block_tokens,
            shard: shard as u32,
            gpu: BlockPool::new(gpu_blocks),
            host: BlockPool::new(host_blocks),
            seqs: Vec::new(),
            prefix: None,
        }
    }

    /// The worker shard this manager belongs to.
    pub fn shard(&self) -> usize {
        self.shard as usize
    }

    /// Does `id` carry this manager's shard index?
    #[inline]
    fn owns(&self, id: RequestId) -> bool {
        rid_shard(id) == self.shard as usize
    }

    pub fn gpu_free(&self) -> usize {
        self.gpu.available()
    }

    pub fn gpu_total(&self) -> usize {
        self.gpu.total()
    }

    pub fn gpu_free_frac(&self) -> f64 {
        self.gpu.available() as f64 / self.gpu.total() as f64
    }

    pub fn host_free(&self) -> usize {
        self.host.available()
    }

    #[inline]
    pub fn seq(&self, id: RequestId) -> Option<&SeqKv> {
        if !self.owns(id) {
            return None;
        }
        self.seqs
            .get(rid_slot(id))
            .filter(|e| e.generation == rid_gen(id))
            .and_then(|e| e.kv.as_ref())
    }

    #[inline]
    fn seq_mut(&mut self, id: RequestId) -> Option<&mut SeqKv> {
        if !self.owns(id) {
            return None;
        }
        self.seqs
            .get_mut(rid_slot(id))
            .filter(|e| e.generation == rid_gen(id))
            .and_then(|e| e.kv.as_mut())
    }

    /// Free every block a stale entry still owns (defensive: callers are
    /// expected to `release` before a slot is recycled, but a leak here
    /// would silently shrink the pools for the rest of the run).
    fn purge_entry(gpu: &mut BlockPool, host: &mut BlockPool, kv: &mut SeqKv) {
        for slot in kv.gpu.iter_mut() {
            if let Some(b) = slot.take() {
                gpu.release(b); // shared blocks survive under other refs
            }
        }
        for c in kv.host.iter_mut() {
            if let BlockCkpt::Done(hb) | BlockCkpt::InFlight(hb) = *c {
                host.free(hb);
            }
            *c = BlockCkpt::None;
        }
        kv.resident = 0;
        kv.host_done = 0;
        kv.published = 0;
        kv.chain = PREFIX_SEED;
    }

    pub fn register(&mut self, id: RequestId) {
        assert!(
            self.owns(id),
            "registering id {id} from shard {} on shard {}",
            rid_shard(id),
            self.shard
        );
        let slot = rid_slot(id);
        let generation = rid_gen(id);
        if self.seqs.len() <= slot {
            self.seqs.resize_with(slot + 1, SeqEntry::default);
        }
        let entry = &mut self.seqs[slot];
        if entry.generation != generation {
            // recycled slot: drop whatever the stale occupant left behind
            if let Some(kv) = entry.kv.as_mut() {
                debug_assert!(
                    kv.resident == 0 && kv.host_done == 0,
                    "recycled slot {slot} still owns blocks"
                );
                Self::purge_entry(&mut self.gpu, &mut self.host, kv);
            }
            entry.generation = generation;
            entry.kv = Some(SeqKv::new());
        } else if entry.kv.is_none() {
            entry.kv = Some(SeqKv::new());
        }
    }

    /// GPU blocks that must be newly allocated for `id` to hold
    /// `new_total` committed tokens.
    pub fn blocks_needed(&self, id: RequestId, new_total: usize) -> usize {
        let have = self.seq(id).map(|s| s.gpu_blocks()).unwrap_or(0);
        new_total.div_ceil(self.block_tokens).saturating_sub(have)
    }

    /// Grow the GPU block table of `id` to cover `new_total` tokens.
    /// Fails atomically (no partial allocation) if the pool is short.
    pub fn grow(&mut self, id: RequestId, new_total: usize) -> Result<(), KvError> {
        let block_tokens = self.block_tokens;
        let seq = self.seq(id).ok_or(KvError::UnknownSeq(id))?;
        let needed_slots = new_total.div_ceil(block_tokens);
        // Fill gaps (evicted blocks being re-fetched keep their slot) and
        // extend; count first for atomicity.
        let mut need = 0;
        for i in 0..needed_slots {
            match seq.gpu.get(i) {
                Some(Some(_)) => {}
                _ => need += 1,
            }
        }
        if need > self.gpu.available() {
            // take cache-only trie blocks back before declaring the pool
            // short — the prefix cache only ever borrows slack capacity
            let short = need - self.gpu.available();
            self.prefix_reclaim(short);
        }
        let gpu_avail = self.gpu.available();
        if need > gpu_avail {
            return Err(KvError::OutOfGpu {
                need,
                free: gpu_avail,
            });
        }
        let slot = rid_slot(id);
        let entry = &mut self.seqs[slot];
        let seq = entry.kv.as_mut().unwrap();
        for i in 0..needed_slots {
            let missing = !matches!(seq.gpu.get(i), Some(Some(_)));
            if missing {
                let b = self.gpu.alloc().unwrap();
                if i < seq.gpu.len() {
                    seq.gpu[i] = Some(b);
                } else {
                    while seq.gpu.len() < i {
                        seq.gpu.push(None);
                    }
                    seq.gpu.push(Some(b));
                }
                seq.resident += 1;
            }
            if seq.host.len() <= i {
                seq.host.push(BlockCkpt::None);
            }
        }
        Ok(())
    }

    /// Commit `n` new tokens (caller already grew capacity). Newly
    /// *refilled* partial blocks invalidate their stale checkpoints:
    /// a block's host copy is only valid if taken when the block was full
    /// or the sequence stopped writing to it.
    pub fn commit(&mut self, id: RequestId, n: usize) -> Result<(), KvError> {
        if !self.owns(id) {
            return Err(KvError::UnknownSeq(id));
        }
        let bt = self.block_tokens;
        let slot = rid_slot(id);
        let entry = self
            .seqs
            .get_mut(slot)
            .filter(|e| e.generation == rid_gen(id))
            .ok_or(KvError::UnknownSeq(id))?;
        let seq = entry.kv.as_mut().ok_or(KvError::UnknownSeq(id))?;
        let first_dirty = seq.tokens / bt; // block receiving new tokens
        seq.tokens += n;
        debug_assert!(
            seq.tokens <= seq.gpu.len() * bt,
            "commit beyond allocated capacity"
        );
        let last_dirty = (seq.tokens - 1) / bt;
        for i in first_dirty..=last_dirty {
            if let Some(c) = seq.host.get_mut(i) {
                match *c {
                    BlockCkpt::Done(hb) => {
                        self.host.free(hb);
                        *c = BlockCkpt::None;
                        seq.host_done -= 1;
                    }
                    BlockCkpt::InFlight(hb) => {
                        self.host.free(hb);
                        *c = BlockCkpt::None;
                    }
                    BlockCkpt::None => {}
                }
            }
        }
        Ok(())
    }

    /// Logical blocks eligible for checkpointing: hold committed tokens,
    /// GPU-resident, no valid/in-flight host copy. A partial tail block
    /// is eligible too (the next commit invalidates it — §4.4 amortizes
    /// this as "checkpoint per generation iteration").
    pub fn checkpoint_candidates(&self, id: RequestId) -> Vec<usize> {
        let mut out = Vec::new();
        self.checkpoint_candidates_into(id, &mut out);
        out
    }

    /// Allocation-free variant: clears and refills `out`.
    pub fn checkpoint_candidates_into(&self, id: RequestId, out: &mut Vec<usize>) {
        out.clear();
        let Some(seq) = self.seq(id) else {
            return;
        };
        let used = seq.tokens.div_ceil(self.block_tokens);
        out.extend((0..used).filter(|&i| {
            matches!(seq.gpu.get(i), Some(Some(_)))
                && matches!(seq.host.get(i), Some(BlockCkpt::None))
        }));
    }

    /// Start a D2H checkpoint of logical block `idx`: allocates a host
    /// block and marks it in flight. Returns (gpu_block, host_block).
    pub fn begin_ckpt(
        &mut self,
        id: RequestId,
        idx: usize,
    ) -> Result<(BlockId, BlockId), KvError> {
        let hb = self.host.alloc().ok_or(KvError::OutOfHost)?;
        let Some(seq) = self.seq_mut(id) else {
            self.host.free(hb);
            return Err(KvError::UnknownSeq(id));
        };
        let gb = seq.gpu[idx].expect("checkpointing evicted block");
        debug_assert_eq!(seq.host[idx], BlockCkpt::None);
        seq.host[idx] = BlockCkpt::InFlight(hb);
        Ok((gb, hb))
    }

    /// D2H copy finished.
    pub fn finish_ckpt(&mut self, id: RequestId, idx: usize) {
        if let Some(seq) = self.seq_mut(id) {
            if let BlockCkpt::InFlight(hb) = seq.host[idx] {
                seq.host[idx] = BlockCkpt::Done(hb);
                seq.host_done += 1;
            }
        }
    }

    /// Evict all GPU blocks of `id` (host checkpoints retained). This is
    /// the O(µs) "discard + remap" release of §4.4 — legal only when the
    /// caller either has full checkpoints or accepts recompute. Returns
    /// the GPU blocks actually freed: a prefix-shared block only drops
    /// this sequence's reference and survives under the remaining ones
    /// (the last dropper frees it).
    pub fn evict_gpu(&mut self, id: RequestId) -> usize {
        if !self.owns(id) {
            return 0;
        }
        let slot = rid_slot(id);
        let Some(entry) = self
            .seqs
            .get_mut(slot)
            .filter(|e| e.generation == rid_gen(id))
        else {
            return 0;
        };
        let Some(seq) = entry.kv.as_mut() else {
            return 0;
        };
        let mut n = 0;
        for s in seq.gpu.iter_mut() {
            if let Some(b) = s.take() {
                if self.gpu.release(b) {
                    n += 1;
                }
            }
        }
        seq.resident = 0;
        n
    }

    /// Drop everything (request finished/aborted or KV discarded).
    /// `keep_host=false` also releases checkpoints.
    pub fn release(&mut self, id: RequestId, keep_host: bool) {
        if !self.owns(id) {
            return;
        }
        let slot = rid_slot(id);
        let Some(entry) = self
            .seqs
            .get_mut(slot)
            .filter(|e| e.generation == rid_gen(id))
        else {
            return;
        };
        let Some(seq) = entry.kv.as_mut() else {
            return;
        };
        for s in seq.gpu.iter_mut() {
            if let Some(b) = s.take() {
                self.gpu.release(b);
            }
        }
        seq.resident = 0;
        if keep_host {
            // sequence dropped to host residence: keep the table so a
            // later prefetch can restore it
        } else {
            for c in seq.host.iter_mut() {
                if let BlockCkpt::Done(hb) | BlockCkpt::InFlight(hb) = *c {
                    self.host.free(hb);
                }
                *c = BlockCkpt::None;
            }
            seq.host_done = 0;
            entry.kv = None;
        }
    }

    /// Discard a sequence's KV entirely (recompute path): frees GPU and
    /// host blocks and resets committed tokens to zero, keeping the
    /// registration alive. Foreign-shard ids are a no-op like every
    /// other entry point (`register` alone asserts, so guard first).
    pub fn discard(&mut self, id: RequestId) {
        if !self.owns(id) {
            return;
        }
        self.release(id, false);
        self.register(id);
    }

    /// Blocks that must be prefetched (H2D) to resume `id`: logical
    /// indices with a host copy but no GPU copy, covering committed tokens.
    pub fn prefetch_candidates(&self, id: RequestId) -> Vec<(usize, BlockId)> {
        let mut out = Vec::new();
        self.prefetch_candidates_into(id, &mut out);
        out
    }

    /// Allocation-free variant: clears and refills `out`.
    pub fn prefetch_candidates_into(&self, id: RequestId, out: &mut Vec<(usize, BlockId)>) {
        out.clear();
        let Some(seq) = self.seq(id) else {
            return;
        };
        let used = seq.tokens.div_ceil(self.block_tokens);
        out.extend((0..used).filter_map(|i| {
            match (seq.gpu.get(i), seq.host.get(i)) {
                (Some(None), Some(BlockCkpt::Done(hb))) => Some((i, *hb)),
                _ => None,
            }
        }));
    }

    /// Count of blocks still missing on the GPU that have a host copy to
    /// restore from (the `prefetch_candidates` cardinality, without the
    /// allocation).
    pub fn missing_prefetch(&self, id: RequestId) -> usize {
        let Some(seq) = self.seq(id) else {
            return 0;
        };
        let used = seq.tokens.div_ceil(self.block_tokens);
        (0..used)
            .filter(|&i| {
                matches!(
                    (seq.gpu.get(i), seq.host.get(i)),
                    (Some(None), Some(BlockCkpt::Done(_)))
                )
            })
            .count()
    }

    /// Detach `id`'s KV accounting for cross-shard migration, freeing this
    /// shard's blocks. Returns the committed tokens covered by the
    /// detached host-checkpoint prefix (the count the importer must
    /// re-allocate), or 0 when the sequence held no state (never
    /// registered, or discarded — a cold steal).
    ///
    /// Fails with [`KvError::NotPortable`] unless the sequence is in the
    /// free-to-move state of §4.4: no GPU-resident blocks, no checkpoint
    /// in flight, and every committed token covered by a completed host
    /// checkpoint — the caller must evict (or discard) first. The block
    /// *data* is the backend's concern
    /// ([`ExecBackend::export_host_kv`](crate::backend::ExecBackend::export_host_kv));
    /// this is the page-table half of the handoff.
    pub fn export_host(&mut self, id: RequestId) -> Result<usize, KvError> {
        if !self.owns(id) {
            return Err(KvError::UnknownSeq(id));
        }
        let slot = rid_slot(id);
        let Some(entry) = self
            .seqs
            .get_mut(slot)
            .filter(|e| e.generation == rid_gen(id))
        else {
            return Ok(0); // never registered: nothing to detach
        };
        let Some(seq) = entry.kv.as_mut() else {
            return Ok(0);
        };
        let bt = self.block_tokens;
        let in_flight = seq
            .host
            .iter()
            .any(|c| matches!(c, BlockCkpt::InFlight(_)));
        // `resident != 0` is also the prefix-sharing guard: a sequence
        // holding *any* GPU block — in particular one whose refcount > 1
        // because other requests or the trie still reference it — must
        // evict first, which drops only this sequence's references.
        // Migration therefore can never detach a block another request
        // still uses; only private host checkpoints travel.
        if seq.resident != 0 || in_flight || !seq.fully_checkpointed(bt) {
            return Err(KvError::NotPortable(id));
        }
        let tokens = seq.tokens;
        for c in seq.host.iter_mut() {
            if let BlockCkpt::Done(hb) = *c {
                self.host.free(hb);
            }
            *c = BlockCkpt::None;
        }
        seq.host_done = 0;
        entry.kv = None;
        Ok(tokens)
    }

    /// Adopt a migrated checkpoint prefix on this shard: registers `id`
    /// and allocates host blocks (marked `Done`) covering `tokens`
    /// committed tokens, so resume is a plain prefetch. Fails atomically
    /// with [`KvError::OutOfHost`] when the pool cannot hold the prefix
    /// (the request stays registered with no KV — the recompute path).
    pub fn import_host(&mut self, id: RequestId, tokens: usize) -> Result<(), KvError> {
        self.register(id);
        if tokens == 0 {
            return Ok(());
        }
        let blocks = tokens.div_ceil(self.block_tokens);
        if self.host.available() < blocks {
            return Err(KvError::OutOfHost);
        }
        let seq = self.seqs[rid_slot(id)].kv.as_mut().unwrap();
        debug_assert!(
            seq.tokens == 0 && seq.gpu.is_empty(),
            "importing over live KV state"
        );
        for _ in 0..blocks {
            let hb = self.host.alloc().unwrap();
            seq.gpu.push(None);
            seq.host.push(BlockCkpt::Done(hb));
        }
        seq.tokens = tokens;
        seq.host_done = blocks;
        Ok(())
    }

    /// Allocate a GPU block for a prefetched logical block and return it.
    pub fn begin_prefetch(&mut self, id: RequestId, idx: usize) -> Result<BlockId, KvError> {
        if self.gpu.available() == 0 {
            self.prefix_reclaim(1); // cache-only blocks yield to swap-ins
        }
        let gb = self.gpu.alloc().ok_or(KvError::OutOfGpu { need: 1, free: 0 })?;
        let Some(seq) = self.seq_mut(id) else {
            self.gpu.free(gb);
            return Err(KvError::UnknownSeq(id));
        };
        debug_assert!(seq.gpu[idx].is_none());
        seq.gpu[idx] = Some(gb);
        seq.resident += 1;
        Ok(gb)
    }

    // ---- cross-request prefix sharing ----

    /// Turn on the prefix cache for this shard: admitted prompts map
    /// onto already-resident shared blocks ([`Self::prefix_attach`]) and
    /// freshly-prefilled prompt blocks are indexed for later requests
    /// ([`Self::prefix_publish`]). Off by default; with it off every
    /// path behaves exactly as before sharing existed.
    pub fn enable_prefix_cache(&mut self) {
        if self.prefix.is_none() {
            self.prefix = Some(PrefixIndex::new());
        }
    }

    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Cumulative (hits, lookups) of admission-time prefix attachment.
    pub fn prefix_stats(&self) -> (u64, u64) {
        self.prefix.as_ref().map(|p| p.stats()).unwrap_or((0, 0))
    }

    /// Blocks currently indexed by the trie (each holds one cache ref).
    pub fn prefix_cached_blocks(&self) -> usize {
        self.prefix.as_ref().map(|p| p.len()).unwrap_or(0)
    }

    /// Cumulative trie blocks freed by reclaim under memory pressure
    /// (drives the engine's `PrefixReclaim` trace events).
    pub fn prefix_reclaimed_blocks(&self) -> u64 {
        self.prefix.as_ref().map(|p| p.reclaimed_blocks()).unwrap_or(0)
    }

    /// GPU blocks referenced by more than one owner right now (O(1)).
    pub fn shared_gpu_blocks(&self) -> usize {
        self.gpu.shared_count()
    }

    /// Membership digest over the trie's prefix hashes (zeros when
    /// sharing is off) — what `ShardLoads` publishes so the router can
    /// score prefix affinity without touching this shard.
    pub fn prefix_digest(&mut self) -> [u64; PREFIX_DIGEST_WORDS] {
        match self.prefix.as_mut() {
            Some(p) => p.digest(),
            None => [0; PREFIX_DIGEST_WORDS],
        }
    }

    /// Map a freshly-registered sequence's prompt onto already-resident
    /// shared blocks. Walks the trie along the prompt's block hash chain
    /// and attaches every hit: the block is retained (refcount + 1) and
    /// becomes the next entry of the sequence's table, and the committed
    /// token count jumps past it — the scheduler's prefill planning then
    /// skips those tokens entirely. Returns the tokens covered (0 = no
    /// hit, sharing off, or the sequence already holds state).
    ///
    /// Copy-on-write boundary, structurally: attachment never covers the
    /// block holding the last prompt token, so the first divergent block
    /// is always private — every subsequent write (`grow` + `commit`)
    /// lands at or after the write frontier in freshly-allocated blocks,
    /// and shared ancestors stay frozen. At least one prefill token
    /// always remains, keeping the first-token sample local.
    pub fn prefix_attach(&mut self, id: RequestId, prompt: &[TokenId]) -> usize {
        let bt = self.block_tokens;
        if self.prefix.is_none() || prompt.len() <= bt || !self.owns(id) {
            return 0;
        }
        // only a fresh, empty sequence may attach: shared ancestors must
        // form the table prefix, ahead of any private block
        match self.seq(id) {
            Some(s) if s.tokens == 0 && s.gpu.is_empty() => {}
            _ => return 0,
        }
        let max_blocks = (prompt.len() - 1) / bt;
        let pfx = self.prefix.as_mut().unwrap();
        pfx.record_lookup();
        let mut h = PREFIX_SEED;
        let mut chain = PREFIX_SEED; // chain through the *matched* blocks
        let mut matched: Vec<BlockId> = Vec::new();
        for blk in 0..max_blocks {
            for &t in &prompt[blk * bt..(blk + 1) * bt] {
                h = chain_hash(h, t);
            }
            match pfx.get(h) {
                Some(b) => {
                    matched.push(b);
                    chain = h;
                }
                None => break,
            }
        }
        if matched.is_empty() {
            return 0;
        }
        pfx.record_hit();
        let k = matched.len();
        let seq = self.seqs[rid_slot(id)].kv.as_mut().unwrap();
        for b in matched {
            self.gpu.retain(b);
            seq.gpu.push(Some(b));
            seq.host.push(BlockCkpt::None);
            seq.resident += 1;
        }
        seq.tokens = k * bt;
        seq.published = k;
        seq.chain = chain;
        k * bt
    }

    /// Publish `id`'s committed full prompt blocks into the trie so later
    /// requests with the same prefix can attach them. Idempotent and
    /// incremental: the engine calls this after every prefill commit and
    /// only the newly-completed blocks past the publish cursor are
    /// hashed. The trie takes one reference per indexed block, so an
    /// entry outlives its publisher; the first publisher of a hash wins.
    pub fn prefix_publish(&mut self, id: RequestId, prompt: &[TokenId]) {
        if self.prefix.is_none() || !self.owns(id) {
            return;
        }
        let bt = self.block_tokens;
        let full = prompt.len() / bt; // blocks holding only prompt tokens
        let Some(entry) = self
            .seqs
            .get_mut(rid_slot(id))
            .filter(|e| e.generation == rid_gen(id))
        else {
            return;
        };
        let Some(seq) = entry.kv.as_mut() else {
            return;
        };
        let pfx = self.prefix.as_mut().unwrap();
        while seq.published < full && (seq.published + 1) * bt <= seq.tokens {
            let idx = seq.published;
            let Some(&Some(b)) = seq.gpu.get(idx) else {
                break; // evicted mid-prefill: nothing publishable here
            };
            let mut h = seq.chain;
            for &t in &prompt[idx * bt..(idx + 1) * bt] {
                h = chain_hash(h, t);
            }
            if pfx.get(h).is_none() {
                self.gpu.retain(b); // the trie's own reference
                pfx.insert(h, b);
            }
            seq.published = idx + 1;
            seq.chain = h;
        }
    }

    /// Evict cache-only trie entries (blocks whose sole reference is the
    /// trie's) to free `need` blocks for live sequences. Entries another
    /// sequence still shares are never torn. Returns blocks freed.
    fn prefix_reclaim(&mut self, need: usize) -> usize {
        let Some(pfx) = self.prefix.as_mut() else {
            return 0;
        };
        let gpu = &mut self.gpu;
        pfx.reclaim(need, |b| {
            if gpu.refcount(b) == 1 {
                gpu.release(b);
                true
            } else {
                false
            }
        })
    }

    /// Invariant check used by property tests: for every GPU block, the
    /// references held by sequence tables plus the prefix trie equal the
    /// pool's refcount, and a block is free exactly when that count is
    /// zero (so the last dropper frees, with no double-free and no
    /// leak). Host blocks stay exclusively owned, and the O(1) counters
    /// (`resident`, `host_done`, the shared gauge) must agree with the
    /// tables they summarize.
    pub fn check_conservation(&self) -> bool {
        let mut expect = vec![0u32; self.gpu.total()];
        let mut host_owned = 0usize;
        let mut seen_host = std::collections::HashSet::new();
        for seq in self.seqs.iter().filter_map(|e| e.kv.as_ref()) {
            let mut resident = 0;
            for b in seq.gpu.iter().flatten() {
                expect[*b as usize] += 1;
                resident += 1;
            }
            if resident != seq.resident {
                return false; // counter drift
            }
            let mut done = 0;
            for c in &seq.host {
                if let BlockCkpt::Done(hb) | BlockCkpt::InFlight(hb) = c {
                    if !seen_host.insert(*hb) {
                        return false; // host blocks are never shared
                    }
                    host_owned += 1;
                }
                if matches!(c, BlockCkpt::Done(_)) {
                    done += 1;
                }
            }
            if done != seq.host_done {
                return false;
            }
        }
        if let Some(pfx) = self.prefix.as_ref() {
            for b in pfx.blocks() {
                expect[b as usize] += 1;
            }
        }
        (0..self.gpu.total()).all(|b| expect[b] == self.gpu.refcount(b as BlockId))
            && self.gpu.consistent()
            && self.host.consistent()
            && host_owned + self.host.available() == self.host.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> KvManager {
        KvManager::new(8, 16, 16)
    }

    #[test]
    fn grow_and_commit() {
        let mut m = mgr();
        m.register(1);
        assert_eq!(m.blocks_needed(1, 17), 2);
        m.grow(1, 17).unwrap();
        m.commit(1, 17).unwrap();
        assert_eq!(m.seq(1).unwrap().tokens, 17);
        assert_eq!(m.gpu_free(), 6);
        assert_eq!(m.blocks_needed(1, 32), 0);
        assert_eq!(m.blocks_needed(1, 33), 1);
        assert!(m.check_conservation());
    }

    #[test]
    fn grow_fails_atomically() {
        let mut m = mgr();
        m.register(1);
        let err = m.grow(1, 16 * 9).unwrap_err();
        assert_eq!(err, KvError::OutOfGpu { need: 9, free: 8 });
        assert_eq!(m.gpu_free(), 8); // nothing leaked
        assert!(m.check_conservation());
    }

    #[test]
    fn checkpoint_lifecycle() {
        let mut m = mgr();
        m.register(1);
        m.grow(1, 40).unwrap();
        m.commit(1, 40).unwrap();
        // blocks 0,1 full; block 2 partial (8 tokens) — all candidates
        assert_eq!(m.checkpoint_candidates(1), vec![0, 1, 2]);
        let (_gb, _hb) = m.begin_ckpt(1, 0).unwrap();
        assert_eq!(m.checkpoint_candidates(1), vec![1, 2]);
        m.finish_ckpt(1, 0);
        assert_eq!(m.seq(1).unwrap().ckpt_tokens(16), 16);
        m.begin_ckpt(1, 1).unwrap();
        m.finish_ckpt(1, 1);
        m.begin_ckpt(1, 2).unwrap();
        m.finish_ckpt(1, 2);
        assert!(m.seq(1).unwrap().fully_checkpointed(16));
        assert!(m.check_conservation());
    }

    #[test]
    fn commit_invalidates_partial_block_ckpt() {
        let mut m = mgr();
        m.register(1);
        m.grow(1, 8).unwrap();
        m.commit(1, 8).unwrap();
        m.begin_ckpt(1, 0).unwrap();
        m.finish_ckpt(1, 0);
        assert!(m.seq(1).unwrap().fully_checkpointed(16));
        let host_free = m.host_free();
        // writing more tokens into block 0 invalidates its checkpoint
        m.grow(1, 12).unwrap();
        m.commit(1, 4).unwrap();
        assert!(!m.seq(1).unwrap().fully_checkpointed(16));
        assert_eq!(m.host_free(), host_free + 1); // stale copy freed
        assert_eq!(m.checkpoint_candidates(1), vec![0]);
        assert!(m.check_conservation());
    }

    #[test]
    fn evict_and_prefetch_roundtrip() {
        let mut m = mgr();
        m.register(1);
        m.grow(1, 32).unwrap();
        m.commit(1, 32).unwrap();
        for i in m.checkpoint_candidates(1) {
            m.begin_ckpt(1, i).unwrap();
            m.finish_ckpt(1, i);
        }
        let freed = m.evict_gpu(1);
        assert_eq!(freed, 2);
        assert_eq!(m.gpu_free(), 8);
        // tokens survive; prefetch restores
        assert_eq!(m.seq(1).unwrap().tokens, 32);
        let cands = m.prefetch_candidates(1);
        assert_eq!(cands.len(), 2);
        assert_eq!(m.missing_prefetch(1), 2);
        for (i, _hb) in cands {
            m.begin_prefetch(1, i).unwrap();
        }
        assert_eq!(m.seq(1).unwrap().gpu_blocks(), 2);
        assert_eq!(m.missing_prefetch(1), 0);
        assert!(m.check_conservation());
    }

    #[test]
    fn discard_resets() {
        let mut m = mgr();
        m.register(1);
        m.grow(1, 32).unwrap();
        m.commit(1, 32).unwrap();
        m.discard(1);
        assert_eq!(m.gpu_free(), 8);
        assert_eq!(m.seq(1).unwrap().tokens, 0);
        assert!(m.check_conservation());
    }

    #[test]
    fn release_keep_host_preserves_ckpts() {
        let mut m = mgr();
        m.register(1);
        m.grow(1, 16).unwrap();
        m.commit(1, 16).unwrap();
        m.begin_ckpt(1, 0).unwrap();
        m.finish_ckpt(1, 0);
        m.release(1, true);
        assert_eq!(m.gpu_free(), 8);
        assert_eq!(m.prefetch_candidates(1).len(), 1);
        m.release(1, false);
        assert_eq!(m.host_free(), 16);
        assert!(m.check_conservation());
    }

    #[test]
    fn foreign_shard_ids_never_alias() {
        use crate::request::rid_pack_sharded;
        let mut a = KvManager::for_shard(1, 8, 16, 16);
        let mut b = KvManager::for_shard(2, 8, 16, 16);
        assert_eq!(a.shard(), 1);
        // same (slot, generation) registered in both shards
        let ida = rid_pack_sharded(1, 3, 0);
        let idb = rid_pack_sharded(2, 3, 0);
        a.register(ida);
        a.grow(ida, 32).unwrap();
        a.commit(ida, 32).unwrap();
        b.register(idb);
        // shard B's id misses shard A entirely (and vice versa)
        assert!(a.seq(idb).is_none());
        assert!(b.seq(ida).is_none());
        assert_eq!(a.grow(idb, 16), Err(KvError::UnknownSeq(idb)));
        assert_eq!(b.commit(ida, 1), Err(KvError::UnknownSeq(ida)));
        assert_eq!(a.evict_gpu(idb), 0);
        b.release(ida, false); // no-op
        b.discard(ida); // no-op, not a panic
        assert_eq!(a.seq(ida).unwrap().tokens, 32);
        assert!(a.check_conservation() && b.check_conservation());
    }

    #[test]
    fn export_import_moves_checkpoint_between_shards() {
        use crate::request::rid_pack_sharded;
        let mut donor = KvManager::for_shard(1, 8, 16, 16);
        let mut target = KvManager::for_shard(2, 8, 16, 16);
        let did = rid_pack_sharded(1, 3, 0);
        donor.register(did);
        donor.grow(did, 40).unwrap();
        donor.commit(did, 40).unwrap();
        // not portable while GPU-resident / partially checkpointed
        assert_eq!(donor.export_host(did), Err(KvError::NotPortable(did)));
        for i in donor.checkpoint_candidates(did) {
            donor.begin_ckpt(did, i).unwrap();
            donor.finish_ckpt(did, i);
        }
        assert_eq!(donor.export_host(did), Err(KvError::NotPortable(did)));
        donor.evict_gpu(did);
        let tokens = donor.export_host(did).unwrap();
        assert_eq!(tokens, 40);
        // donor fully clean: no leaked blocks, no resolvable sequence
        assert_eq!(donor.gpu_free(), 8);
        assert_eq!(donor.host_free(), 16);
        assert!(donor.seq(did).is_none());
        assert!(donor.check_conservation());

        let tid = rid_pack_sharded(2, 5, 0);
        target.import_host(tid, tokens).unwrap();
        let seq = target.seq(tid).unwrap();
        assert_eq!(seq.tokens, 40);
        assert!(seq.fully_checkpointed(16));
        assert_eq!(seq.gpu_blocks(), 0);
        assert_eq!(target.host_free(), 16 - 3);
        // resume is a plain prefetch of the imported blocks
        assert_eq!(target.prefetch_candidates(tid).len(), 3);
        for (i, _hb) in target.prefetch_candidates(tid) {
            target.begin_prefetch(tid, i).unwrap();
        }
        assert_eq!(target.seq(tid).unwrap().gpu_blocks(), 3);
        assert!(target.check_conservation());
        target.release(tid, false);
        assert!(target.check_conservation());
    }

    #[test]
    fn export_host_of_empty_state_is_a_cold_steal() {
        let mut m = mgr();
        // never registered: nothing to detach, not an error
        assert_eq!(m.export_host(1), Ok(0));
        // discarded (registered, zero tokens): also cold
        m.register(2);
        m.grow(2, 20).unwrap();
        m.commit(2, 20).unwrap();
        m.discard(2);
        assert_eq!(m.export_host(2), Ok(0));
        assert!(m.seq(2).is_none(), "export drops the registration");
        assert!(m.check_conservation());
        // foreign ids still bounce
        use crate::request::rid_pack_sharded;
        let foreign = rid_pack_sharded(3, 1, 0);
        assert_eq!(m.export_host(foreign), Err(KvError::UnknownSeq(foreign)));
    }

    #[test]
    fn import_host_fails_atomically_when_pool_short() {
        let mut m = KvManager::new(8, 2, 16);
        assert_eq!(m.import_host(1, 3 * 16), Err(KvError::OutOfHost));
        assert_eq!(m.host_free(), 2, "failed import must not leak");
        // the registration survives for the recompute fallback
        assert!(m.seq(1).is_some());
        assert_eq!(m.seq(1).unwrap().tokens, 0);
        assert!(m.check_conservation());
    }

    #[test]
    fn stale_generation_never_aliases() {
        use crate::request::rid_pack;
        let mut m = mgr();
        let old = rid_pack(1, 0);
        m.register(old);
        m.grow(old, 16).unwrap();
        m.commit(old, 16).unwrap();
        m.release(old, false);
        // slot 1 recycled under generation 1
        let new = rid_pack(1, 1);
        m.register(new);
        m.grow(new, 32).unwrap();
        m.commit(new, 32).unwrap();
        // the stale id must not see (or mutate) the new occupant
        assert!(m.seq(old).is_none());
        assert_eq!(m.grow(old, 64), Err(KvError::UnknownSeq(old)));
        assert_eq!(m.evict_gpu(old), 0);
        assert_eq!(m.seq(new).unwrap().tokens, 32);
        assert!(m.check_conservation());
    }

    // ---- prefix sharing ----

    fn prefix_mgr() -> KvManager {
        let mut m = mgr();
        m.enable_prefix_cache();
        m
    }

    /// 48-token prompt = 3 full blocks at block_tokens 16.
    fn prompt48() -> Vec<TokenId> {
        (0..48).map(|i| (i % 7) as TokenId).collect()
    }

    /// Prefill + publish the canonical prompt under id 1, then attach a
    /// second request to it — the shared fixture for the sharing tests.
    fn publish_and_attach(m: &mut KvManager) -> Vec<TokenId> {
        let p = prompt48();
        m.register(1);
        m.grow(1, 48).unwrap();
        m.commit(1, 48).unwrap();
        m.prefix_publish(1, &p);
        m.register(2);
        assert_eq!(m.prefix_attach(2, &p), 32);
        p
    }

    #[test]
    fn publish_then_attach_skips_shared_prefix() {
        let mut m = prefix_mgr();
        let p = publish_and_attach(&mut m);
        assert_eq!(m.prefix_cached_blocks(), 3);
        // CoW boundary: the block holding the last prompt token stays
        // private, so only 2 of the 3 full blocks attach
        assert_eq!(m.seq(2).unwrap().tokens, 32);
        assert_eq!(m.seq(2).unwrap().gpu_blocks(), 2);
        assert_eq!(m.shared_gpu_blocks(), 3);
        // the divergent tail grows a fresh private block and commits
        // normally from the write frontier
        assert_eq!(m.blocks_needed(2, 48), 1);
        m.grow(2, 48).unwrap();
        m.commit(2, 16).unwrap();
        assert_eq!(m.seq(2).unwrap().tokens, 48);
        assert_eq!(m.prefix_stats(), (1, 1));
        // a different prompt misses without attaching anything
        let q: Vec<TokenId> = (0..48).map(|i| (i % 5) as TokenId).collect();
        m.register(3);
        assert_eq!(m.prefix_attach(3, &q), 0);
        assert_eq!(m.prefix_stats(), (1, 2));
        assert!(m.check_conservation());
    }

    #[test]
    fn last_dropper_frees_and_trie_pins_survivors() {
        let mut m = prefix_mgr();
        publish_and_attach(&mut m);
        // publisher drops: its blocks survive under the trie's refs (and
        // two of them under seq 2); nothing returns to the free list
        m.release(1, false);
        assert_eq!(m.gpu_free(), 8 - 3);
        assert!(m.check_conservation());
        // sharer drops too: blocks are cache-only now, still resident
        m.release(2, false);
        assert_eq!(m.gpu_free(), 8 - 3);
        assert_eq!(m.prefix_cached_blocks(), 3);
        assert_eq!(m.shared_gpu_blocks(), 0, "cache-only refs are exclusive");
        // pool pressure reclaims cache-only blocks instead of failing
        m.register(3);
        m.grow(3, 8 * 16).unwrap();
        assert_eq!(m.prefix_cached_blocks(), 0);
        assert!(m.check_conservation());
    }

    #[test]
    fn reclaim_never_tears_a_live_sharer() {
        let mut m = prefix_mgr();
        publish_and_attach(&mut m);
        m.release(1, false);
        // seq 2 still shares the first two blocks; only the cache-only
        // third block may be reclaimed, so an 8-block grow stays short
        m.register(3);
        let err = m.grow(3, 8 * 16).unwrap_err();
        assert_eq!(err, KvError::OutOfGpu { need: 8, free: 6 });
        assert_eq!(m.prefix_cached_blocks(), 2, "live-shared entries survive");
        assert_eq!(m.seq(2).unwrap().gpu_blocks(), 2);
        assert!(m.check_conservation());
    }

    #[test]
    fn export_host_rejects_sequences_holding_shared_blocks() {
        let mut m = prefix_mgr();
        publish_and_attach(&mut m);
        // the sharer finishes its prefill and takes private checkpoints
        // of everything — it is still not portable while it references
        // shared GPU blocks
        m.grow(2, 48).unwrap();
        m.commit(2, 16).unwrap();
        for i in m.checkpoint_candidates(2) {
            m.begin_ckpt(2, i).unwrap();
            m.finish_ckpt(2, i);
        }
        assert_eq!(m.export_host(2), Err(KvError::NotPortable(2)));
        // evicting drops only this sequence's references: the private
        // divergent block frees, shared ancestors survive untouched
        assert_eq!(m.evict_gpu(2), 1);
        let tokens = m.export_host(2).unwrap();
        assert_eq!(tokens, 48);
        assert_eq!(m.shared_gpu_blocks(), 3, "publisher + trie still share");
        assert_eq!(m.seq(1).unwrap().gpu_blocks(), 3, "donor untouched by export");
        assert!(m.check_conservation());
    }

    #[test]
    fn attach_never_covers_the_whole_prompt() {
        let mut m = prefix_mgr();
        // 32-token prompt: 2 full blocks published, but at most 1 attaches
        let p: Vec<TokenId> = (0..32).map(|i| i as TokenId).collect();
        m.register(1);
        m.grow(1, 32).unwrap();
        m.commit(1, 32).unwrap();
        m.prefix_publish(1, &p);
        assert_eq!(m.prefix_cached_blocks(), 2);
        m.register(2);
        assert_eq!(m.prefix_attach(2, &p), 16);
        // a one-block prompt has nothing shareable to gain (and does not
        // even count as a lookup)
        m.register(3);
        assert_eq!(m.prefix_attach(3, &p[..16].to_vec()), 0);
        assert_eq!(m.prefix_stats(), (1, 1));
        assert!(m.check_conservation());
    }

    #[test]
    fn sharing_off_changes_nothing() {
        let mut m = mgr(); // prefix cache NOT enabled
        let p = prompt48();
        m.register(1);
        m.grow(1, 48).unwrap();
        m.commit(1, 48).unwrap();
        m.prefix_publish(1, &p); // no-op
        m.register(2);
        assert_eq!(m.prefix_attach(2, &p), 0);
        assert_eq!(m.prefix_stats(), (0, 0));
        assert_eq!(m.shared_gpu_blocks(), 0);
        assert_eq!(m.prefix_digest(), [0u64; PREFIX_DIGEST_WORDS]);
        assert!(m.check_conservation());
    }

    #[test]
    fn digest_reflects_published_prefixes() {
        use crate::kvcache::prefix::{digest_contains, prefix_probes};
        let mut m = prefix_mgr();
        assert_eq!(m.prefix_digest(), [0u64; PREFIX_DIGEST_WORDS]);
        let p = publish_and_attach(&mut m);
        let d = m.prefix_digest();
        for h in prefix_probes(&p, 16, 8) {
            assert!(digest_contains(&d, h), "published probe missing from digest");
        }
    }
}
