//! Deadline-aware offline **job manager**: OpenAI-Batch-style jobs over
//! the sharded co-serving engine.
//!
//! ConServe treats offline work as latency-tolerant filler, but real
//! harvesting fleets sell it as *batch jobs* with tenants, priority
//! tiers and soft deadlines (HyGen, arXiv 2501.14808; Echo, arXiv
//! 2504.03651). This module gives the engine that job layer:
//!
//! * [`JobSpec`]/[`JobInput`] — a job groups many offline requests under
//!   one tenant, priority tier and soft deadline.
//! * [`JobManager`] — admits jobs, derives an **EDF-family
//!   least-laxity urgency score** ([`urgency_score`]) from deadline
//!   slack and estimated remaining work, and stamps it (plus tenant,
//!   fair-share weight and deadline) onto every request. Urgency then
//!   flows into three existing mechanisms:
//!   1. *placement* — [`Placement::Deadline`] penalizes deep offline
//!      backlogs proportionally to urgency, so urgent jobs land where
//!      they start soonest;
//!   2. *work stealing* — donors serve their highest-urgency queued
//!      requests first
//!      ([`ServingEngine::donate_victims`](crate::server::ServingEngine::donate_victims)),
//!      so urgent work migrates toward idle shards ahead of lax work;
//!   3. *scheduling* — [`SchedConfig::fair_share`](crate::config::SchedConfig::fair_share)
//!      switches each shard's offline admission from FIFO to
//!      (urgency desc, weighted tenant deficit, FIFO), so one tenant's
//!      mega-job cannot starve the others.
//! * [`JobBoard`] — lock-cheap shared progress cells the engines notify
//!   once per finished job request: the poll-able surface behind
//!   [`BatchHandle`](crate::server::api::BatchHandle) and the source of
//!   job-level deadline attainment.
//! * [`JobStore`] — a durable, resumable JSONL store (`--state-dir`):
//!   specs, per-request [`PortableRequest`] checkpoints and completed
//!   outputs. `--resume` reconstructs in-flight jobs after a crash or
//!   restart and replays unfinished requests; keyed sampling makes the
//!   replayed token streams byte-identical to an uninterrupted run.
//! * [`run_jobs`] — the sharded trace-mode driver (admission → routing →
//!   co-serving fleet → attainment report), built on the supervised
//!   fleet runner ([`run_sharded_traces_supervised`]).
//! * [`run_jobs_with_store`]/[`run_jobs_with_recovery`] — the
//!   fault-tolerance surface: periodic durable checkpoint flushes
//!   ([`JobRunOpts::ckpt_every`]), deterministic fault injection
//!   ([`FaultPlan`]), structured shard deaths with fail-fast online
//!   reporting, and checkpoint-backed offline recovery on the
//!   surviving shards under degraded offline budgets (failure model in
//!   `rust/ARCHITECTURE.md` §8).
//!
//! Acceptance benches: `cargo bench --bench bench_jobs` (FIFO vs
//! urgency scheduling → `BENCH_jobs.json`, schema in `rust/PERF.md`
//! §6) and `cargo bench --bench bench_fault` (kill/recovery equivalence
//! → `BENCH_fault.json`, schema in `rust/PERF.md` §7).

pub mod store;

use crate::config::EngineConfig;
use crate::request::{PortableRequest, Request, TokenId, URGENCY_MAX};
use crate::request::{Class, State};
use crate::shard::{
    run_sharded_traces_supervised, Placement, ShardDied, ShardRouter, ShardedRun, StealConfig,
};
use crate::util::fault::FaultPlan;
use crate::TimeUs;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

pub use store::{JobStore, ResumeState, StoredJob, StoredRequest};

/// Job identifier (nonzero; 0 in [`Request::job`] means "no job").
pub type JobId = u64;

/// Base of the job-request submission-id namespace: below the client
/// ticket bit (1<<63) and far above any trace id, so job request ids
/// never collide with either.
pub const JOB_SID_BASE: u64 = 1 << 48;

/// Nominal offline service rate (processed tokens/second per shard)
/// used for deadline-slack estimates when no measured rate is supplied.
/// The A100/7B simulator processes ~8k offline tokens per ~0.9 s
/// iteration in offline batching mode; co-serving with online traffic
/// roughly halves it.
pub const NOMINAL_TOK_PER_S: f64 = 5_000.0;

/// Resolution horizon of the urgency scale: one hour of laxity maps
/// near 0, zero laxity maps to `URGENCY_MAX`, with most of the scale's
/// resolution in the first minute (where ordering decisions matter).
const URGENCY_HORIZON_US: f64 = 60.0 * 1e6;

/// Least-laxity urgency: score by the absolute slack left *after* the
/// estimated remaining work — `laxity = deadline − now − est` — mapped
/// monotonically onto `0..=URGENCY_MAX` (`MAX·H/(H+laxity)` with a
/// 60 s horizon `H`). No deadline → 0; laxity ≤ 0 (late, or the work
/// no longer fits) → `URGENCY_MAX`; otherwise urgency rises as the
/// deadline nears or work piles up.
///
/// Laxity, not the `est/slack` ratio, is the right ordering key: a
/// mega-job with a proportionally-scaled deadline has the same ratio
/// as a tiny job with a near deadline, but far more absolute room —
/// serving the tiny job first barely delays the mega-job while the
/// reverse destroys the tiny job's deadline (the classic EDF/LLF
/// argument).
pub fn urgency_score(
    deadline: TimeUs,
    now: TimeUs,
    remaining_tokens: u64,
    svc_tok_per_s: f64,
) -> u32 {
    if deadline == 0 {
        return 0;
    }
    let est_us = remaining_tokens as f64 / svc_tok_per_s.max(1.0) * 1e6;
    let laxity_us = deadline.saturating_sub(now) as f64 - est_us;
    if laxity_us <= 0.0 {
        URGENCY_MAX
    } else {
        let u = URGENCY_MAX as f64 * URGENCY_HORIZON_US / (URGENCY_HORIZON_US + laxity_us);
        (u as u32).clamp(1, URGENCY_MAX - 1)
    }
}

/// Fair-share weight of a priority tier: tier 0 (premium) counts each
/// served token as a quarter, tier 1 as a half, everything else at par.
pub fn tier_weight(tier: u8) -> u32 {
    match tier {
        0 => 4,
        1 => 2,
        _ => 1,
    }
}

/// Immutable identity of an admitted job (what the durable store
/// persists alongside the request descriptors).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobSpec {
    pub job: JobId,
    pub tenant: u32,
    /// Priority tier (0 = highest; drives [`tier_weight`]).
    pub tier: u8,
    /// Soft deadline (µs timestamp; 0 = none).
    pub deadline: TimeUs,
    pub submitted_at: TimeUs,
    /// Requests in the job at admission.
    pub n_requests: u64,
    /// Σ (prompt + max output) over the job — the admission-time work
    /// estimate behind the urgency score.
    pub total_tokens: u64,
}

/// One request of a [`JobInput`] (prompt may be empty on the simulator
/// path — lengths drive everything there).
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub prompt: Vec<TokenId>,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
}

/// A job as submitted: tenant, tier, deadline, and its requests.
/// [`JobManager::admit`] turns it into stamped engine [`Request`]s.
#[derive(Debug, Clone)]
pub struct JobInput {
    pub tenant: u32,
    pub tier: u8,
    pub submitted_at: TimeUs,
    /// Soft deadline (µs timestamp; 0 = none).
    pub deadline: TimeUs,
    pub requests: Vec<JobRequest>,
}

// ---------------------------------------------------------------------
// Progress board
// ---------------------------------------------------------------------

/// Poll-able per-job progress: engines bump these cells once per
/// finished request (commit path), submitters and drivers read them
/// lock-free after a one-time map lookup. Handles hold their own `Arc`
/// to the cell, so the board may drop completed entries
/// ([`JobBoard::gc_completed`]) without invalidating anyone's polling.
#[derive(Debug)]
pub(crate) struct JobCell {
    total: AtomicU64,
    finished: AtomicU64,
    gen_tokens: AtomicU64,
    deadline: TimeUs,
    tenant: u32,
    /// 0 while in flight; completion timestamp (clamped ≥ 1) once the
    /// last request finished.
    completed_at: AtomicU64,
}

impl JobCell {
    pub(crate) fn snapshot(&self) -> JobProgress {
        let at = self.completed_at.load(Ordering::Relaxed);
        JobProgress {
            total: self.total.load(Ordering::Relaxed),
            finished: self.finished.load(Ordering::Relaxed),
            gen_tokens: self.gen_tokens.load(Ordering::Relaxed),
            deadline: self.deadline,
            tenant: self.tenant,
            completed_at: if at == 0 { None } else { Some(at) },
        }
    }
}

/// Snapshot of one job's progress (see [`JobBoard::progress`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobProgress {
    pub total: u64,
    pub finished: u64,
    pub gen_tokens: u64,
    pub deadline: TimeUs,
    pub tenant: u32,
    pub completed_at: Option<TimeUs>,
}

impl JobProgress {
    pub fn done(&self) -> bool {
        self.completed_at.is_some()
    }

    /// Deadline verdict: `None` while in flight or deadline-free.
    pub fn met_deadline(&self) -> Option<bool> {
        match (self.deadline, self.completed_at) {
            (0, _) => None,
            (_, None) => None,
            (d, Some(t)) => Some(t <= d),
        }
    }
}

/// Returned by [`JobBoard::note_finished`] when the noted request was
/// the job's last.
#[derive(Debug, Clone, Copy)]
pub struct JobCompletion {
    pub job: JobId,
    pub tenant: u32,
    pub deadline: TimeUs,
    pub completed_at: TimeUs,
    pub met: bool,
}

/// Shared job-progress board: one cell per registered job. Engines from
/// every shard notify the same board; all mutation after registration
/// is a couple of relaxed atomics behind one short map-lock hold, and
/// it runs once per *request completion*, never per token or iteration.
#[derive(Debug, Default)]
pub struct JobBoard {
    cells: Mutex<BTreeMap<JobId, Arc<JobCell>>>,
}

impl JobBoard {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or re-register, on resume) a job expecting `total`
    /// request completions. A memberless job (`total == 0`) is complete
    /// on arrival — nothing will ever notify it, and a handle polling
    /// `done()` must not spin forever.
    pub fn register(&self, job: JobId, total: u64, deadline: TimeUs, tenant: u32) {
        let cell = Arc::new(JobCell {
            total: AtomicU64::new(total),
            finished: AtomicU64::new(0),
            gen_tokens: AtomicU64::new(0),
            deadline,
            tenant,
            completed_at: AtomicU64::new(if total == 0 { 1 } else { 0 }),
        });
        self.cells.lock().unwrap().insert(job, cell);
    }

    /// Register a job mid-flight (durable-store resume): `total` is the
    /// job's full size and `finished`/`gen_tokens` pre-credit the
    /// requests whose outputs already landed before the restart, so the
    /// resumed job reports `finished/total` over its real size instead
    /// of claiming it only ever had the remainder.
    pub fn register_resumed(
        &self,
        job: JobId,
        total: u64,
        finished: u64,
        gen_tokens: u64,
        deadline: TimeUs,
        tenant: u32,
    ) {
        let cell = Arc::new(JobCell {
            total: AtomicU64::new(total),
            finished: AtomicU64::new(finished),
            gen_tokens: AtomicU64::new(gen_tokens),
            deadline,
            tenant,
            completed_at: AtomicU64::new(if finished >= total { 1 } else { 0 }),
        });
        self.cells.lock().unwrap().insert(job, cell);
    }

    pub(crate) fn cell(&self, job: JobId) -> Option<Arc<JobCell>> {
        self.cells.lock().unwrap().get(&job).cloned()
    }

    /// Drop the board entries of completed jobs, returning how many
    /// were collected. Safe at any time: handles poll through their own
    /// `Arc<JobCell>`, and engines only notify in-flight jobs (whose
    /// cells this never touches). Long-lived serving processes should
    /// call this periodically — the map otherwise grows by one entry
    /// per job forever.
    pub fn gc_completed(&self) -> usize {
        let mut cells = self.cells.lock().unwrap();
        let before = cells.len();
        cells.retain(|_, c| c.completed_at.load(Ordering::Relaxed) == 0);
        before - cells.len()
    }

    /// Drop one job's board entry regardless of state. A submitter
    /// that never wired the board to an engine (so the job can never
    /// complete), or that abandoned a batch, uses this to keep the
    /// board bounded. Held handles keep polling their own cell; late
    /// engine notifications for a retired job are no-ops.
    pub fn retire(&self, job: JobId) -> bool {
        self.cells.lock().unwrap().remove(&job).is_some()
    }

    /// Engine hook: one request of `job` finished at `now`, generating
    /// `gen_tokens` output tokens. Returns the completion record iff
    /// this was the job's last request (exactly once per job — each
    /// request finishes exactly once, so the counter crosses `total`
    /// exactly once).
    pub fn note_finished(
        &self,
        job: JobId,
        gen_tokens: u64,
        now: TimeUs,
    ) -> Option<JobCompletion> {
        let cell = self.cell(job)?;
        cell.gen_tokens.fetch_add(gen_tokens, Ordering::Relaxed);
        let done = cell.finished.fetch_add(1, Ordering::Relaxed) + 1;
        if done < cell.total.load(Ordering::Relaxed) {
            return None;
        }
        // compare-exchange makes completion idempotent even if a
        // misregistered total lets the counter pass `total` more than
        // once — exactly one notify wins the completion record
        let at = now.max(1);
        if cell
            .completed_at
            .compare_exchange(0, at, Ordering::Relaxed, Ordering::Relaxed)
            .is_err()
        {
            return None;
        }
        Some(JobCompletion {
            job,
            tenant: cell.tenant,
            deadline: cell.deadline,
            completed_at: at,
            met: cell.deadline == 0 || at <= cell.deadline,
        })
    }

    /// Snapshot one job still on the board.
    pub fn progress(&self, job: JobId) -> Option<JobProgress> {
        self.cell(job).map(|c| c.snapshot())
    }

    /// Snapshot every registered job (ascending job id).
    pub fn jobs(&self) -> Vec<(JobId, JobProgress)> {
        self.cells
            .lock()
            .unwrap()
            .iter()
            .map(|(&j, c)| (j, c.snapshot()))
            .collect()
    }
}

// ---------------------------------------------------------------------
// Job manager
// ---------------------------------------------------------------------

/// Admission front of the job subsystem: allocates job + submission
/// ids, computes urgency, stamps requests, registers board cells, and
/// rebuilds all of that from a [`ResumeState`] after a restart.
pub struct JobManager {
    next_job: JobId,
    next_sid: u64,
    svc_tok_per_s: f64,
    board: Arc<JobBoard>,
    specs: Vec<JobSpec>,
}

impl JobManager {
    pub fn new(svc_tok_per_s: f64) -> Self {
        Self {
            next_job: 1,
            next_sid: JOB_SID_BASE,
            svc_tok_per_s,
            board: Arc::new(JobBoard::new()),
            specs: Vec::new(),
        }
    }

    /// The shared progress board (hand clones to every engine via
    /// [`ServingEngine::set_job_board`](crate::server::ServingEngine::set_job_board)).
    pub fn board(&self) -> &Arc<JobBoard> {
        &self.board
    }

    /// Specs admitted so far (admission order).
    pub fn specs(&self) -> &[JobSpec] {
        &self.specs
    }

    /// Admit one job: appends its stamped offline [`Request`]s to `out`
    /// (arrival = `submitted_at`) and returns the spec. Urgency is the
    /// admission-time EDF score over the whole job's work.
    pub fn admit(&mut self, input: &JobInput, out: &mut Vec<Request>) -> JobSpec {
        let job = self.next_job;
        self.next_job += 1;
        let total_tokens: u64 = input
            .requests
            .iter()
            .map(|r| (r.prompt_len + r.max_new_tokens) as u64)
            .sum();
        let urgency = urgency_score(
            input.deadline,
            input.submitted_at,
            total_tokens,
            self.svc_tok_per_s,
        );
        let weight = tier_weight(input.tier);
        self.board
            .register(job, input.requests.len() as u64, input.deadline, input.tenant);
        for jr in &input.requests {
            let sid = self.next_sid;
            self.next_sid += 1;
            let mut r = Request::new(
                sid,
                Class::Offline,
                jr.prompt.clone(),
                jr.prompt_len,
                jr.max_new_tokens,
                input.submitted_at,
            );
            r.job = job;
            r.tenant = input.tenant;
            r.urgency = urgency;
            r.fair_weight = weight;
            r.deadline = input.deadline;
            out.push(r);
        }
        let spec = JobSpec {
            job,
            tenant: input.tenant,
            tier: input.tier,
            deadline: input.deadline,
            submitted_at: input.submitted_at,
            n_requests: input.requests.len() as u64,
            total_tokens,
        };
        self.specs.push(spec.clone());
        spec
    }

    /// Rebuild in-flight jobs from a durable-store [`ResumeState`]:
    /// every stored request without a recorded output is replayed —
    /// from its last checkpoint when one exists (outputs so far +
    /// sampler state travel; prefill recomputes), from its spec
    /// otherwise (recreated with the *same* submission id, so the
    /// derived sampler state — and therefore the token stream — is
    /// identical to the original run's). Returns the number of
    /// requests queued for replay.
    ///
    /// Deadlines are restored verbatim: they are absolute timestamps of
    /// the original run's clock, so a resumed run (clock restarts at 0)
    /// judges them *leniently* by the time already burned before the
    /// crash. Job-level attainment across a restart is therefore an
    /// upper bound; per-run reports stay exact.
    pub fn resume(&mut self, state: &ResumeState, out: &mut Vec<Request>) -> usize {
        let mut replayed = 0;
        for sj in &state.jobs {
            let spec = &sj.spec;
            self.next_job = self.next_job.max(spec.job + 1);
            let weight = tier_weight(spec.tier);
            // remaining work drives the *re*-computed urgency
            let mut pending: Vec<Request> = Vec::new();
            let mut remaining_tokens = 0u64;
            let mut done = 0u64;
            let mut done_tokens = 0u64;
            for sr in &sj.requests {
                self.next_sid = self.next_sid.max(sr.sid + 1);
                if let Some(fin) = state.outputs.get(&sr.sid) {
                    // already completed before the restart: pre-credit
                    done += 1;
                    done_tokens += fin.generated;
                    continue;
                }
                let r = match state.checkpoints.get(&sr.sid) {
                    Some(ckpt) => {
                        let mut r = ckpt.clone().into_request();
                        // the resumed run's clock restarts at 0: a
                        // stale original-run arrival would park the
                        // request in the trace source until the old
                        // timestamp passes (possibly beyond the new
                        // duration cap — it would never run at all)
                        r.arrival = 0;
                        r
                    }
                    None => {
                        let mut r = Request::new(
                            sr.sid,
                            Class::Offline,
                            sr.prompt.clone(),
                            sr.prompt_len,
                            sr.max_new_tokens,
                            0,
                        );
                        r.job = spec.job;
                        r.tenant = spec.tenant;
                        r.fair_weight = weight;
                        r.deadline = spec.deadline;
                        r
                    }
                };
                remaining_tokens += (r.prompt_len + r.max_new_tokens - r.generated) as u64;
                pending.push(r);
            }
            if pending.is_empty() {
                continue;
            }
            let urgency = urgency_score(spec.deadline, 0, remaining_tokens, self.svc_tok_per_s);
            // full job size, with pre-crash completions pre-credited —
            // progress reads `finished/total` over the real job
            self.board.register_resumed(
                spec.job,
                spec.n_requests,
                done,
                done_tokens,
                spec.deadline,
                spec.tenant,
            );
            for mut r in pending {
                r.urgency = urgency;
                out.push(r);
                replayed += 1;
            }
            self.specs.push(spec.clone());
        }
        replayed
    }
}

// ---------------------------------------------------------------------
// Sharded job-run driver
// ---------------------------------------------------------------------

/// Options for [`run_jobs`].
#[derive(Debug, Clone)]
pub struct JobRunOpts {
    pub n_shards: usize,
    pub placement: Placement,
    pub steal: Option<StealConfig>,
    pub duration_s: f64,
    /// Retain finished requests and collect per-shard state (finished
    /// outputs + cold snapshots of unfinished requests) for durable
    /// [`JobStore`] persistence. Off for pure benchmarking runs.
    pub collect_state: bool,
    /// Synthesize deterministic sim tokens (keyed by sampler state ×
    /// position) so collected outputs are byte-comparable across runs,
    /// restarts and migrations.
    pub synth_tokens: bool,
    /// Flush cold snapshots of in-progress job work to the attached
    /// [`JobStore`] every this many engine iterations (0 = end-of-run
    /// persistence only). Only meaningful with a store sink
    /// ([`run_jobs_with_store`]/[`run_jobs_with_recovery`]).
    pub ckpt_every: u64,
    /// Re-stamp queued-offline urgency on this virtual-time interval
    /// (µs; 0 = admission-time stamps only).
    pub restamp_every_us: u64,
    /// Service-rate estimate behind urgency (re-)computation.
    pub svc_tok_per_s: f64,
    /// Fleet flight recorder: each shard's engine attaches
    /// `tracer.shard(i)` before serving, so admission/scheduling/steal
    /// decisions land in the per-shard rings. Shared across recovery
    /// rounds ([`run_jobs_with_recovery`]) so one export covers the
    /// crash and the replay.
    pub tracer: Option<Arc<crate::trace::FleetTracer>>,
}

impl JobRunOpts {
    pub fn new(n_shards: usize, duration_s: f64) -> Self {
        Self {
            n_shards,
            placement: Placement::deadline(),
            steal: Some(StealConfig::default()),
            duration_s,
            collect_state: false,
            synth_tokens: false,
            ckpt_every: 0,
            restamp_every_us: 0,
            svc_tok_per_s: NOMINAL_TOK_PER_S,
            tracer: None,
        }
    }
}

/// A finished request's durable output record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FinishedOutput {
    pub sid: u64,
    pub job: JobId,
    pub generated: u64,
    pub output: Vec<TokenId>,
}

/// Post-run view of one job.
#[derive(Debug, Clone, Copy)]
pub struct JobResult {
    pub job: JobId,
    pub progress: JobProgress,
}

/// Everything [`run_jobs`] produces.
#[derive(Debug)]
pub struct JobRunOutcome {
    pub run: ShardedRun,
    /// One row per registered job (ascending id).
    pub jobs: Vec<JobResult>,
    /// Job-level deadline attainment: completed before the deadline /
    /// jobs carrying a deadline (unfinished or late = miss; 1.0 when no
    /// job carries one).
    pub job_attainment: f64,
    /// Finished request outputs (empty unless `collect_state`).
    pub finished: Vec<FinishedOutput>,
    /// Cold snapshots of requests still unfinished at run end (empty
    /// unless `collect_state`) — what a durable store checkpoints.
    /// Dead shards contribute nothing here: their in-memory state died
    /// with them, which is exactly what the periodic store flush
    /// bounds.
    pub unfinished: Vec<PortableRequest>,
    /// Structured shard deaths (empty on a healthy run). See
    /// [`crate::shard::supervisor`].
    pub deaths: Vec<ShardDied>,
    /// Submission ids of *online* requests routed to shards that died —
    /// fail-fast set for client retry. Conservative superset: routing
    /// is known, per-request completion on the dead shard is not (its
    /// recorder died with it), so ids that finished before the crash
    /// are included.
    pub failed_online: Vec<u64>,
}

/// Serve `events` (stamped job requests + any online background
/// traffic) on an `opts.n_shards`-worker simulated fleet: route under
/// `opts.placement` (urgency-aware), run with optional work stealing,
/// notify `board` as job requests finish, and reduce job-level
/// attainment. The engine-side urgency machinery (fair-share pick
/// order) is enabled by `cfg.sched.fair_share`, not here.
pub fn run_jobs(
    cfg: &EngineConfig,
    opts: &JobRunOpts,
    board: Arc<JobBoard>,
    events: Vec<Request>,
) -> JobRunOutcome {
    run_jobs_with_store(cfg, opts, board, events, None, None)
}

/// [`run_jobs`] with the full fault-tolerance surface: an optional
/// durable [`JobStore`] sink (periodic checkpoint flushes every
/// [`JobRunOpts::ckpt_every`] iterations) and an optional deterministic
/// [`FaultPlan`]. Runs on the *supervised* fleet
/// ([`run_sharded_traces_supervised`]): a shard death does not
/// propagate — it surfaces in [`JobRunOutcome::deaths`], with the
/// shard's online routing reported in [`JobRunOutcome::failed_online`]
/// for client retry. Use [`run_jobs_with_recovery`] to also rebuild the
/// dead shard's offline work from the store.
pub fn run_jobs_with_store(
    cfg: &EngineConfig,
    opts: &JobRunOpts,
    board: Arc<JobBoard>,
    events: Vec<Request>,
    sink: Option<Arc<Mutex<JobStore>>>,
    faults: Option<&FaultPlan>,
) -> JobRunOutcome {
    let mut router = ShardRouter::new(opts.n_shards, opts.placement, cfg);
    for r in events {
        router.push(r);
    }
    let traces = router.into_traces();
    // online routing per shard, captured before the run: if a shard
    // dies, these are the requests whose clients must fail fast/retry
    let online_by_shard: Vec<Vec<u64>> = traces
        .iter()
        .map(|t| {
            t.iter()
                .filter(|r| r.class == Class::Online)
                .map(|r| r.submitted_id)
                .collect()
        })
        .collect();
    let collect_state = opts.collect_state;
    let synth = opts.synth_tokens;
    let ckpt_every = opts.ckpt_every;
    let restamp_every_us = opts.restamp_every_us;
    let svc = opts.svc_tok_per_s;
    let plan = faults.cloned();
    let tracer = opts.tracer.clone();
    let setup_board = board.clone();
    let fleet = run_sharded_traces_supervised(
        cfg,
        traces,
        opts.duration_s,
        opts.steal,
        |e| {
            e.set_job_board(setup_board.clone());
            if let Some(t) = &tracer {
                e.set_tracer(t.shard(e.shard()));
            }
            if collect_state {
                e.set_retain_finished(true);
            }
            if synth {
                e.backend.set_synth_tokens(true);
            }
            if let Some(sink) = &sink {
                if ckpt_every > 0 {
                    e.set_ckpt_sink(sink.clone(), ckpt_every);
                }
            }
            if restamp_every_us > 0 {
                e.set_urgency_restamp(restamp_every_us, svc);
            }
            if let Some(p) = &plan {
                let shard = e.shard();
                e.set_fault_injector(p.injector_for(shard));
            }
        },
        |e| {
            let mut finished = Vec::new();
            let mut unfinished = Vec::new();
            if collect_state {
                // job-tagged requests only: online background traffic
                // is not durable-store material, and cloning its output
                // streams would be pure waste
                for r in e.table.values().filter(|r| r.job != 0) {
                    if r.state == State::Finished {
                        finished.push(FinishedOutput {
                            sid: r.submitted_id,
                            job: r.job,
                            generated: r.generated as u64,
                            output: r.output.clone(),
                        });
                    } else if r.state != State::Aborted {
                        unfinished.push(PortableRequest::snapshot_cold(r));
                    }
                }
            }
            (finished, unfinished)
        },
    );
    let deaths = fleet.deaths;
    let mut failed_online = Vec::new();
    for d in &deaths {
        failed_online.extend(online_by_shard.get(d.shard).into_iter().flatten().copied());
    }
    let mut finished = Vec::new();
    let mut unfinished = Vec::new();
    for (f, u) in fleet.extras.into_iter().flatten() {
        finished.extend(f);
        unfinished.extend(u);
    }
    let jobs: Vec<JobResult> = board
        .jobs()
        .into_iter()
        .map(|(job, progress)| JobResult { job, progress })
        .collect();
    let with_deadline = jobs.iter().filter(|j| j.progress.deadline > 0).count();
    let met = jobs
        .iter()
        .filter(|j| j.progress.met_deadline() == Some(true))
        .count();
    let job_attainment = if with_deadline == 0 {
        1.0
    } else {
        met as f64 / with_deadline as f64
    };
    JobRunOutcome {
        run: fleet.run,
        jobs,
        job_attainment,
        finished,
        unfinished,
        deaths,
        failed_online,
    }
}

/// Everything [`run_jobs_with_recovery`] produces: the faulted first
/// round, the recovery round on the surviving shard count (if any
/// shard died), and how much work recovery replayed.
#[derive(Debug)]
pub struct RecoveryOutcome {
    pub first: JobRunOutcome,
    /// `Some` iff the first round lost a shard.
    pub recovery: Option<JobRunOutcome>,
    /// Requests the recovery round replayed (from checkpoints or
    /// specs).
    pub resumed_requests: usize,
    /// Garbled checkpoint lines skipped while loading the store for
    /// recovery (torn writes).
    pub torn_checkpoint_lines: usize,
}

/// Crash-recovery driver: one supervised, checkpointing run, then — if
/// any shard died — a recovery round on the survivors.
///
/// Round 1 serves `events` with `store` attached as the periodic
/// checkpoint sink (so a crash loses at most [`JobRunOpts::ckpt_every`]
/// iterations of progress) and persists the surviving shards'
/// end-of-run state. If every shard survived, that is the whole story.
/// Otherwise the store — specs, periodic checkpoints, outputs — is
/// reloaded, a fresh [`JobManager`] [`resume`](JobManager::resume)s
/// every un-output request (same submission ids ⇒ same keyed sampler
/// states ⇒ byte-identical streams), and a recovery fleet of
/// `n_shards − deaths` survivors re-serves them under **degraded
/// offline budgets** (three-quarter batch-token cap: online admits
/// first under the paper's scheduler, so shrinking the cap sheds
/// offline throughput, not online latency). Online requests are *not*
/// replayed — they failed fast in
/// [`JobRunOutcome::failed_online`] and retry client-side.
pub fn run_jobs_with_recovery(
    cfg: &EngineConfig,
    opts: &JobRunOpts,
    board: Arc<JobBoard>,
    events: Vec<Request>,
    store: Arc<Mutex<JobStore>>,
    faults: Option<&FaultPlan>,
) -> anyhow::Result<RecoveryOutcome> {
    let first = run_jobs_with_store(cfg, opts, board, events, Some(store.clone()), faults);
    persist_outcome(&store, &first)?;
    if first.deaths.is_empty() {
        return Ok(RecoveryOutcome {
            first,
            recovery: None,
            resumed_requests: 0,
            torn_checkpoint_lines: 0,
        });
    }
    let dir = store.lock().unwrap().dir().to_path_buf();
    let state = JobStore::load(&dir)?;
    let torn_checkpoint_lines = state.torn_checkpoint_lines;
    let mut jm = JobManager::new(opts.svc_tok_per_s);
    let mut replay = Vec::new();
    let resumed_requests = jm.resume(&state, &mut replay);
    let survivors = opts.n_shards.saturating_sub(first.deaths.len()).max(1);
    if let Some(t) = &opts.tracer {
        // mark the crash→replay seam in the shared flight record: one
        // Recover event per death (a = dead shard, b = replayed work),
        // stamped on the survivor fleet's first shard at its epoch
        for d in &first.deaths {
            t.shard(0).emit(
                0,
                crate::trace::EventKind::Recover,
                0,
                d.shard as u64,
                resumed_requests as u64,
            );
        }
    }
    // graceful degradation: the survivor fleet sheds offline first
    let mut rcfg = cfg.clone();
    rcfg.sched.max_batch_tokens = (rcfg.sched.max_batch_tokens * 3 / 4).max(1);
    let ropts = JobRunOpts {
        n_shards: survivors,
        ..opts.clone()
    };
    let recovery = run_jobs_with_store(
        &rcfg,
        &ropts,
        jm.board().clone(),
        replay,
        Some(store.clone()),
        None,
    );
    persist_outcome(&store, &recovery)?;
    Ok(RecoveryOutcome {
        first,
        recovery: Some(recovery),
        resumed_requests,
        torn_checkpoint_lines,
    })
}

/// Persist a run's end state: durable outputs for everything finished,
/// a final cold checkpoint for everything not. Duplicates against the
/// periodic flushes are harmless — last line per sid wins on load.
fn persist_outcome(store: &Arc<Mutex<JobStore>>, out: &JobRunOutcome) -> anyhow::Result<()> {
    let mut s = store.lock().unwrap();
    for f in &out.finished {
        s.record_output(f)?;
    }
    for p in &out.unfinished {
        s.record_checkpoint(p)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(tenant: u32, tier: u8, at: TimeUs, deadline: TimeUs, n: usize) -> JobInput {
        JobInput {
            tenant,
            tier,
            submitted_at: at,
            deadline,
            requests: (0..n)
                .map(|_| JobRequest {
                    prompt: Vec::new(),
                    prompt_len: 256,
                    max_new_tokens: 32,
                })
                .collect(),
        }
    }

    #[test]
    fn urgency_tracks_laxity() {
        // no deadline: never urgent
        assert_eq!(urgency_score(0, 0, 1_000_000, 5000.0), 0);
        // 10k tokens at 5k tok/s = 2 s of work; deadline 20 s out =>
        // 18 s of laxity => 1000 * 60 / 78
        let est_2s_work = 10_000;
        assert_eq!(urgency_score(20_000_000, 0, est_2s_work, 5000.0), 769);
        // laxity shrinks as `now` advances: urgency grows monotonically
        let u1 = urgency_score(20_000_000, 10_000_000, est_2s_work, 5000.0);
        let u2 = urgency_score(20_000_000, 17_000_000, est_2s_work, 5000.0);
        assert!(769 < u1 && u1 < u2, "{u1} < {u2}");
        // est >= slack, or already late: pegged at max
        assert_eq!(urgency_score(20_000_000, 18_500_000, est_2s_work, 5000.0), URGENCY_MAX);
        assert_eq!(urgency_score(1_000, 2_000, 1, 5000.0), URGENCY_MAX);
        // the LLF property: a tiny near-deadline job outranks a huge
        // job whose deadline is proportionally as far (same est/slack
        // ratio, much more absolute room)
        let tiny = urgency_score(5_000_000, 0, 5_000, 5000.0); // 1s work, 5s deadline
        let huge = urgency_score(500_000_000, 0, 500_000, 5000.0); // 100s work, 500s deadline
        assert!(tiny > huge, "laxity orders correctly: {tiny} vs {huge}");
    }

    #[test]
    fn admit_stamps_requests_and_registers_board() {
        let mut jm = JobManager::new(5000.0);
        let mut out = Vec::new();
        let spec = jm.admit(&input(7, 0, 1_000, 50_000_000, 3), &mut out);
        assert_eq!(spec.job, 1);
        assert_eq!(spec.n_requests, 3);
        assert_eq!(spec.total_tokens, 3 * 288);
        assert_eq!(out.len(), 3);
        for r in &out {
            assert_eq!(r.job, 1);
            assert_eq!(r.tenant, 7);
            assert_eq!(r.fair_weight, 4, "tier 0 weighs 4x");
            assert_eq!(r.deadline, 50_000_000);
            assert_eq!(r.arrival, 1_000);
            assert!(r.urgency > 0);
            assert!(r.submitted_id >= JOB_SID_BASE);
        }
        // distinct sids, distinct sampler states
        assert_ne!(out[0].submitted_id, out[1].submitted_id);
        assert_ne!(out[0].sampler_state, out[1].sampler_state);
        let p = jm.board().progress(1).unwrap();
        assert_eq!(p.total, 3);
        assert_eq!(p.finished, 0);
        assert!(!p.done());
        assert_eq!(p.met_deadline(), None);
    }

    #[test]
    fn board_reports_completion_exactly_once() {
        let board = JobBoard::new();
        board.register(9, 2, 1_000_000, 3);
        assert!(board.note_finished(9, 12, 400_000).is_none());
        let done = board
            .note_finished(9, 8, 900_000)
            .expect("last request completes");
        assert!(done.met);
        assert_eq!(done.tenant, 3);
        let p = board.progress(9).unwrap();
        assert_eq!(p.finished, 2);
        assert_eq!(p.gen_tokens, 20, "token credit accumulates");
        assert_eq!(p.met_deadline(), Some(true));
        // deadline-free jobs are never late
        board.register(10, 1, 1_000, 0);
        let d = board.note_finished(10, 1, 5_000).unwrap();
        assert!(d.met, "deadline-free jobs are never late");
        board.register(11, 1, 1_000, 0);
        assert!(board.note_finished(99, 1, 0).is_none(), "unknown job ignored");
        // a memberless job is complete on arrival (nothing will ever
        // notify it; a polling handle must not spin forever)
        board.register(12, 0, 5_000, 1);
        let p = board.progress(12).unwrap();
        assert!(p.done());
        assert_eq!(p.met_deadline(), Some(true));
        // gc drops completed entries (9, 10, 12) and keeps in-flight 11
        assert_eq!(board.gc_completed(), 3);
        assert!(board.progress(9).is_none());
        assert!(board.progress(11).is_some());
        assert_eq!(board.gc_completed(), 0, "idempotent");
        // retire drops an entry regardless of state; later notifies no-op
        assert!(board.retire(11));
        assert!(!board.retire(11));
        assert!(board.note_finished(11, 1, 99).is_none());
    }

    #[test]
    fn sharded_job_run_completes_and_reports_attainment() {
        let cfg = EngineConfig::sim_a100_7b();
        let mut jm = JobManager::new(NOMINAL_TOK_PER_S);
        let mut events = Vec::new();
        // a generous deadline (met) and an impossible one (missed)
        jm.admit(&input(1, 1, 0, 600_000_000, 4), &mut events);
        jm.admit(&input(2, 2, 0, 1_000, 4), &mut events);
        let opts = JobRunOpts {
            steal: None,
            ..JobRunOpts::new(2, 600.0)
        };
        let out = run_jobs(&cfg, &opts, jm.board().clone(), events);
        assert_eq!(out.jobs.len(), 2);
        assert!(out.jobs.iter().all(|j| j.progress.done()));
        assert_eq!(out.run.merged.offline_finished, 8);
        assert_eq!(out.run.merged.jobs_completed, 2);
        assert!((out.job_attainment - 0.5).abs() < 1e-9, "{}", out.job_attainment);
        // request-level counters land in the merged report too
        assert_eq!(
            out.run.merged.deadline_met + out.run.merged.deadline_missed,
            8
        );
        let tenants = &out.run.merged.per_tenant;
        assert_eq!(tenants.len(), 2);
        assert!(tenants.iter().all(|t| t.finished == 4));
    }

    #[test]
    fn collect_state_partitions_finished_and_unfinished() {
        let cfg = EngineConfig::sim_a100_7b();
        let mut jm = JobManager::new(NOMINAL_TOK_PER_S);
        let mut events = Vec::new();
        // two quick requests (finish within the cap) + four slow ones
        // (still mid-generation when the cap hits)
        let mut job = input(1, 2, 0, 0, 0);
        for _ in 0..2 {
            job.requests.push(JobRequest {
                prompt: Vec::new(),
                prompt_len: 256,
                max_new_tokens: 4,
            });
        }
        for _ in 0..4 {
            job.requests.push(JobRequest {
                prompt: Vec::new(),
                prompt_len: 3000,
                max_new_tokens: 256,
            });
        }
        jm.admit(&job, &mut events);
        let opts = JobRunOpts {
            steal: None,
            collect_state: true,
            synth_tokens: true,
            // a tight time cap leaves the slow requests unfinished
            ..JobRunOpts::new(1, 1.5)
        };
        let out = run_jobs(&cfg, &opts, jm.board().clone(), events);
        assert_eq!(
            out.finished.len() + out.unfinished.len(),
            6,
            "every request is either finished or snapshotted"
        );
        assert!(!out.finished.is_empty(), "quick requests finish");
        assert!(!out.unfinished.is_empty(), "slow requests get snapshotted");
        for f in &out.finished {
            assert_eq!(f.generated, 4);
            assert_eq!(f.output.len(), 4, "synth tokens materialize outputs");
        }
        for p in &out.unfinished {
            assert_eq!(p.ckpt_tokens, 0, "store snapshots are cold");
            assert_eq!(p.job, 1);
        }
    }
}
