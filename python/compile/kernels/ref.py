"""Pure-jnp correctness oracles for the Pallas kernels and the L2 model.

Everything here is the "obviously correct" dense implementation; the
Pallas kernels and the layered model are validated against these by
pytest (python/tests/). Nothing in this file is exported to artifacts.
"""

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis: x / rms(x) * w."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(ms + eps) * w).astype(x.dtype)


def attention_ref(
    q: jax.Array,        # [B, H, T, Dh] (RoPE already applied)
    k_cache: jax.Array,  # [B, Hkv, S, Dh] (new tokens already written)
    v_cache: jax.Array,  # [B, Hkv, S, Dh]
    ctx_lens: jax.Array, # [B] i32, context length BEFORE this chunk
) -> jax.Array:
    """Dense causal attention over a per-sequence KV cache.

    Query t of sequence b sits at absolute position ctx_lens[b] + t and may
    attend to cache slots s <= that position. GQA: query head h reads KV
    head h * Hkv // H.
    """
    B, H, T, Dh = q.shape
    _, Hkv, S, _ = k_cache.shape
    group = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))

    k = jnp.repeat(k_cache, group, axis=1)  # [B, H, S, Dh]
    v = jnp.repeat(v_cache, group, axis=1)

    scores = jnp.einsum("bhtd,bhsd->bhts", q, k).astype(jnp.float32) * scale
    qpos = ctx_lens[:, None] + jnp.arange(T)[None, :]          # [B, T]
    kpos = jnp.arange(S)[None, None, :]                        # [1, 1, S]
    mask = kpos <= qpos[:, :, None]                            # [B, T, S]
    scores = jnp.where(mask[:, None, :, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", probs, v).astype(q.dtype)


def rope_ref(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding, Llama half-split convention.

    x: [B, T, H, Dh]; positions: [B, T] absolute token positions.
    """
    Dh = x.shape[-1]
    half = Dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, T, half]
    cos = jnp.cos(angles)[:, :, None, :]                       # [B, T, 1, half]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def update_cache_ref(cache: jax.Array, new: jax.Array, ctx_lens: jax.Array) -> jax.Array:
    """Write `new` [B, Hkv, T, Dh] into `cache` [B, Hkv, S, Dh] at per-row
    offsets ctx_lens [B]."""

    def row(c, n, off):
        return jax.lax.dynamic_update_slice(c, n, (0, off, 0))

    return jax.vmap(row)(cache, new, ctx_lens)


def swiglu_ref(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def layer_ref(cfg, hidden, k_cache, v_cache, ctx_lens, w):
    """Reference transformer layer matching model.layer_fwd semantics.

    w: dict with attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down.
    Returns (hidden, k_cache, v_cache).
    """
    B, T, D = hidden.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = ctx_lens[:, None] + jnp.arange(T)[None, :]

    x = rmsnorm_ref(hidden, w["attn_norm"], cfg.norm_eps)
    q = (x @ w["wq"]).reshape(B, T, H, Dh)
    k = (x @ w["wk"]).reshape(B, T, Hkv, Dh)
    v = (x @ w["wv"]).reshape(B, T, Hkv, Dh)
    q = rope_ref(q, positions, cfg.rope_theta)
    k = rope_ref(k, positions, cfg.rope_theta)

    k_cache = update_cache_ref(k_cache, k.transpose(0, 2, 1, 3), ctx_lens)
    v_cache = update_cache_ref(v_cache, v.transpose(0, 2, 1, 3), ctx_lens)

    attn = attention_ref(q.transpose(0, 2, 1, 3), k_cache, v_cache, ctx_lens)
    attn = attn.transpose(0, 2, 1, 3).reshape(B, T, H * Dh)
    hidden = hidden + attn @ w["wo"]

    y = rmsnorm_ref(hidden, w["mlp_norm"], cfg.norm_eps)
    hidden = hidden + swiglu_ref(y, w["w_gate"], w["w_up"], w["w_down"])
    return hidden, k_cache, v_cache


def model_ref(cfg, params, tokens, k_caches, v_caches, ctx_lens):
    """Reference full model: embed -> layers -> head.

    params: flat dict name -> array (configs.param_specs naming).
    k_caches/v_caches: [L, B, Hkv, S, Dh]. Returns (logits, k_caches, v_caches).
    """
    hidden = params["embedding"][tokens]
    new_k, new_v = [], []
    for l in range(cfg.n_layers):
        w = {n: params[f"layers.{l}.{n}"] for n in (
            "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down")}
        hidden, kc, vc = layer_ref(cfg, hidden, k_caches[l], v_caches[l], ctx_lens, w)
        new_k.append(kc)
        new_v.append(vc)
    hidden = rmsnorm_ref(hidden, params["final_norm"], cfg.norm_eps)
    logits = hidden @ params["lm_head"]
    return logits, jnp.stack(new_k), jnp.stack(new_v)
