//! Bandwidth-metered asynchronous swap I/O (paper §4.4, Fig. 4).
//!
//! Models the PCIe link between GPU HBM and host DRAM as two independent
//! FIFO channels (D2H for checkpointing, H2D for prefetching — PCIe is
//! full duplex). Each enqueued op completes at
//! `max(now, channel_busy_until) + bytes / bandwidth`; `tick(now)`
//! returns ops whose completion time has passed. The engine calls `tick`
//! at every safepoint and iteration boundary, which is exactly how the
//! paper's dedicated-CUDA-stream copies surface: asynchronously,
//! overlapped with compute, observed at synchronization points.
//!
//! The same structure serves both backends: the simulator advances a
//! virtual clock past completion times; the real backend performs the
//! actual memcpy when the op is *enqueued* (host<->host, data is safe
//! immediately) while the *accounting* completes on PCIe-modelled time so
//! scheduling behaviour matches the modelled hardware.

use crate::request::RequestId;
use crate::TimeUs;
use std::collections::VecDeque;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Device -> host: incremental checkpoint.
    D2H,
    /// Host -> device: prefetch / swap-in.
    H2D,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SwapOp {
    pub req: RequestId,
    /// Logical block index within the sequence.
    pub block_idx: usize,
    pub dir: Direction,
    pub enqueued: TimeUs,
    pub completes: TimeUs,
}

#[derive(Debug)]
struct Channel {
    busy_until: TimeUs,
    inflight: VecDeque<SwapOp>,
}

/// The swap engine. `bytes_per_block` and `bandwidth` (bytes/s) come from
/// the backend's cost model (A100: 8 MB blocks over 32 GB/s PCIe 4.0x16
/// => 250 µs/block; tiny real model: 64 KB blocks).
#[derive(Debug)]
pub struct SwapEngine {
    pub bytes_per_block: u64,
    pub bandwidth_bytes_per_sec: u64,
    d2h: Channel,
    h2d: Channel,
}

impl SwapEngine {
    pub fn new(bytes_per_block: u64, bandwidth_bytes_per_sec: u64) -> Self {
        let ch = || Channel {
            busy_until: 0,
            inflight: VecDeque::new(),
        };
        Self {
            bytes_per_block,
            bandwidth_bytes_per_sec,
            d2h: ch(),
            h2d: ch(),
        }
    }

    pub fn block_transfer_us(&self) -> u64 {
        (self.bytes_per_block * 1_000_000 / self.bandwidth_bytes_per_sec).max(1)
    }

    fn channel(&mut self, dir: Direction) -> &mut Channel {
        match dir {
            Direction::D2H => &mut self.d2h,
            Direction::H2D => &mut self.h2d,
        }
    }

    /// Enqueue a one-block transfer; returns its completion time.
    pub fn enqueue(
        &mut self,
        now: TimeUs,
        req: RequestId,
        block_idx: usize,
        dir: Direction,
    ) -> TimeUs {
        let dur = self.block_transfer_us();
        let ch = self.channel(dir);
        let start = ch.busy_until.max(now);
        let completes = start + dur;
        ch.busy_until = completes;
        ch.inflight.push_back(SwapOp {
            req,
            block_idx,
            dir,
            enqueued: now,
            completes,
        });
        completes
    }

    /// Pop all ops completed by `now` (FIFO per channel).
    pub fn tick(&mut self, now: TimeUs) -> Vec<SwapOp> {
        let mut done = Vec::new();
        self.tick_into(now, &mut done);
        done
    }

    /// Allocation-free variant of [`tick`](Self::tick): clears and refills
    /// `done` (the engine reuses one buffer across iterations).
    pub fn tick_into(&mut self, now: TimeUs, done: &mut Vec<SwapOp>) {
        done.clear();
        for ch in [&mut self.d2h, &mut self.h2d] {
            while ch
                .inflight
                .front()
                .is_some_and(|op| op.completes <= now)
            {
                done.push(ch.inflight.pop_front().unwrap());
            }
        }
    }

    /// True when no transfer is in flight on either channel (fast path
    /// for the engine's per-iteration I/O poll).
    pub fn is_idle(&self) -> bool {
        self.d2h.inflight.is_empty() && self.h2d.inflight.is_empty()
    }

    /// Duration of a *blocking* multi-block transfer (the vLLM swap-out
    /// path ConServe's incremental checkpointing replaces, Fig. 4b).
    pub fn blocking_transfer_us(&mut self, now: TimeUs, dir: Direction, blocks: usize) -> u64 {
        let dur = self.block_transfer_us() * blocks as u64;
        // blocking transfer still occupies the channel
        let ch = self.channel(dir);
        let start = ch.busy_until.max(now);
        ch.busy_until = start + dur;
        (start + dur).saturating_sub(now)
    }

    /// Inflight ops for a request+direction (used to avoid double-issuing
    /// prefetches).
    pub fn inflight_for(&self, req: RequestId, dir: Direction) -> usize {
        let ch = match dir {
            Direction::D2H => &self.d2h,
            Direction::H2D => &self.h2d,
        };
        ch.inflight.iter().filter(|op| op.req == req).count()
    }

    /// When will the channel drain (for SLO-aware I/O budgeting, §4.5).
    pub fn busy_until(&self, dir: Direction) -> TimeUs {
        match dir {
            Direction::D2H => self.d2h.busy_until,
            Direction::H2D => self.h2d.busy_until,
        }
    }

    /// Earliest pending completion across both channels (idle-advance
    /// target for the discrete-event loop).
    pub fn next_completion(&self) -> Option<TimeUs> {
        let a = self.d2h.inflight.front().map(|op| op.completes);
        let b = self.h2d.inflight.front().map(|op| op.completes);
        match (a, b) {
            (Some(x), Some(y)) => Some(x.min(y)),
            (x, y) => x.or(y),
        }
    }

    /// Cancel all in-flight ops for a request; returns how many were
    /// dropped (in-place retain — no allocation).
    pub fn drop_request(&mut self, req: RequestId) -> usize {
        let mut dropped = 0;
        for ch in [&mut self.d2h, &mut self.h2d] {
            let before = ch.inflight.len();
            ch.inflight.retain(|op| op.req != req);
            dropped += before - ch.inflight.len();
        }
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eng() -> SwapEngine {
        // 8 MB blocks over 32 GB/s => 250 µs/block (A100 calibration)
        SwapEngine::new(8 << 20, 32 << 30)
    }

    #[test]
    fn block_time_matches_calibration() {
        let e = eng();
        assert_eq!(e.block_transfer_us(), 244); // 8 MiB / 32 GiB/s = 244 µs
    }

    #[test]
    fn fifo_serialization_per_channel() {
        let mut e = eng();
        let t1 = e.enqueue(0, 1, 0, Direction::D2H);
        let t2 = e.enqueue(0, 1, 1, Direction::D2H);
        assert_eq!(t2, 2 * t1); // queued behind the first
        // H2D is an independent channel (full duplex)
        let t3 = e.enqueue(0, 2, 0, Direction::H2D);
        assert_eq!(t3, t1);
    }

    #[test]
    fn tick_completes_in_order() {
        let mut e = eng();
        e.enqueue(0, 1, 0, Direction::D2H);
        e.enqueue(0, 1, 1, Direction::D2H);
        assert!(e.tick(100).is_empty());
        let done = e.tick(244);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].block_idx, 0);
        let done = e.tick(10_000);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].block_idx, 1);
    }

    #[test]
    fn idle_channel_starts_at_now() {
        let mut e = eng();
        let t = e.enqueue(1_000_000, 1, 0, Direction::H2D);
        assert_eq!(t, 1_000_244);
    }

    #[test]
    fn blocking_transfer_accounts_queue() {
        let mut e = eng();
        e.enqueue(0, 1, 0, Direction::D2H); // busy until 244
        let wait = e.blocking_transfer_us(0, Direction::D2H, 4);
        assert_eq!(wait, 244 + 4 * 244);
    }

    #[test]
    fn drop_request_clears_inflight() {
        let mut e = eng();
        e.enqueue(0, 1, 0, Direction::D2H);
        e.enqueue(0, 2, 0, Direction::D2H);
        assert_eq!(e.inflight_for(1, Direction::D2H), 1);
        let dropped = e.drop_request(1);
        assert_eq!(dropped, 1);
        assert_eq!(e.inflight_for(1, Direction::D2H), 0);
        assert_eq!(e.inflight_for(2, Direction::D2H), 1);
    }
}
