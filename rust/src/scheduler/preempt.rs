//! Running-batch preemption decision (paper Algorithm 2).
//!
//! When an online request arrives while a *pure offline* batch is
//! executing, the arrival handler estimates whether waiting for the batch
//! to finish would blow the newcomer's TTFT objective; if so, the worker
//! is signalled (safepoint flag) and aborts at the next layer-group
//! boundary. The estimates come from the offline profiler (§4.5).

use crate::backend::PlanSummary;
use crate::profiler::LatencyProfile;
use crate::TimeUs;

/// Inputs to the Alg.-2 decision, gathered at a safepoint.
#[derive(Debug, Clone, Copy)]
pub struct PreemptQuery {
    pub now: TimeUs,
    /// Earliest waiting online request's arrival time.
    pub oldest_online_arrival: TimeUs,
    /// When the running batch was scheduled.
    pub batch_sched_at: TimeUs,
    /// Profile estimate for the full running batch.
    pub batch_est_us: u64,
    /// Shape of the waiting online work (its prefill).
    pub online_shape: PlanSummary,
    pub ttft_slo_us: u64,
}

/// Fraction of the TTFT objective the projection may consume before the
/// worker is signalled. Algorithm 2 compares against t_TTFT directly; a
/// headroom keeps the *P99* under the SLO — the projection is a mean-path
/// estimate and queueing behind the aborted batch (scheduling, eviction,
/// recompute of the online queue) is not in it.
pub const PREEMPT_HEADROOM: f64 = 0.5;

/// Algorithm 2 lines 7-10: preempt iff the remaining batch time plus the
/// online work's own execution time would exceed the TTFT objective
/// (scaled by [`PREEMPT_HEADROOM`]) measured from the online request's
/// arrival.
pub fn should_preempt(profile: &LatencyProfile, q: &PreemptQuery) -> bool {
    let elapsed = q.now.saturating_sub(q.batch_sched_at);
    let t_remain = q.batch_est_us.saturating_sub(elapsed);
    let t_exec = profile.estimate_us(&q.online_shape);
    let waited = q.now.saturating_sub(q.oldest_online_arrival);
    (waited + t_remain + t_exec) as f64 > q.ttft_slo_us as f64 * PREEMPT_HEADROOM
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> LatencyProfile {
        LatencyProfile {
            c: [1200.0, 96.0, 40.0, 0.385],
        }
    }

    fn query() -> PreemptQuery {
        PreemptQuery {
            now: 1_000_000,
            oldest_online_arrival: 990_000,
            batch_sched_at: 900_000,
            batch_est_us: 800_000, // long offline batch
            online_shape: PlanSummary {
                prefill_tokens: 1024,
                decode_seqs: 0,
                ctx_tokens: 0,
                n_seqs: 1,
            },
            ttft_slo_us: 1_500_000,
        }
    }

    #[test]
    fn long_batch_triggers_preemption() {
        // 400ms remain + ~100ms online exec + 10ms waited, under the
        // 750ms headroomed objective: no preemption.
        let mut q = query();
        q.batch_est_us = 500_000;
        assert!(!should_preempt(&profile(), &q));
        // but a 2s batch must be preempted
        q.batch_est_us = 2_000_000;
        assert!(should_preempt(&profile(), &q));
    }

    #[test]
    fn nearly_finished_batch_is_left_alone() {
        let mut q = query();
        q.batch_est_us = 2_000_000;
        q.batch_sched_at = 0;
        q.now = 1_990_000; // batch ~done
        q.oldest_online_arrival = 1_980_000;
        assert!(!should_preempt(&profile(), &q));
    }

    #[test]
    fn long_waited_request_forces_preemption() {
        let mut q = query();
        // modest remaining batch but the request already waited 1.45s
        q.now = 2_000_000;
        q.batch_sched_at = 1_900_000;
        q.oldest_online_arrival = q.now - 1_450_000;
        assert!(should_preempt(&profile(), &q));
    }

    #[test]
    fn tight_slo_is_stricter() {
        let mut q = query();
        q.ttft_slo_us = 200_000;
        assert!(should_preempt(&profile(), &q));
    }
}
