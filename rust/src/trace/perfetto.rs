//! Chrome/Perfetto trace-event JSON export for [`super::FleetTracer`]
//! rings, plus the `conserve trace` summarizer that reads an exported
//! file back.
//!
//! The export is the classic trace-event *JSON array* format (loadable
//! by Perfetto UI and `chrome://tracing`): one thread track per shard
//! (plus a `front-door` track under serve), `"X"` complete events for
//! engine iterations (duration = measured latency, estimated latency in
//! `args`), `"i"` instants for point events, `"C"` counters for harvest
//! budget moves, and `"s"`/`"f"` flow arrows keyed by submission id so
//! a request can be followed across a steal migration.
//!
//! Output is deterministic: events sort by (timestamp, track, emission
//! order) and `util::json` renders objects in key order, so two
//! lockstep sim runs export byte-identical files.

use anyhow::{bail, Context, Result};

use super::{EventKind, FleetTracer, TraceEvent};
use crate::util::json::{num, obj, Json};

/// Render the fleet's surviving events as a trace-event JSON array
/// (one event object per line for diff-ability).
pub fn export_perfetto(fleet: &FleetTracer) -> String {
    let mut lines: Vec<String> = Vec::new();
    lines.push(
        obj(vec![
            ("args", obj(vec![("name", Json::Str("conserve".into()))])),
            ("name", Json::Str("process_name".into())),
            ("ph", Json::Str("M".into())),
            ("pid", num(1.0)),
            ("tid", num(0.0)),
        ])
        .to_string(),
    );
    for track in 0..fleet.n_tracks() {
        lines.push(
            obj(vec![
                ("args", obj(vec![("name", Json::Str(fleet.track_name(track)))])),
                ("name", Json::Str("thread_name".into())),
                ("ph", Json::Str("M".into())),
                ("pid", num(1.0)),
                ("tid", num(track as f64 + 1.0)),
            ])
            .to_string(),
        );
    }
    for e in fleet.merged() {
        lines.push(event_json(&e).to_string());
    }
    let mut out = String::with_capacity(lines.iter().map(|l| l.len() + 4).sum::<usize>() + 4);
    out.push_str("[\n");
    for (i, l) in lines.iter().enumerate() {
        out.push_str(l);
        out.push_str(if i + 1 == lines.len() { "\n" } else { ",\n" });
    }
    out.push_str("]\n");
    out
}

fn event_json(e: &TraceEvent) -> Json {
    let tid = num(e.shard as f64 + 1.0);
    let ts = num(e.t_us as f64);
    match e.kind {
        EventKind::Iteration => {
            let prefill = e.a >> 32;
            let decode = e.a & 0xffff_ffff;
            let est = e.b >> 32;
            let actual = e.b & 0xffff_ffff;
            obj(vec![
                (
                    "args",
                    obj(vec![
                        ("actual_us", num(actual as f64)),
                        ("decode_seqs", num(decode as f64)),
                        ("est_us", num(est as f64)),
                        ("prefill_tokens", num(prefill as f64)),
                    ]),
                ),
                ("cat", Json::Str("engine".into())),
                ("dur", num(actual as f64)),
                ("name", Json::Str("iter".into())),
                ("ph", Json::Str("X".into())),
                ("pid", num(1.0)),
                ("tid", tid),
                ("ts", num(e.t_us.saturating_sub(actual) as f64)),
            ])
        }
        EventKind::HarvestTighten | EventKind::HarvestOpen => obj(vec![
            (
                "args",
                obj(vec![
                    ("audit_id", num(e.a as f64)),
                    ("permille", num(e.b as f64)),
                ]),
            ),
            ("cat", Json::Str("harvest".into())),
            ("name", Json::Str("harvest_budget_permille".into())),
            ("ph", Json::Str("C".into())),
            ("pid", num(1.0)),
            ("tid", tid),
            ("ts", ts),
        ]),
        EventKind::StealDonate | EventKind::StealAbsorb => {
            let start = e.kind == EventKind::StealDonate;
            let mut fields = vec![
                (
                    "args",
                    obj(vec![
                        ("ckpt_tokens", num(e.b as f64)),
                        ("peer", num(e.a as f64)),
                        ("sid", num(e.sid as f64)),
                    ]),
                ),
                ("cat", Json::Str("steal".into())),
                ("id", num(e.sid as f64)),
                ("name", Json::Str("steal".into())),
                ("ph", Json::Str(if start { "s" } else { "f" }.into())),
                ("pid", num(1.0)),
                ("tid", tid),
                ("ts", ts),
            ];
            if !start {
                fields.push(("bp", Json::Str("e".into())));
            }
            obj(fields)
        }
        _ => obj(vec![
            (
                "args",
                obj(vec![
                    ("a", num(e.a as f64)),
                    ("b", num(e.b as f64)),
                    ("sid", num(e.sid as f64)),
                ]),
            ),
            ("cat", Json::Str(category(e.kind).into())),
            ("name", Json::Str(e.kind.name().into())),
            ("ph", Json::Str("i".into())),
            ("pid", num(1.0)),
            ("s", Json::Str("t".into())),
            ("tid", tid),
            ("ts", ts),
        ]),
    }
}

fn category(kind: EventKind) -> &'static str {
    use EventKind::*;
    match kind {
        AdmitOnline | ShedOnline | JobAccept | JobDownTier | JobReject => "admission",
        QueueEnter | PrefillChunk | Iteration | Preempt | LayerAbort => "engine",
        StealDemand | StealDonate | StealAbsorb => "steal",
        CkptFlush | Drain | Repair | Recover | ShardDeath => "durability",
        HarvestTighten | HarvestOpen => "harvest",
        PrefixAttach | PrefixPublish | PrefixReclaim => "prefix",
        FirstToken | Finish | Abort => "request",
    }
}

/// Structural facts about an exported file, for the acceptance bench:
/// the array parses, every shard has a named track, and flow ids link
/// a donate on one track to an absorb on another.
#[derive(Debug, Default)]
pub struct PerfettoStats {
    pub events: usize,
    pub tracks: usize,
    pub iterations: usize,
    pub flow_starts: usize,
    pub flow_ends: usize,
    /// Flow ids appearing as both start and end on *different* tracks —
    /// requests actually followed across a migration.
    pub flows_linked: usize,
}

/// Parse and structurally validate an exported trace.
pub fn validate(text: &str) -> Result<PerfettoStats> {
    let j = Json::parse(text).context("trace file is not valid JSON")?;
    let arr = match &j {
        Json::Arr(v) => v,
        _ => bail!("trace file is not a JSON array"),
    };
    let mut st = PerfettoStats::default();
    let mut starts: Vec<(u64, u64)> = Vec::new(); // (id, tid)
    let mut ends: Vec<(u64, u64)> = Vec::new();
    for ev in arr {
        let ph = ev
            .get("ph")
            .and_then(|p| p.as_str())
            .context("event missing ph")?;
        let tid = ev.get("tid").and_then(|t| t.as_f64()).unwrap_or(0.0) as u64;
        match ph {
            "M" => {
                if ev.get("name").and_then(|n| n.as_str()) == Some("thread_name") {
                    st.tracks += 1;
                }
            }
            "X" => {
                st.events += 1;
                st.iterations += 1;
                if ev.get("dur").and_then(|d| d.as_f64()).is_none() {
                    bail!("X event without dur");
                }
            }
            "s" | "f" => {
                st.events += 1;
                let id = ev
                    .get("id")
                    .and_then(|i| i.as_f64())
                    .context("flow event without id")? as u64;
                if ph == "s" {
                    st.flow_starts += 1;
                    starts.push((id, tid));
                } else {
                    st.flow_ends += 1;
                    ends.push((id, tid));
                }
            }
            _ => st.events += 1,
        }
    }
    for (id, tid) in &starts {
        if ends.iter().any(|(eid, etid)| eid == id && etid != tid) {
            st.flows_linked += 1;
        }
    }
    Ok(st)
}

/// Human summary of an exported trace: top-K slowest iterations and
/// per-request span timelines — the `conserve trace --in FILE` output.
pub fn summarize(text: &str, top_k: usize, max_spans: usize) -> Result<String> {
    let j = Json::parse(text).context("trace file is not valid JSON")?;
    let arr = match &j {
        Json::Arr(v) => v,
        _ => bail!("trace file is not a JSON array"),
    };
    struct Iter {
        tid: u64,
        ts: f64,
        dur: f64,
        est: f64,
        prefill: u64,
        decode: u64,
    }
    struct SpanEv {
        ts: f64,
        tid: u64,
        name: String,
    }
    let mut iters: Vec<Iter> = Vec::new();
    let mut spans: std::collections::BTreeMap<u64, Vec<SpanEv>> = Default::default();
    let mut n_events = 0usize;
    let mut tracks = 0usize;
    for ev in arr {
        let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        let tid = ev.get("tid").and_then(|t| t.as_f64()).unwrap_or(0.0) as u64;
        let ts = ev.get("ts").and_then(|t| t.as_f64()).unwrap_or(0.0);
        match ph {
            "M" => {
                if ev.get("name").and_then(|n| n.as_str()) == Some("thread_name") {
                    tracks += 1;
                }
                continue;
            }
            "X" => {
                n_events += 1;
                let args = ev.get("args");
                let g = |k: &str| {
                    args.and_then(|a| a.get(k)).and_then(|v| v.as_f64()).unwrap_or(0.0)
                };
                iters.push(Iter {
                    tid,
                    ts,
                    dur: ev.get("dur").and_then(|d| d.as_f64()).unwrap_or(0.0),
                    est: g("est_us"),
                    prefill: g("prefill_tokens") as u64,
                    decode: g("decode_seqs") as u64,
                });
            }
            _ => {
                n_events += 1;
                let sid = ev
                    .get("args")
                    .and_then(|a| a.get("sid"))
                    .and_then(|s| s.as_f64())
                    .unwrap_or(0.0) as u64;
                if sid != 0 {
                    let name = ev
                        .get("name")
                        .and_then(|n| n.as_str())
                        .unwrap_or("?")
                        .to_string();
                    spans.entry(sid).or_default().push(SpanEv { ts, tid, name });
                }
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "trace: {} events on {} tracks, {} iterations, {} request spans\n",
        n_events,
        tracks,
        iters.len(),
        spans.len()
    ));
    iters.sort_by(|a, b| b.dur.total_cmp(&a.dur));
    out.push_str(&format!("top {} slowest iterations:\n", top_k.min(iters.len())));
    for (i, it) in iters.iter().take(top_k).enumerate() {
        out.push_str(&format!(
            "  {:>2}. track {} @ {:>10.3}s  dur {:>8.3}ms  est {:>8.3}ms  prefill {:>5}  decode {:>4}\n",
            i + 1,
            it.tid,
            it.ts / 1e6,
            it.dur / 1e3,
            it.est / 1e3,
            it.prefill,
            it.decode
        ));
    }
    out.push_str(&format!(
        "request spans (first {} by start time):\n",
        max_spans.min(spans.len())
    ));
    let mut ordered: Vec<(u64, Vec<SpanEv>)> = spans.into_iter().collect();
    ordered.sort_by(|a, b| {
        let ta = a.1.first().map(|e| e.ts).unwrap_or(0.0);
        let tb = b.1.first().map(|e| e.ts).unwrap_or(0.0);
        ta.total_cmp(&tb).then(a.0.cmp(&b.0))
    });
    for (sid, evs) in ordered.iter().take(max_spans) {
        let start = evs.first().map(|e| e.ts).unwrap_or(0.0);
        let end = evs.last().map(|e| e.ts).unwrap_or(0.0);
        let mut shards: Vec<u64> = evs.iter().map(|e| e.tid).collect();
        shards.dedup();
        let chain: Vec<&str> = evs.iter().map(|e| e.name.as_str()).take(8).collect();
        let ell = if evs.len() > 8 { " …" } else { "" };
        out.push_str(&format!(
            "  sid {:>6}: [{:.3}s → {:.3}s] {} events, tracks {:?}: {}{}\n",
            sid,
            start / 1e6,
            end / 1e6,
            evs.len(),
            shards,
            chain.join(" → "),
            ell
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::FleetTracer;

    fn sample_fleet() -> std::sync::Arc<FleetTracer> {
        let fleet = FleetTracer::new(2, 256);
        let s0 = fleet.shard(0);
        let s1 = fleet.shard(1);
        s0.emit(1_000, EventKind::QueueEnter, 7, 0, 64);
        s0.emit(5_000, EventKind::Iteration, 0, (64 << 32) | 3, (4_000 << 32) | 3_500);
        s0.emit(6_000, EventKind::StealDonate, 7, 1, 640);
        s1.emit(7_000, EventKind::StealAbsorb, 7, 0, 640);
        s1.emit(8_000, EventKind::FirstToken, 7, 6_000, 0);
        s1.emit(9_000, EventKind::HarvestTighten, 0, 3, 250);
        s1.emit(9_500, EventKind::Finish, 7, 1, 8);
        fleet
    }

    #[test]
    fn export_is_valid_and_deterministic() {
        let a = export_perfetto(&sample_fleet());
        let b = export_perfetto(&sample_fleet());
        assert_eq!(a, b, "identical rings must export byte-identically");
        let st = validate(&a).unwrap();
        assert_eq!(st.tracks, 2);
        assert_eq!(st.iterations, 1);
        assert_eq!(st.flow_starts, 1);
        assert_eq!(st.flow_ends, 1);
        assert_eq!(st.flows_linked, 1, "donate/absorb must link across tracks");
        assert!(st.events >= 7);
    }

    #[test]
    fn summarize_reports_iterations_and_spans() {
        let text = export_perfetto(&sample_fleet());
        let s = summarize(&text, 5, 10).unwrap();
        assert!(s.contains("slowest iterations"), "{s}");
        assert!(s.contains("sid      7"), "{s}");
        assert!(s.contains("queue_enter"), "{s}");
        assert!(s.contains("finish"), "{s}");
    }

    #[test]
    fn validate_rejects_non_array() {
        assert!(validate("{}").is_err());
        assert!(validate("not json").is_err());
    }
}
