//! Property tests for cross-shard offline work stealing: a stolen
//! request leaks zero donor KV blocks, the donor's old id can never
//! resolve again on any shard, a checkpoint that does not fit the
//! target degrades to recompute instead of losing the request, and the
//! same trace served with stealing on and off completes the identical
//! request set with identical token streams.

use conserve::backend::{CostModel, SimBackend};
use conserve::clock::Clock;
use conserve::config::EngineConfig;
use conserve::profiler::LatencyProfile;
use conserve::request::{rid_shard, Class, KvResidence, Request, State, TokenId};
use conserve::server::{ArrivalSource, ServingEngine};
use conserve::shard::{MigratedRequest, ShardLoads, StealConfig, StealCoordinator};
use conserve::util::rng::Rng;
use std::collections::BTreeMap;
use std::sync::Arc;

fn profile() -> LatencyProfile {
    LatencyProfile {
        c: [1200.0, 96.0, 40.0, 0.385],
    }
}

fn engine(shard: usize, cfg: &EngineConfig, trace: Vec<Request>) -> ServingEngine<SimBackend> {
    let clock = Clock::virtual_at(0);
    let backend = SimBackend::new(
        CostModel::a100_llama2_7b(),
        clock.clone(),
        cfg.sched.safepoint_layers,
    );
    ServingEngine::for_shard(
        shard,
        cfg.clone(),
        backend,
        clock,
        profile(),
        ArrivalSource::from_trace(trace),
    )
}

#[test]
fn cold_steal_rekeys_and_preserves_submission() {
    let cfg = EngineConfig::sim_a100_7b();
    let mut donor = engine(1, &cfg, Vec::new());
    let mut target = engine(2, &cfg, Vec::new());

    let mut r = Request::new(77, Class::Offline, vec![1, 2, 3], 3, 4, 0);
    r.output = vec![9];
    r.generated = 1; // discard-preempted progress: outputs known, ctx 0
    let sampler_state = r.sampler_state;
    let old_id = donor.table.insert(r);
    donor.sched.enqueue(old_id, Class::Offline);

    let mut out = Vec::new();
    donor.donate_victims(4, &mut out);
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].portable.submitted_id, 77);
    assert_eq!(out[0].portable.ckpt_tokens, 0, "cold steal carries no KV");
    assert!(out[0].kv.is_none());
    assert_eq!(donor.rec.steals_out, 1);
    assert_eq!(donor.sched.offline_waiting(), 0);

    target.absorb_migrations(&mut out);
    assert!(out.is_empty());
    assert_eq!(target.rec.steals_in, 1);
    assert_eq!(target.sched.offline_waiting(), 1);
    let (new_id, req) = target.table.iter().next().expect("absorbed request");
    assert_ne!(new_id, old_id);
    assert_eq!(rid_shard(new_id), 2, "re-keyed into the target shard");
    assert_eq!(req.submitted_id, 77);
    assert_eq!(req.sampler_state, sampler_state);
    assert_eq!(req.output, vec![9]);
    assert_eq!(req.generated, 1);
    assert_eq!(req.state, State::Waiting);
}

#[test]
fn checkpointed_steal_leaks_no_donor_blocks() {
    let cfg = EngineConfig::sim_a100_7b();
    let mut donor = engine(1, &cfg, Vec::new());
    let mut target = engine(2, &cfg, Vec::new());
    let host_total = cfg.mem.host_blocks;
    let gpu_total = cfg.mem.gpu_blocks;
    let bt = cfg.mem.block_tokens;

    // a mid-prefill offline request, fully checkpointed then evicted —
    // the §4.4 free-to-move state
    let r = Request::new(88, Class::Offline, vec![], 64, 8, 0);
    let old_id = donor.table.insert(r);
    donor.kv.register(old_id);
    donor.kv.grow(old_id, 48).unwrap();
    donor.kv.commit(old_id, 48).unwrap();
    for i in donor.kv.checkpoint_candidates(old_id) {
        donor.kv.begin_ckpt(old_id, i).unwrap();
        donor.kv.finish_ckpt(old_id, i);
    }
    donor.kv.evict_gpu(old_id);
    {
        let req = donor.table.get_mut(old_id).unwrap();
        req.ctx_len = 48;
        req.ckpt_len = 48;
        req.state = State::Preempted;
        req.residence = KvResidence::Host;
        req.preemptions = 1;
    }
    donor.sched.enqueue(old_id, Class::Offline);
    assert!(donor.kv.host_free() < host_total, "checkpoints hold blocks");

    let mut out = Vec::new();
    donor.donate_victims(1, &mut out);
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].portable.ckpt_tokens, 48);

    // leak-freedom: every donor pool is exactly full again
    assert_eq!(donor.kv.gpu_free(), gpu_total);
    assert_eq!(donor.kv.host_free(), host_total);
    assert!(donor.kv.check_conservation());
    assert_eq!(donor.rec.stolen_ckpt_tokens, 48);

    // stale donor id: misses donor and target, arena and KV alike
    for eng in [&donor, &target] {
        assert!(eng.table.get(old_id).is_none());
        assert!(eng.kv.seq(old_id).is_none());
    }

    target.absorb_migrations(&mut out);
    let (new_id, req) = target.table.iter().next().expect("absorbed");
    assert_eq!(req.residence, KvResidence::Host);
    assert_eq!(req.ctx_len, 48);
    let seq = target.kv.seq(new_id).expect("imported sequence");
    assert_eq!(seq.tokens, 48);
    assert!(seq.fully_checkpointed(bt));
    assert_eq!(target.kv.host_free(), host_total - 48usize.div_ceil(bt));
    assert!(target.kv.check_conservation());

    // the target finishes it end to end (prefetch -> prefill -> decode)
    target.run(120_000_000);
    assert_eq!(target.rec.finished[1], 1, "stolen request must finish");
    let done = target
        .table
        .values()
        .find(|r| r.submitted_id == 88)
        .unwrap();
    assert_eq!(done.state, State::Finished);
    assert_eq!(done.generated, 8);
    assert_eq!(target.kv.gpu_free(), gpu_total);
    assert_eq!(target.kv.host_free(), host_total);
    assert!(target.kv.check_conservation());
}

#[test]
fn oversized_checkpoint_degrades_to_recompute() {
    // target host pool too small for the migrated prefix: the request
    // must fall back to the recompute path, not get lost or leak
    let mut small = EngineConfig::sim_a100_7b();
    small.mem.host_blocks = 1;
    let mut target = engine(3, &small, Vec::new());

    let mut r = Request::new(99, Class::Offline, vec![], 64, 4, 0);
    r.ctx_len = 48;
    r.ckpt_len = 48;
    let mig = MigratedRequest {
        portable: conserve::request::PortableRequest::detach(r, 48),
        kv: None,
    };
    let mut migs = vec![mig];
    target.absorb_migrations(&mut migs);
    let (_, req) = target.table.iter().next().unwrap();
    assert_eq!(req.residence, KvResidence::Discarded);
    assert_eq!(req.ctx_len, 0);
    assert_eq!(req.recomputed_tokens, 48);
    assert_eq!(target.kv.host_free(), 1, "failed import must not leak");
    assert!(target.kv.check_conservation());

    target.run(120_000_000);
    assert_eq!(target.rec.finished[1], 1, "recompute path still finishes");
}

/// Build a deterministic skewed workload: shard 0 holds the whole
/// offline burst plus some online traffic, shard 1 holds online only —
/// the stranded-capacity shape stealing exists to fix.
fn skewed_traces(seed: u64) -> Vec<Vec<Request>> {
    let mut rng = Rng::new(seed);
    let mut next_id = 1u64;
    let mut mk = |class: Class, input: usize, output: usize, at: u64| {
        let r = Request::new(next_id, class, Vec::new(), input, output, at);
        next_id += 1;
        r
    };
    let mut shard0 = Vec::new();
    let mut shard1 = Vec::new();
    for i in 0..6 {
        shard0.push(mk(Class::Online, 128, 8, i * 500_000));
        shard1.push(mk(Class::Online, 128, 8, 250_000 + i * 500_000));
    }
    for _ in 0..30 {
        let input = rng.range_usize(256, 768);
        let output = rng.range_usize(12, 24);
        shard0.push(mk(Class::Offline, input, output, 0));
    }
    vec![shard0, shard1]
}

/// Per-request result fingerprint: (class, generated, token stream).
type Results = BTreeMap<u64, (Class, usize, Vec<TokenId>)>;

/// Serve `traces` in deterministic single-thread lockstep: every shard
/// advances its virtual clock in fixed slices, in shard order, polling
/// the steal coordinator between slices. Same inputs => same schedule,
/// same steals, same results — which is what lets the on/off runs be
/// compared exactly.
fn lockstep_run(traces: Vec<Vec<Request>>, steal: Option<StealConfig>) -> (Results, bool, u64) {
    let cfg = EngineConfig::sim_a100_7b();
    let n = traces.len();
    let loads = Arc::new(ShardLoads::new(n, cfg.mem.gpu_blocks));
    let st = steal.map(|c| Arc::new(StealCoordinator::new(c, loads.clone())));
    let mut engines: Vec<ServingEngine<SimBackend>> = traces
        .into_iter()
        .enumerate()
        .map(|(s, tr)| {
            let mut e = engine(s, &cfg, tr);
            if let Some(st) = &st {
                e.set_shard_loads(loads.clone());
                e.set_steal_coordinator(st.clone());
            }
            e
        })
        .collect();

    const SLICE: u64 = 200_000; // 200 ms of virtual time per step
    let mut all_done = false;
    for step in 1..=10_000u64 {
        let until = step * SLICE;
        for e in engines.iter_mut() {
            e.poll_steals();
            e.run(until);
        }
        if engines.iter().all(|e| e.drained()) {
            let more = engines.iter_mut().any(|e| e.poll_steals());
            if !more {
                all_done = true;
                break;
            }
        }
    }

    let mut results = Results::new();
    let mut steals_in = 0;
    for e in &engines {
        assert!(e.kv.check_conservation());
        assert_eq!(
            e.kv.gpu_free(),
            cfg.mem.gpu_blocks,
            "finished fleet must hold no GPU blocks"
        );
        assert_eq!(
            e.kv.host_free(),
            cfg.mem.host_blocks,
            "finished fleet must hold no host blocks"
        );
        steals_in += e.rec.steals_in;
        for r in e.table.values() {
            assert_eq!(r.state, State::Finished, "unfinished request {}", r.submitted_id);
            let prev = results.insert(r.submitted_id, (r.class, r.generated, r.output.clone()));
            assert!(prev.is_none(), "request {} served twice", r.submitted_id);
        }
    }
    (results, all_done, steals_in)
}

#[test]
fn steal_on_off_complete_identical_request_sets() {
    let traces = skewed_traces(0xC0FFEE);
    let n_requests: usize = traces.iter().map(Vec::len).sum();

    let (off, off_done, off_steals) = lockstep_run(traces.clone(), None);
    let (on, on_done, on_steals) = lockstep_run(traces, Some(StealConfig::default()));

    assert!(off_done && on_done, "both runs must drain the fleet");
    assert_eq!(off_steals, 0);
    assert!(on_steals > 0, "the skewed trace must trigger migrations");
    assert_eq!(off.len(), n_requests);
    assert_eq!(
        off, on,
        "stealing must not change which requests complete or what they generate"
    );
}
