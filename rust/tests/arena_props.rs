//! Property tests for slab-id recycling across the request arena and the
//! KV manager: releasing and re-admitting requests must never alias live
//! KV state or resurrect stale generation ids, and block conservation
//! must hold across thousands of random admit / preempt / resume /
//! discard / finish cycles.

use conserve::kvcache::manager::KvManager;
use conserve::request::{rid_slot, Class, Request, RequestArena, RequestId};
use conserve::util::rng::Rng;
use std::collections::HashSet;

const BLOCK_TOKENS: usize = 16;

fn new_req(rng: &mut Rng) -> Request {
    let class = if rng.range(0, 4) == 0 {
        Class::Online
    } else {
        Class::Offline
    };
    let prompt = rng.range_usize(16, 200);
    let out = rng.range_usize(4, 40);
    Request::new(0, class, vec![], prompt, out, 0)
}

#[test]
fn recycling_never_aliases_or_resurrects() {
    let mut rng = Rng::new(2024);
    let mut arena = RequestArena::new();
    let mut kv = KvManager::new(96, 256, BLOCK_TOKENS);
    let mut live: Vec<RequestId> = Vec::new();
    let mut dead: Vec<RequestId> = Vec::new();
    let mut ever_issued: HashSet<RequestId> = HashSet::new();

    for step in 0..10_000 {
        match rng.range(0, 6) {
            // admit: insert + register + grow/commit some prefix
            0 | 1 => {
                if live.len() < 12 {
                    let id = arena.insert(new_req(&mut rng));
                    assert!(
                        ever_issued.insert(id),
                        "step {step}: id {id} resurrected — generation guard failed"
                    );
                    kv.register(id);
                    let want = rng.range_usize(1, arena[id].prompt_len + 1);
                    if kv.grow(id, want).is_ok() {
                        kv.commit(id, want).unwrap();
                        arena.get_mut(id).unwrap().ctx_len = want;
                    }
                    live.push(id);
                }
            }
            // preempt-evict (checkpoint everything, then release GPU)
            2 => {
                if let Some(&id) = live.get(rng.range_usize(0, live.len().max(1)) % live.len().max(1)) {
                    for idx in kv.checkpoint_candidates(id) {
                        if kv.begin_ckpt(id, idx).is_err() {
                            break;
                        }
                        kv.finish_ckpt(id, idx);
                    }
                    kv.evict_gpu(id);
                }
            }
            // resume (prefetch back what has host copies)
            3 => {
                if let Some(&id) = live.get(rng.range_usize(0, live.len().max(1)) % live.len().max(1)) {
                    for (idx, _hb) in kv.prefetch_candidates(id) {
                        if kv.begin_prefetch(id, idx).is_err() {
                            break;
                        }
                    }
                }
            }
            // discard-preempt (recompute path)
            4 => {
                if let Some(&id) = live.get(rng.range_usize(0, live.len().max(1)) % live.len().max(1)) {
                    kv.discard(id);
                    arena.get_mut(id).unwrap().ctx_len = 0;
                }
            }
            // finish: release KV, remove from arena, slot recycles
            _ => {
                if !live.is_empty() {
                    let i = rng.range_usize(0, live.len());
                    let id = live.swap_remove(i);
                    kv.release(id, false);
                    let removed = arena.remove(id);
                    assert!(removed.is_some(), "step {step}: live id {id} vanished");
                    dead.push(id);
                }
            }
        }

        assert!(
            kv.check_conservation(),
            "step {step}: block conservation violated"
        );

        // stale ids must stay dead: no arena hit, no KV state, and no
        // mutation path back into the new slot occupant
        for &stale in dead.iter().rev().take(8) {
            assert!(arena.get(stale).is_none(), "step {step}: stale {stale} readable");
            assert!(
                kv.seq(stale).is_none(),
                "step {step}: stale {stale} still owns KV"
            );
            assert!(kv.grow(stale, 64).is_err());
            assert_eq!(kv.evict_gpu(stale), 0);
        }
        // live ids must still resolve, and committed tokens must match
        // what the request believes it has
        for &id in &live {
            let r = arena.get(id).expect("live id must resolve");
            assert_eq!(r.id, id);
            let toks = kv.seq(id).map(|s| s.tokens).unwrap_or(0);
            assert_eq!(toks, r.ctx_len, "step {step}: KV tokens drifted for {id}");
        }
    }

    // arena stayed dense: slots bounded by peak concurrency, not by the
    // total number of requests ever admitted
    assert!(ever_issued.len() > 1_000, "exercise enough admissions");
    assert!(
        arena.slot_count() <= 16,
        "arena grew to {} slots for <=12 concurrent requests",
        arena.slot_count()
    );
}

#[test]
fn slot_reuse_pairs_fresh_kv_with_fresh_request() {
    // deterministic tight loop: one slot recycled thousands of times;
    // the KV registration under the new generation must always start
    // empty even though the previous occupant left host checkpoints
    let mut arena = RequestArena::new();
    let mut kv = KvManager::new(8, 16, BLOCK_TOKENS);
    let mut last: Option<RequestId> = None;
    for round in 0..5_000 {
        let id = arena.insert(Request::new(0, Class::Offline, vec![], 48, 8, 0));
        if let Some(prev) = last {
            assert_eq!(rid_slot(prev), rid_slot(id), "single-slot recycling");
            assert_ne!(prev, id);
            assert!(kv.seq(prev).is_none(), "round {round}: stale KV visible");
        }
        kv.register(id);
        assert_eq!(kv.seq(id).unwrap().tokens, 0, "round {round}: inherited KV");
        kv.grow(id, 48).unwrap();
        kv.commit(id, 48).unwrap();
        for idx in kv.checkpoint_candidates(id) {
            kv.begin_ckpt(id, idx).unwrap();
            kv.finish_ckpt(id, idx);
        }
        kv.evict_gpu(id);
        // finish without releasing host copies first: release() drops them
        kv.release(id, false);
        arena.remove(id).unwrap();
        assert!(kv.check_conservation(), "round {round}");
        last = Some(id);
    }
    assert_eq!(arena.slot_count(), 2); // reserved slot 0 + the one reused slot
}
