"""L2 model correctness: layered entry points vs the dense reference, and
the serving-semantics invariants the Rust engine relies on (chunked
prefill equivalence, bucket-padding harmlessness, layered == monolithic).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import MODEL, LAYER_WEIGHT_NAMES
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = MODEL
PARAMS = model.init_params(CFG, seed=7)


def layer_weights(l):
    return [PARAMS[f"layers.{l}.{n}"] for n in LAYER_WEIGHT_NAMES]


def empty_caches(b):
    shape = (b, CFG.n_kv_heads, CFG.max_seq, CFG.head_dim)
    return jnp.zeros(shape), jnp.zeros(shape)


def run_layered(tokens, k_caches, v_caches, ctx_lens):
    """Compose embed -> layer_fwd* -> lm_head exactly as the Rust engine."""
    hidden = model.embed(tokens, PARAMS["embedding"])
    ks, vs = [], []
    for l in range(CFG.n_layers):
        hidden, kc, vc = model.layer_fwd(
            CFG, hidden, k_caches[l], v_caches[l], ctx_lens, *layer_weights(l)
        )
        ks.append(kc)
        vs.append(vc)
    logits = model.lm_head(CFG, hidden, PARAMS["final_norm"], PARAMS["lm_head"])
    return logits, jnp.stack(ks), jnp.stack(vs)


def fresh_stacked(b):
    shape = (CFG.n_layers, b, CFG.n_kv_heads, CFG.max_seq, CFG.head_dim)
    return jnp.zeros(shape), jnp.zeros(shape)


def test_layer_fwd_matches_ref():
    B, T = 2, 16
    hidden = jax.random.normal(jax.random.PRNGKey(0), (B, T, CFG.d_model))
    kc, vc = empty_caches(B)
    ctx = jnp.array([0, 5], jnp.int32)
    w = {n: PARAMS[f"layers.0.{n}"] for n in LAYER_WEIGHT_NAMES}
    out = model.layer_fwd(CFG, hidden, kc, vc, ctx, *layer_weights(0))
    expect = ref.layer_ref(CFG, hidden, kc, vc, ctx, w)
    for o, e in zip(out, expect):
        np.testing.assert_allclose(o, e, rtol=2e-4, atol=2e-4)


def test_layered_matches_model_ref():
    B, T = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, CFG.vocab_size)
    ks, vs = fresh_stacked(B)
    ctx = jnp.zeros(B, jnp.int32)
    logits, ks1, vs1 = run_layered(tokens, ks, vs, ctx)
    logits2, ks2, vs2 = ref.model_ref(CFG, PARAMS, tokens, ks, vs, ctx)
    np.testing.assert_allclose(logits, logits2, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(ks1, ks2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(vs1, vs2, rtol=2e-4, atol=2e-4)


def test_layered_matches_monolithic_full():
    """model_full (the no-safepoint export) must agree with the layered
    composition bit-for-bit in structure (same kernels, same order)."""
    B, T = 4, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, CFG.vocab_size)
    ks, vs = fresh_stacked(B)
    ctx = jnp.zeros(B, jnp.int32)
    flat = [PARAMS[n] for n, _ in __import__(
        "compile.configs", fromlist=["param_specs"]).param_specs(CFG)]
    logits_f, ks_f, vs_f = model.model_full(CFG, tokens, ks, vs, ctx, *flat)
    logits_l, ks_l, vs_l = run_layered(tokens, ks, vs, ctx)
    np.testing.assert_allclose(logits_f, logits_l, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ks_f, ks_l, rtol=1e-6, atol=1e-6)


def test_chunked_prefill_equivalence():
    """Prefilling 32 tokens as 2x16-token chunks must produce the same
    final-position logits and caches as one 32-token pass. This is the
    invariant chunked prefill (paper §4.2/§4.5) rests on."""
    B = 1
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, 32), 0, CFG.vocab_size)
    ks, vs = fresh_stacked(B)

    # one shot (T=32 not a bucket, but jax accepts any static shape here)
    logits_one, ks_one, vs_one = run_layered(prompt, ks, vs, jnp.zeros(B, jnp.int32))

    # two chunks
    ks_c, vs_c = fresh_stacked(B)
    _, ks_c, vs_c = run_layered(prompt[:, :16], ks_c, vs_c, jnp.zeros(B, jnp.int32))
    logits_two, ks_c, vs_c = run_layered(
        prompt[:, 16:], ks_c, vs_c, jnp.full((B,), 16, jnp.int32)
    )
    np.testing.assert_allclose(
        logits_one[:, -1], logits_two[:, -1], rtol=2e-3, atol=2e-3
    )
    np.testing.assert_allclose(ks_one[:, :, :, :32], ks_c[:, :, :, :32],
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_prefill_logits():
    """Greedy decode step (T=1) after a prefill must equal the logits the
    full-sequence pass computes at that position."""
    B = 1
    seq = jax.random.randint(jax.random.PRNGKey(4), (B, 17), 0, CFG.vocab_size)
    ks, vs = fresh_stacked(B)

    # full pass over 17 tokens: logits at position 16
    logits_full, _, _ = run_layered(seq, ks, vs, jnp.zeros(B, jnp.int32))

    # prefill 16 then decode token 16
    ks2, vs2 = fresh_stacked(B)
    _, ks2, vs2 = run_layered(seq[:, :16], ks2, vs2, jnp.zeros(B, jnp.int32))
    logits_dec, _, _ = run_layered(
        seq[:, 16:17], ks2, vs2, jnp.full((B,), 16, jnp.int32)
    )
    np.testing.assert_allclose(
        logits_full[:, -1], logits_dec[:, 0], rtol=2e-3, atol=2e-3
    )


def test_bucket_padding_rows_harmless():
    """Batch-bucket padding: extra rows must not change real rows' output."""
    T = 16
    tokens = jax.random.randint(jax.random.PRNGKey(5), (1, T), 0, CFG.vocab_size)
    ks1, vs1 = fresh_stacked(1)
    logits1, _, _ = run_layered(tokens, ks1, vs1, jnp.zeros(1, jnp.int32))

    # same request padded into a B=4 bucket with dummy rows
    tokens4 = jnp.concatenate([tokens, jnp.zeros((3, T), tokens.dtype)], axis=0)
    ks4, vs4 = fresh_stacked(4)
    logits4, _, _ = run_layered(tokens4, ks4, vs4, jnp.zeros(4, jnp.int32))
    np.testing.assert_allclose(logits1[0], logits4[0], rtol=1e-4, atol=1e-4)


def test_chunk_padding_tokens_harmless():
    """Chunk-bucket padding: a 10-token tail padded to T=16 must yield the
    same cache content for the 10 real slots, and the next chunk (which
    overwrites the 6 garbage slots) must see identical state."""
    B = 1
    prompt = jax.random.randint(jax.random.PRNGKey(6), (B, 26), 0, CFG.vocab_size)
    # exact: chunks 16 + 10
    ks_a, vs_a = fresh_stacked(B)
    _, ks_a, vs_a = run_layered(prompt[:, :16], ks_a, vs_a, jnp.zeros(B, jnp.int32))
    la, ks_a, vs_a = run_layered(
        prompt[:, 16:26], ks_a, vs_a, jnp.full((B,), 16, jnp.int32)
    )
    # padded: second chunk padded to 16 with zeros
    padded = jnp.concatenate(
        [prompt[:, 16:26], jnp.zeros((B, 6), prompt.dtype)], axis=1
    )
    ks_b, vs_b = fresh_stacked(B)
    _, ks_b, vs_b = run_layered(prompt[:, :16], ks_b, vs_b, jnp.zeros(B, jnp.int32))
    lb, ks_b, vs_b = run_layered(padded, ks_b, vs_b, jnp.full((B,), 16, jnp.int32))

    np.testing.assert_allclose(la[:, 9], lb[:, 9], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        ks_a[:, :, :, :26], ks_b[:, :, :, :26], rtol=1e-5, atol=1e-5
    )


def test_rope_positions_matter():
    """Same token at different positions must produce different K vectors
    (sanity that RoPE is actually applied at absolute positions)."""
    B, T = 1, 1
    tok = jnp.full((B, T), 65, jnp.int32)
    ks0, vs0 = fresh_stacked(B)
    _, ks_a, _ = run_layered(tok, ks0, vs0, jnp.zeros(B, jnp.int32))
    _, ks_b, _ = run_layered(tok, ks0, vs0, jnp.full((B,), 50, jnp.int32))
    a = np.asarray(ks_a[0, 0, :, 0, :])   # layer 0, row 0, slot written at 0
    b = np.asarray(ks_b[0, 0, :, 50, :])
    assert not np.allclose(a[:, :][0] if a.ndim > 1 else a, b, atol=1e-5)


def test_logits_finite_and_varied():
    """Random-init weights must give finite, non-degenerate logits (the
    real-path examples rely on this for non-trivial token streams)."""
    B, T = 4, 16
    tokens = jax.random.randint(jax.random.PRNGKey(8), (B, T), 0, CFG.vocab_size)
    ks, vs = fresh_stacked(B)
    logits, _, _ = run_layered(tokens, ks, vs, jnp.zeros(B, jnp.int32))
    arr = np.asarray(logits)
    assert np.isfinite(arr).all()
    assert len(np.unique(arr.argmax(-1))) > 1
