//! Minimal JSON: a recursive-descent parser and a compact emitter.
//!
//! The environment vendors no serde; this module is just enough to read
//! the AOT `manifest.json` / profiler tables and to emit experiment
//! reports. It supports the full JSON value grammar (objects, arrays,
//! strings with escapes, numbers, booleans, null) but none of the exotic
//! extensions (comments, NaN, trailing commas).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---------------------------------------------------------- accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required fields (manifest is trusted build
    /// output; a missing field is a build bug, not a runtime condition).
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required json field `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ------------------------------------------------------------ parsing
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ----------------------------------------------------------- emitting
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for report emission.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn arr<I: IntoIterator<Item = Json>>(it: I) -> Json {
    Json::Arr(it.into_iter().collect())
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            j.req("a").as_arr().unwrap()[2].req("b").as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":{"x":1,"y":[true,false,null,"s\"q"]},"n":-0.25}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn parses_real_manifest_when_built() {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let m = Json::parse(&text).unwrap();
            assert!(m.req("entries").as_arr().unwrap().len() > 10);
            assert_eq!(m.req("model").req("d_model").as_usize(), Some(128));
        }
    }
}
