#!/usr/bin/env python3
"""Bench-regression ratchet (scaffold).

Diffs freshly produced bench result files (BENCH_sched.json,
BENCH_jobs.json, ...) against a checked-in baseline and *warns* on
regressions. Non-fatal by default: hosted-runner numbers are too noisy
to gate on until a stable baseline exists (see ROADMAP.md) — pass
--fail to turn warnings into a nonzero exit once that day comes.

Baseline format (scripts/bench_baseline.json):

    {
      "<metric name>": {
        "file": "BENCH_jobs.json",        # bench output file
        "path": "attainment_urgency_minus_fifo",  # dotted path, [i] indexes arrays
        "direction": "min",               # "min": value must stay >= baseline*(1-tol)
                                          # "max": value must stay <= baseline*(1+tol)
        "baseline": null,                 # null = unpopulated (record-only)
        "tolerance": 0.10
      }, ...
    }

A null baseline never warns — the script prints the measured value so a
maintainer (or a future CI job) can ratchet it in.

Usage: python3 scripts/ratchet.py [--dir .] [--baseline scripts/bench_baseline.json] [--fail]
"""

import argparse
import json
import os
import re
import sys


def dig(obj, path):
    """Resolve a dotted path with optional [i] array indexing."""
    for part in path.split("."):
        m = re.fullmatch(r"(.*?)((?:\[\d+\])*)", part)
        key, idxs = m.group(1), re.findall(r"\[(\d+)\]", m.group(2))
        if key:
            if not isinstance(obj, dict) or key not in obj:
                raise KeyError(f"missing key {key!r} in path {path!r}")
            obj = obj[key]
        for i in idxs:
            obj = obj[int(i)]
    return obj


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=".", help="directory holding BENCH_*.json files")
    ap.add_argument(
        "--baseline",
        default=os.path.join(os.path.dirname(__file__), "bench_baseline.json"),
    )
    ap.add_argument(
        "--fail",
        action="store_true",
        help="exit nonzero on regressions (default: warn only)",
    )
    args = ap.parse_args()

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"ratchet: no baseline at {args.baseline}; nothing to check")
        return 0

    # surface bench outputs the baseline doesn't know about: a new
    # BENCH_*.json with no metric entry silently escapes the ratchet
    covered = {spec["file"] for spec in baseline.values()}
    try:
        produced = sorted(
            f
            for f in os.listdir(args.dir)
            if re.fullmatch(r"BENCH_\w+\.json", f)
        )
    except FileNotFoundError:
        produced = []
    for f in produced:
        if f not in covered:
            print(
                f"ratchet: WARNING: {f} present in {args.dir} but no baseline "
                f"metric references it -- add an entry to {args.baseline}"
            )

    warnings = 0
    missing = 0
    for name, spec in sorted(baseline.items()):
        path = os.path.join(args.dir, spec["file"])
        try:
            with open(path) as f:
                results = json.load(f)
            value = dig(results, spec["path"])
        except FileNotFoundError:
            # a baseline-covered bench that produced no result file is a
            # regression signal too (a renamed output or a bench dropped
            # from CI would otherwise escape the ratchet silently)
            print(
                f"ratchet: WARNING: {name}: {spec['file']} not found in {args.dir} "
                f"(bench not run, or its output file was renamed?)"
            )
            warnings += 1
            missing += 1
            continue
        except (KeyError, IndexError, TypeError) as e:
            print(f"ratchet: {name}: cannot resolve {spec['path']!r}: {e} -- skipped")
            missing += 1
            continue

        base = spec.get("baseline")
        if base is None:
            print(f"ratchet: {name}: measured {value} (baseline unpopulated -- record-only)")
            continue
        tol = float(spec.get("tolerance", 0.10))
        direction = spec.get("direction", "min")
        if direction == "min":
            limit = base * (1.0 - tol)
            ok = value >= limit
            rel = "<" if not ok else ">="
        else:
            limit = base * (1.0 + tol)
            ok = value <= limit
            rel = ">" if not ok else "<="
        if ok:
            print(f"ratchet: {name}: OK ({value} {rel} limit {limit:.4g}, baseline {base})")
        else:
            print(
                f"ratchet: WARNING: {name} regressed: {value} {rel} limit {limit:.4g} "
                f"(baseline {base}, tolerance {tol:.0%})"
            )
            warnings += 1

    print(
        f"ratchet: {warnings} regression warning(s), {missing} metric(s) skipped"
        + ("" if args.fail or warnings == 0 else " -- non-fatal (pass --fail to gate)")
    )
    return 1 if (args.fail and warnings) else 0


if __name__ == "__main__":
    sys.exit(main())
