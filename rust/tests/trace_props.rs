//! Flight-recorder properties: the observability layer must be
//! deterministic, truthful, and complete.
//!
//! * Two identical-seed simulated runs export **byte-identical**
//!   Perfetto JSON — traces are diff-able artifacts, not timestamps
//!   soup (virtual clocks, ordered rings, ordered JSON keys).
//! * A deterministic injected kill leaves a flight dump whose final
//!   `ShardDeath` event carries the same iteration number the
//!   supervisor's [`ShardDied`] payload reports — post-mortems and
//!   supervision never disagree about where a shard stopped.
//! * Request spans stay well-formed (queue entry → … → terminal)
//!   across preemption, migration, a shard kill and the store-backed
//!   recovery round: no orphan lifecycles.

use conserve::batch::{
    run_jobs_with_recovery, run_jobs_with_store, JobInput, JobManager, JobRequest,
    JobRunOpts, JobStore,
};
use conserve::config::EngineConfig;
use conserve::request::{Class, Request};
use conserve::shard::{run_sharded_sim_traced, Placement, ShardDied};
use conserve::trace::{
    analyze_spans, flight_dump, parse_flight_dump, perfetto, EventKind, FleetTracer,
    DEFAULT_DUMP_LAST, DEFAULT_RING_EVENTS,
};
use conserve::util::fault::{silence_injected_panics, FaultPlan};
use conserve::util::rng::Rng;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

const N_SHARDS: usize = 2;
const DURATION_S: f64 = 600.0;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "conserve-traceprops-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A deterministic co-serving mix: online gamma-ish arrivals plus an
/// offline pool, the same every call (seeded Rng, fixed ids).
fn sim_events() -> Vec<Request> {
    let mut rng = Rng::new(0x7ace);
    let mut events = Vec::new();
    let mut id = 1u64;
    for i in 0..48u64 {
        let input = rng.range_usize(64, 512);
        let output = rng.range_usize(8, 48);
        events.push(Request::new(id, Class::Online, vec![], input, output, i * 400_000));
        id += 1;
    }
    for _ in 0..24 {
        let input = rng.range_usize(256, 1024);
        let output = rng.range_usize(32, 128);
        events.push(Request::new(id, Class::Offline, vec![], input, output, 0));
        id += 1;
    }
    events
}

fn traced_sim() -> (Arc<FleetTracer>, String) {
    let cfg = EngineConfig::sim_a100_7b();
    let tracer = FleetTracer::new(N_SHARDS, DEFAULT_RING_EVENTS);
    // steal off: cross-shard stealing reacts to real thread interleaving
    // (load-board sampling), which is exactly what a determinism check
    // must exclude; each shard alone is lockstep on its virtual clock
    let run = run_sharded_sim_traced(
        &cfg,
        N_SHARDS,
        Placement::affinity(),
        sim_events(),
        60.0,
        None,
        Some(tracer.clone()),
    );
    assert!(run.merged.online_finished > 0, "the workload must finish online work");
    let text = perfetto::export_perfetto(&tracer);
    (tracer, text)
}

#[test]
fn identical_seed_runs_export_byte_identical_perfetto_json() {
    let (tracer, a) = traced_sim();
    let (_, b) = traced_sim();
    assert_eq!(a, b, "same seed, same workload ⇒ byte-identical trace files");

    let st = perfetto::validate(&a).expect("exported trace must be valid trace-event JSON");
    assert_eq!(st.tracks, N_SHARDS, "one named track per shard");
    assert!(st.iterations > 0, "engine iterations must appear as X slices");
    assert!(st.events > st.iterations, "instant events must be present too");

    // every lifecycle stage of the taxonomy shows up in a plain co-serving run
    let merged = tracer.merged();
    for kind in [
        EventKind::QueueEnter,
        EventKind::PrefillChunk,
        EventKind::Iteration,
        EventKind::FirstToken,
        EventKind::Finish,
    ] {
        assert!(
            merged.iter().any(|e| e.kind == kind),
            "expected at least one {kind:?} event in the trace"
        );
    }
    assert_eq!(tracer.dropped(), 0, "this workload must fit the default ring");

    // the summarizer digests its own export
    let s = perfetto::summarize(&a, 5, 10).unwrap();
    assert!(s.contains("slowest iterations"), "{s}");
    assert!(s.contains("request spans"), "{s}");
}

/// The crash-recovery job mix from the fault-props suite: enough work
/// that a mid-run kill strands requests on the dead shard.
fn job_inputs() -> Vec<JobInput> {
    let mut rng = Rng::new(0xFA17);
    let mut jobs = Vec::new();
    for (n, in_lo, in_hi, out) in [(5, 128, 512, 12), (4, 256, 768, 16), (3, 2048, 3072, 384)] {
        jobs.push(JobInput {
            tenant: 1 + jobs.len() as u32,
            tier: (jobs.len() % 3) as u8,
            submitted_at: 0,
            deadline: 0,
            requests: (0..n)
                .map(|_| JobRequest {
                    prompt: Vec::new(),
                    prompt_len: rng.range_usize(in_lo, in_hi),
                    max_new_tokens: out,
                })
                .collect(),
        });
    }
    jobs
}

fn admit_all(jm: &mut JobManager) -> Vec<Request> {
    let mut events = Vec::new();
    for input in job_inputs() {
        jm.admit(&input, &mut events);
    }
    events
}

fn traced_opts(tracer: &Arc<FleetTracer>, ckpt_every: u64) -> JobRunOpts {
    JobRunOpts {
        collect_state: true,
        synth_tokens: true,
        ckpt_every,
        tracer: Some(tracer.clone()),
        ..JobRunOpts::new(N_SHARDS, DURATION_S)
    }
}

#[test]
fn flight_dump_after_injected_kill_agrees_with_the_supervisor() {
    silence_injected_panics();
    let cfg = EngineConfig::sim_a100_7b();
    let mut jm = JobManager::new(5_000.0);
    let events = admit_all(&mut jm);
    let tracer = FleetTracer::new(N_SHARDS, DEFAULT_RING_EVENTS);
    let plan = FaultPlan::parse("kill=1@30").unwrap();
    let out = run_jobs_with_store(
        &cfg,
        &traced_opts(&tracer, 0),
        jm.board().clone(),
        events,
        None,
        Some(&plan),
    );

    assert_eq!(out.deaths.len(), 1, "the planned kill lands");
    let d: &ShardDied = &out.deaths[0];
    let iter = d
        .iteration()
        .expect("an injected kill's payload carries the death iteration");
    assert_eq!(iter, 30, "kill=1@30 dies at iteration 30");

    let dir = tmp_dir("kill");
    let path = flight_dump(&dir, "death", &tracer, DEFAULT_DUMP_LAST).unwrap();
    let evs = parse_flight_dump(&std::fs::read_to_string(&path).unwrap());
    assert!(!evs.is_empty(), "the dump must hold events");
    let death = evs
        .iter()
        .filter(|e| e.kind == EventKind::ShardDeath && e.shard == d.shard as u32)
        .next_back()
        .expect("the dead shard's ring ends with a ShardDeath event");
    assert_eq!(
        death.a, iter,
        "the flight record's last word and the supervisor agree on the death iteration"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn spans_stay_well_formed_across_kill_and_recovery() {
    silence_injected_panics();
    let cfg = EngineConfig::sim_a100_7b();
    let dir = tmp_dir("spans");
    let mut jm = JobManager::new(5_000.0);
    let events = admit_all(&mut jm);
    let mut store = JobStore::open(&dir).unwrap();
    for spec in jm.specs().to_vec() {
        store.record_spec(&spec, &events).unwrap();
    }
    let store = Arc::new(Mutex::new(store));

    // one tracer across both rounds: the crash and the replay form one
    // flight record, so a request killed mid-decode and re-served by
    // recovery is a single span under its stable submission id
    let tracer = FleetTracer::new(N_SHARDS, DEFAULT_RING_EVENTS);
    let plan = FaultPlan::parse("kill=1@35,delay-steals=2").unwrap();
    let rec = run_jobs_with_recovery(
        &cfg,
        &traced_opts(&tracer, 10),
        jm.board().clone(),
        events,
        store.clone(),
        Some(&plan),
    )
    .unwrap();

    assert_eq!(rec.first.deaths.len(), 1);
    assert!(rec.recovery.is_some(), "a death must trigger recovery");
    let dead: Vec<u32> = rec.first.deaths.iter().map(|d| d.shard as u32).collect();

    let merged = tracer.merged();
    assert!(
        merged.iter().any(|e| e.kind == EventKind::Recover),
        "the recovery round must stamp a Recover seam event"
    );
    let rep = analyze_spans(&merged, &dead, false, tracer.dropped() > 0);
    assert!(rep.spans >= 12, "every job request forms a span (got {})", rep.spans);
    assert!(
        rep.ok(),
        "no orphan request lifecycles across kill + recovery: {:?}",
        rep.orphans
    );
    assert!(
        rep.finished >= rep.spans - rep.killed,
        "every span not excused by the death must reach a terminal event"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
