//! `bench_sched_loop` — engine hot-loop throughput on a large synthetic
//! on/off co-serving trace (the ISSUE-1 zero-allocation acceptance
//! bench).
//!
//! Drives the full schedule→execute→commit loop on the simulated
//! A100/Llama-2-7B testbed with `retain_finished(false)` (slab slots
//! recycle; arena stays flat) and event capture off (streaming metrics
//! only), then reports:
//!
//! * engine iterations/sec and processed tokens/sec (wall clock);
//! * request-table lookup ns: slab arena vs the `HashMap` the seed used
//!   (the measured component baseline);
//! * windowed-timeseries build time: single-pass streaming histograms vs
//!   the seed's per-window filter + sort (measured in-process on the
//!   same sample set).
//!
//! Results are written to `BENCH_sched.json`. Scale with
//! `SCHED_BENCH_REQS` (default 100_000; CI smoke uses a small value).

use conserve::backend::{CostModel, SimBackend};
use conserve::clock::Clock;
use conserve::config::EngineConfig;
use conserve::metrics::percentile;
use conserve::profiler::LatencyProfile;
use conserve::request::{Class, Request, RequestArena, RequestId};
use conserve::server::{ArrivalSource, ServingEngine};
use conserve::util::json::{num, obj, Json};
use conserve::util::rng::Rng;
use conserve::workload::trace::onoff_trace;
use conserve::US_PER_SEC;
use std::collections::HashMap;
use std::time::Instant;

fn main() {
    let n_reqs: usize = std::env::var("SCHED_BENCH_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100_000);
    let n_online = n_reqs * 9 / 10;
    let n_offline = n_reqs - n_online;

    // ---- build the trace: gamma on/off online arrivals + offline pool ----
    let on_rate = 60.0; // sustainable on the simulated testbed at these lengths
    let phase_s = 30.0;
    let duration_s = 2.0 * n_online as f64 / on_rate;
    let arrivals = onoff_trace(42, duration_s, phase_s, on_rate, 2.0);
    let mut rng = Rng::new(7);
    let mut events: Vec<Request> = arrivals
        .iter()
        .take(n_online)
        .map(|&t| {
            let input = rng.range_usize(64, 256);
            let output = rng.range_usize(8, 24);
            Request::new(0, Class::Online, vec![], input, output, t)
        })
        .collect();
    for _ in 0..n_offline {
        let input = rng.range_usize(512, 2048);
        let output = rng.range_usize(32, 96);
        events.push(Request::new(0, Class::Offline, vec![], input, output, 0));
    }
    let n_events = events.len();

    // ---- run the engine, wall-clocked ----
    let cfg = EngineConfig::sim_a100_7b();
    let clock = Clock::virtual_at(0);
    let backend = SimBackend::new(
        CostModel::a100_llama2_7b(),
        clock.clone(),
        cfg.sched.safepoint_layers,
    );
    let profile = LatencyProfile {
        c: [1200.0, 96.0, 40.0, 0.385],
    };
    let mut engine = ServingEngine::new(
        cfg,
        backend,
        clock,
        profile,
        ArrivalSource::from_trace(events),
    );
    engine.set_retain_finished(false); // recycle slots: flat arena
    engine.rec.set_capture_events(false); // streaming aggregates only

    let until = ((duration_s * 4.0) * US_PER_SEC as f64) as u64;
    let t0 = Instant::now();
    let end = engine.run(until);
    let wall_s = t0.elapsed().as_secs_f64();

    let iters = engine.rec.engine_iters;
    let processed = engine.rec.processed_token_count(None);
    let generated = engine.rec.gen_token_count(None);
    let finished = engine.rec.finished[0] + engine.rec.finished[1];
    let iters_per_sec = iters as f64 / wall_s;
    let tokens_per_sec = processed as f64 / wall_s;

    println!("=== bench_sched_loop ({n_events} requests) ===");
    println!("sim time            {:>12.1} s", end as f64 / 1e6);
    println!("wall time           {:>12.2} s", wall_s);
    println!("engine iterations   {iters:>12}");
    println!("iterations/sec      {iters_per_sec:>12.0}");
    println!("processed tokens    {processed:>12}");
    println!("tokens/sec (wall)   {tokens_per_sec:>12.0}");
    println!("generated tokens    {generated:>12}");
    println!("finished requests   {finished:>12}");
    println!(
        "arena slots         {:>12}  (peak concurrency; flat despite {n_events} requests)",
        engine.table.slot_count()
    );
    assert!(
        engine.kv.check_conservation(),
        "KV conservation must hold after the full run"
    );

    // ---- component baseline A: table lookup, arena vs HashMap ----
    let mut arena = RequestArena::new();
    let mut map: HashMap<RequestId, Request> = HashMap::new();
    let mut ids = Vec::new();
    for i in 0..4096u64 {
        let id = arena.insert(Request::new(0, Class::Offline, vec![], 1024, 128, i));
        map.insert(id, Request::new(id, Class::Offline, vec![], 1024, 128, i));
        ids.push(id);
    }
    let lookup_ns = |f: &mut dyn FnMut(RequestId) -> usize| {
        let reps = 2_000_000usize;
        let mut acc = 0usize;
        let mut k = 0usize;
        let t = Instant::now();
        for _ in 0..reps {
            k = (k + 13) & 4095;
            acc = acc.wrapping_add(f(ids[k]));
        }
        std::hint::black_box(acc);
        t.elapsed().as_nanos() as f64 / reps as f64
    };
    let arena_ns = lookup_ns(&mut |id| arena.get(id).unwrap().ctx_len);
    let hashmap_ns = lookup_ns(&mut |id| map.get(&id).unwrap().ctx_len);
    println!("table lookup        {arena_ns:>9.1} ns arena vs {hashmap_ns:.1} ns hashmap ({:.2}x)",
        hashmap_ns / arena_ns);

    // ---- component baseline B: timeseries, streaming vs filter+sort ----
    let mut rec = conserve::metrics::Recorder::new();
    let mut rng = Rng::new(3);
    let span = 600 * US_PER_SEC;
    for _ in 0..200_000 {
        let t = rng.range(0, span);
        rec.record_first_token(t, Class::Online, 1_000 + rng.range(0, 2_000_000));
    }
    let window = 15 * US_PER_SEC;
    let t = Instant::now();
    let ts = rec.timeseries(Some(Class::Online), window, span);
    let streaming_ms = t.elapsed().as_secs_f64() * 1e3;
    // the seed algorithm: re-filter the event log per window, then a
    // copy + sort percentile per window
    let t = Instant::now();
    let mut naive = Vec::new();
    let mut start = 0u64;
    while start < span {
        let end_w = start + window;
        let ttfts: Vec<f64> = rec
            .ttfts
            .iter()
            .filter(|e| e.t >= start && e.t < end_w)
            .map(|e| e.ttft_us as f64 / 1000.0)
            .collect();
        let mut sorted = ttfts.clone();
        sorted.sort_by(f64::total_cmp);
        let rank = ((99.0 / 100.0) * sorted.len() as f64).ceil() as usize;
        let p99 = if sorted.is_empty() {
            0.0
        } else {
            sorted[rank.clamp(1, sorted.len()) - 1]
        };
        naive.push((ttfts.len(), p99));
        start = end_w;
    }
    let naive_ms = t.elapsed().as_secs_f64() * 1e3;
    assert_eq!(ts.len(), naive.len());
    for (s, (n, p99)) in ts.iter().zip(&naive) {
        assert_eq!(s.n_ttft, *n);
        let err = (s.p99_ttft_ms - p99).abs() / p99.max(1.0);
        assert!(err < 0.016, "window p99 drifted: {} vs {p99}", s.p99_ttft_ms);
    }
    println!(
        "timeseries build    {streaming_ms:>9.2} ms streaming vs {naive_ms:.2} ms filter+sort ({:.2}x)",
        naive_ms / streaming_ms
    );
    let _ = percentile(&[1.0], 50.0); // keep the exact-percentile path linked

    // ---- emit BENCH_sched.json ----
    let json = obj(vec![
        ("requests", num(n_events as f64)),
        ("sim_duration_s", num(end as f64 / 1e6)),
        ("wall_s", num(wall_s)),
        ("engine_iterations", num(iters as f64)),
        ("iters_per_sec", num(iters_per_sec)),
        ("processed_tokens", num(processed as f64)),
        ("tokens_per_sec_wall", num(tokens_per_sec)),
        ("finished_requests", num(finished as f64)),
        ("arena_slots", num(engine.table.slot_count() as f64)),
        (
            "baseline",
            obj(vec![
                ("table_lookup_ns_hashmap", num(hashmap_ns)),
                ("table_lookup_ns_arena", num(arena_ns)),
                ("table_lookup_speedup", num(hashmap_ns / arena_ns)),
                ("timeseries_ms_filter_sort", num(naive_ms)),
                ("timeseries_ms_streaming", num(streaming_ms)),
                ("timeseries_speedup", num(naive_ms / streaming_ms)),
            ]),
        ),
    ]);
    let out_path = std::env::var("SCHED_BENCH_OUT").unwrap_or_else(|_| "BENCH_sched.json".into());
    std::fs::write(&out_path, json.to_string()).expect("write BENCH_sched.json");
    println!("\nwrote {out_path}");
    let _ = Json::parse(&json.to_string()).expect("self-emitted json parses");
    println!("bench_sched_loop OK");
}
