//! Deterministic PRNG (PCG64-DXSM-ish split-mix core) plus the
//! distribution samplers the workload generator needs: exponential,
//! gamma (Marsaglia–Tsang), and normal (Box–Muller).
//!
//! Every experiment takes an explicit seed so benches and property tests
//! are reproducible bit-for-bit.

/// Splitmix64-seeded xoshiro256++ — small, fast, well-understood; quality
/// is far beyond what workload synthesis needs.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// One splitmix64 scramble as a pure function: a stateless 64-bit mixer
/// for deriving keys (per-request sampler draws, shard-stable hashes)
/// without threading an `Rng` through the call site.
pub fn mix64(x: u64) -> u64 {
    let mut s = x;
    splitmix64(&mut s)
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // (0, 1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (mean 1/rate).
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        -u.ln() / rate
    }

    /// Gamma(shape k, scale θ) via Marsaglia–Tsang (with the k < 1 boost).
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            // boosting: G(k) = G(k+1) * U^(1/k)
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64().max(f64::MIN_POSITIVE);
            if u.ln() < 0.5 * x * x + d - d * v3 + d * v3.ln() {
                return d * v3 * scale;
            }
        }
    }

    /// Inter-arrival sample for a gamma arrival process with mean rate
    /// `rate` (1/s) and coefficient of variation `cv` (paper §6.3.2:
    /// CV measures burstiness; CV = 1 is Poisson).
    pub fn gamma_interarrival(&mut self, rate: f64, cv: f64) -> f64 {
        let shape = 1.0 / (cv * cv);
        let scale = 1.0 / (rate * shape);
        self.gamma(shape, scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let xs: Vec<f64> = (0..20_000).map(|_| r.f64()).collect();
        let (mean, _) = stats(&xs);
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..40_000).map(|_| r.normal()).collect();
        let (mean, var) = stats(&xs);
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..40_000).map(|_| r.exp(2.0)).collect();
        let (mean, _) = stats(&xs);
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gamma_moments() {
        // Gamma(k, θ): mean kθ, var kθ².
        for &(k, t) in &[(0.25, 2.0), (1.0, 1.0), (4.0, 0.5), (9.0, 3.0)] {
            let mut r = Rng::new(4);
            let xs: Vec<f64> = (0..60_000).map(|_| r.gamma(k, t)).collect();
            let (mean, var) = stats(&xs);
            assert!((mean - k * t).abs() / (k * t) < 0.05, "k={k} mean={mean}");
            assert!(
                (var - k * t * t).abs() / (k * t * t) < 0.12,
                "k={k} var={var}"
            );
        }
    }

    #[test]
    fn gamma_interarrival_rate_and_cv() {
        // rate 2/s, CV 2 => mean gap 0.5s, std 1.0s.
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..60_000).map(|_| r.gamma_interarrival(2.0, 2.0)).collect();
        let (mean, var) = stats(&xs);
        assert!((mean - 0.5).abs() < 0.03, "mean={mean}");
        let cv = var.sqrt() / mean;
        assert!((cv - 2.0).abs() < 0.15, "cv={cv}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(6);
        for _ in 0..1000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }
}
