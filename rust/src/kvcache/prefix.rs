//! Cross-request prefix KV sharing: the admission-time trie.
//!
//! At production scale most traffic shares long common prefixes — system
//! prompts, few-shot templates, multi-turn history — yet without sharing
//! every request re-prefills and stores its own copy of that KV. This
//! module indexes already-resident **full** prompt blocks by a rolling
//! hash chain over their tokens, so admission can map a new prompt onto
//! blocks other requests already computed
//! ([`crate::kvcache::KvManager::prefix_attach`]) and skip their
//! prefill.
//!
//! The "trie" is flattened: because each block's hash chains over *all*
//! tokens before it, a single `hash -> block` map encodes exactly the
//! trie of block-granular prefixes — matching hashes imply matching
//! whole prefixes (modulo 64-bit collisions), so walking chain hashes
//! left-to-right until the first miss *is* the trie descent, without
//! child pointers.
//!
//! The index also folds its hashes into a compact 512-bit membership
//! digest that [`ShardLoads`](crate::shard::ShardLoads) publishes, so
//! the router can score shards by how much of a prompt's prefix is
//! already resident there (prefix-affinity placement) with eight words
//! per shard and no cross-thread chatter.

use std::collections::{HashMap, VecDeque};

use super::BlockId;
use crate::request::TokenId;
use crate::util::rng::mix64;

/// Words in the per-shard prefix membership digest (8 × 64 = 512 bits).
pub const PREFIX_DIGEST_WORDS: usize = 8;
const DIGEST_BITS: u64 = (PREFIX_DIGEST_WORDS * 64) as u64;

/// Hash-chain seed. Any fixed constant works; sharing only requires that
/// every shard chains identically.
pub const PREFIX_SEED: u64 = 0x436f_6e53_6572_7665; // "ConServe"

/// Extend a rolling prefix hash by one token. The `+ 1` keeps token 0
/// from being an identity fold.
#[inline]
pub fn chain_hash(prev: u64, tok: TokenId) -> u64 {
    mix64(prev ^ (tok as u64 + 1))
}

/// Hash of each full-block prefix of `prompt` (block `i`'s hash covers
/// tokens `0..(i+1)*block_tokens`), capped at `cap` blocks. These are
/// the probes the router tests against shard digests, and exactly the
/// keys [`crate::kvcache::KvManager::prefix_attach`] walks — the two
/// sides cannot drift.
pub fn prefix_probes(prompt: &[TokenId], block_tokens: usize, cap: usize) -> Vec<u64> {
    let full = (prompt.len() / block_tokens).min(cap);
    let mut out = Vec::with_capacity(full);
    let mut h = PREFIX_SEED;
    for blk in 0..full {
        for &t in &prompt[blk * block_tokens..(blk + 1) * block_tokens] {
            h = chain_hash(h, t);
        }
        out.push(h);
    }
    out
}

/// Fold a prefix hash into a membership digest.
#[inline]
pub fn digest_insert(digest: &mut [u64; PREFIX_DIGEST_WORDS], h: u64) {
    let bit = h % DIGEST_BITS;
    digest[(bit / 64) as usize] |= 1u64 << (bit % 64);
}

/// May the digest contain `h`? One-sided like any Bloom-style filter:
/// false means definitely absent; true means probably present.
#[inline]
pub fn digest_contains(digest: &[u64; PREFIX_DIGEST_WORDS], h: u64) -> bool {
    let bit = h % DIGEST_BITS;
    digest[(bit / 64) as usize] & (1u64 << (bit % 64)) != 0
}

/// The per-shard prefix index: `hash -> resident GPU block`, plus the
/// reclaim queue, hit accounting, and the lazily-recomputed digest.
///
/// The index *owns one reference* on every block it maps (taken by
/// [`crate::kvcache::KvManager::prefix_publish`]), so an indexed block
/// outlives its publisher and can seed later requests; pool pressure
/// takes cache-only blocks back through [`Self::reclaim`] — never
/// blocks a live sequence still references.
#[derive(Debug, Default)]
pub struct PrefixIndex {
    entries: HashMap<u64, BlockId>,
    /// Hashes in insertion order — the reclaim scan order. May briefly
    /// hold re-queued duplicates of hot entries; `entries` is the source
    /// of truth.
    order: VecDeque<u64>,
    hits: u64,
    lookups: u64,
    /// Cumulative blocks freed by [`Self::reclaim`] (observability:
    /// the engine emits a `PrefixReclaim` trace event on each delta).
    reclaimed: u64,
    digest: [u64; PREFIX_DIGEST_WORDS],
    dirty: bool,
}

impl PrefixIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Indexed blocks (each holding one cache reference).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, h: u64) -> Option<BlockId> {
        self.entries.get(&h).copied()
    }

    /// Index `h -> b`. First publisher wins; the caller must have taken
    /// the cache's reference on `b` before inserting.
    pub fn insert(&mut self, h: u64, b: BlockId) {
        if self.entries.insert(h, b).is_none() {
            self.order.push_back(h);
        }
        self.dirty = true;
    }

    pub fn record_lookup(&mut self) {
        self.lookups += 1;
    }

    pub fn record_hit(&mut self) {
        self.hits += 1;
    }

    /// Cumulative (hits, lookups) of admission-time attachment.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.lookups)
    }

    /// Cumulative blocks freed by [`Self::reclaim`].
    pub fn reclaimed_blocks(&self) -> u64 {
        self.reclaimed
    }

    /// Membership digest over the indexed hashes, recomputed only when
    /// the index changed since the last call.
    pub fn digest(&mut self) -> [u64; PREFIX_DIGEST_WORDS] {
        if self.dirty {
            self.digest = [0; PREFIX_DIGEST_WORDS];
            for h in self.entries.keys() {
                digest_insert(&mut self.digest, *h);
            }
            self.dirty = false;
        }
        self.digest
    }

    /// Iterate the indexed blocks (conservation checks).
    pub fn blocks(&self) -> impl Iterator<Item = BlockId> + '_ {
        self.entries.values().copied()
    }

    /// Drop up to `need` entries whose block `can_free` accepts (the
    /// manager passes "refcount is exactly the cache's own reference"),
    /// oldest first; entries still shared with live sequences are
    /// re-queued, not torn. Returns how many were freed.
    pub fn reclaim(&mut self, need: usize, mut can_free: impl FnMut(BlockId) -> bool) -> usize {
        let mut freed = 0;
        for _ in 0..self.order.len() {
            if freed >= need {
                break;
            }
            let Some(h) = self.order.pop_front() else {
                break;
            };
            let Some(&b) = self.entries.get(&h) else {
                continue; // stale queue slot from a re-queue
            };
            if can_free(b) {
                self.entries.remove(&h);
                self.dirty = true;
                freed += 1;
            } else {
                self.order.push_back(h);
            }
        }
        self.reclaimed += freed as u64;
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_hash_is_positional() {
        // same multiset, different order => different chains
        let a = chain_hash(chain_hash(PREFIX_SEED, 1), 2);
        let b = chain_hash(chain_hash(PREFIX_SEED, 2), 1);
        assert_ne!(a, b);
        // deterministic
        assert_eq!(a, chain_hash(chain_hash(PREFIX_SEED, 1), 2));
    }

    #[test]
    fn probes_cover_full_blocks_only() {
        let prompt: Vec<TokenId> = (0..40).map(|i| i as TokenId).collect();
        let probes = prefix_probes(&prompt, 16, 8);
        assert_eq!(probes.len(), 2, "40 tokens = 2 full 16-token blocks");
        assert_eq!(prefix_probes(&prompt, 16, 1).len(), 1, "cap respected");
        // probe i is the chain through block i — extending the prompt
        // does not change earlier probes (prefix property)
        let longer: Vec<TokenId> = (0..64).map(|i| i as TokenId).collect();
        assert_eq!(prefix_probes(&longer, 16, 8)[..2], probes[..]);
        assert!(prefix_probes(&prompt[..16], 16, 8).len() == 1);
        assert!(prefix_probes(&prompt[..15], 16, 8).is_empty());
    }

    #[test]
    fn digest_membership_is_one_sided() {
        let mut d = [0u64; PREFIX_DIGEST_WORDS];
        assert!(!digest_contains(&d, 12345));
        digest_insert(&mut d, 12345);
        assert!(digest_contains(&d, 12345));
        // inserted hashes are always found (no false negatives)
        let mut d2 = [0u64; PREFIX_DIGEST_WORDS];
        for h in 0..1000u64 {
            digest_insert(&mut d2, mix64(h));
        }
        for h in 0..1000u64 {
            assert!(digest_contains(&d2, mix64(h)));
        }
    }

    #[test]
    fn reclaim_skips_refused_blocks_and_keeps_order() {
        let mut idx = PrefixIndex::new();
        for (h, b) in [(10u64, 0u32), (20, 1), (30, 2)] {
            idx.insert(h, b);
        }
        // block 1 is "still shared": refused, re-queued, survives
        let freed = idx.reclaim(3, |b| b != 1);
        assert_eq!(freed, 2);
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.get(20), Some(1));
        // once releasable, a later pass takes it
        assert_eq!(idx.reclaim(1, |_| true), 1);
        assert!(idx.is_empty());
    }

    #[test]
    fn insert_is_idempotent_on_hash() {
        let mut idx = PrefixIndex::new();
        idx.insert(7, 3);
        idx.insert(7, 3);
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.reclaim(8, |_| true), 1, "no duplicate queue entries freed");
    }
}
