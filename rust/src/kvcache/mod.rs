//! Paged KV-cache management (vLLM-style block tables) plus the paper's
//! two memory contributions: **incremental checkpointing** (§4.4) and the
//! **bandwidth-metered asynchronous swap engine** that overlaps
//! checkpoint/prefetch I/O with compute.
//!
//! Accounting and policy live here; the actual KV *data* lives in the
//! execution backend (dense slabs on the real path, nothing in the
//! simulator). The scheduler drives this module; it never touches
//! device buffers directly.

pub mod checkpoint;
pub mod manager;
pub mod prefix;
pub mod swap;

pub type BlockId = u32;

pub use checkpoint::CkptController;
pub use manager::{KvManager, SeqKv};
pub use prefix::{prefix_probes, PrefixIndex, PREFIX_DIGEST_WORDS};
pub use swap::{Direction, SwapEngine, SwapOp};
