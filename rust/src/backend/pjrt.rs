//! Real execution backend: runs the AOT-compiled layered model through
//! the PJRT CPU client (`xla` crate). Python is never invoked — the HLO
//! text artifacts were produced once by `make artifacts`.
//!
//! ## Execution shape
//!
//! An [`IterationPlan`] is partitioned into *sub-batches* of uniform
//! chunk bucket (decode = the T=1 bucket), each padded up to a batch
//! bucket. Every sub-batch runs `embed -> layer x n_layers -> head`; the
//! per-layer executables give the engine a natural **safepoint** between
//! layer groups (paper §4.3) — the preemption flag is checked there and
//! the whole iteration's partial work can be discarded (commit happens
//! only after the head).
//!
//! ## KV residency
//!
//! Each sequence owns dense per-layer slabs ([Hkv, S, Dh] f32) — the
//! "GPU" copy. Checkpoints copy block-granular slices into a host mirror
//! slab; eviction drops the GPU slab; prefetch restores it. On this CPU
//! testbed both live in host RAM, but the copies are real, so the
//! checkpoint/prefetch data path is exercised end to end.

use super::{ExecBackend, ExecOutcome, HostKvBlob, IterationPlan, PlanSummary, SafepointAction};
use crate::clock::Clock;
use crate::request::{Class, Phase, RequestId, TokenId};
use crate::runtime::artifacts::{f32_literal, i32_literal, Artifacts, EntryKey, EntryKind};
use crate::runtime::sampler::Sampler;
use crate::util::bucket_up;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Per-sequence dense KV storage (one slab per layer per K/V).
struct KvSlab {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl KvSlab {
    fn zeros(n_layers: usize, elems: usize) -> Self {
        Self {
            k: vec![vec![0.0; elems]; n_layers],
            v: vec![vec![0.0; elems]; n_layers],
        }
    }
}

pub struct PjrtBackend {
    art: Artifacts,
    clock: Clock,
    sampler: Sampler,
    slabs: HashMap<RequestId, KvSlab>,
    mirrors: HashMap<RequestId, KvSlab>,
    safepoint_layers: usize,
    /// Modeled PCIe bandwidth for swap pacing (bytes/s). The tiny model's
    /// 64 KB blocks would be invisible at real PCIe speed; a smaller
    /// default keeps I/O time on the same scale as tiny-model compute so
    /// the overlap machinery is observable (DESIGN.md §Substitutions).
    pub modeled_link_bw: u64,
    /// Surrogate distributed-barrier cost charged per safepoint when
    /// estimating (the in-process check itself is ~ns; a multi-worker
    /// deployment pays a collective barrier — §6.4.2 measured 988 µs).
    pub safepoint_surrogate_us: u64,
    probe_seq: RequestId,
}

impl PjrtBackend {
    pub fn load(artifact_dir: &str, seed: u64, safepoint_layers: usize) -> Result<Self> {
        let art = Artifacts::load(artifact_dir)?;
        let sp = safepoint_layers.clamp(1, art.dims.n_layers);
        Ok(Self {
            art,
            clock: Clock::real(),
            sampler: Sampler::new(seed, 0.8),
            slabs: HashMap::new(),
            mirrors: HashMap::new(),
            safepoint_layers: sp,
            modeled_link_bw: 256 << 20, // 256 MB/s
            safepoint_surrogate_us: 100,
            probe_seq: 1 << 62,
        })
    }

    pub fn dims(&self) -> crate::runtime::artifacts::ModelDims {
        self.art.dims
    }

    pub fn clock(&self) -> Clock {
        self.clock.clone()
    }

    pub fn artifacts(&self) -> &Artifacts {
        &self.art
    }

    /// Test hook: drop only the device slab (simulates GPU eviction
    /// without the engine; prefetch restores from the host mirror).
    pub fn wipe_device_slab(&mut self, req: RequestId) {
        self.slabs.remove(&req);
    }

    /// Sampling temperature (0.0 = greedy argmax).
    pub fn set_temperature(&mut self, t: f32) {
        self.sampler.temperature = t;
    }

    /// Partition plan items into (batch_bucket, chunk_bucket, item
    /// indices) sub-batches.
    fn partition(&self, plan: &IterationPlan) -> Vec<(usize, usize, Vec<usize>)> {
        let mut by_chunk: HashMap<usize, Vec<usize>> = HashMap::new();
        for (i, item) in plan.items.iter().enumerate() {
            let tb = bucket_up(&self.art.chunk_buckets, item.n_tokens);
            by_chunk.entry(tb).or_default().push(i);
        }
        let max_b = *self.art.batch_buckets.last().unwrap();
        let mut subs = Vec::new();
        let mut chunks: Vec<_> = by_chunk.into_iter().collect();
        chunks.sort_by_key(|(t, _)| *t);
        for (tb, idxs) in chunks {
            for group in idxs.chunks(max_b) {
                let bb = bucket_up(&self.art.batch_buckets, group.len());
                subs.push((bb, tb, group.to_vec()));
            }
        }
        subs
    }

    /// Assemble and run one sub-batch; returns per-item sampled tokens
    /// and the updated KV literals to commit. `None` if aborted.
    #[allow(clippy::too_many_arguments)]
    fn run_sub_batch(
        &mut self,
        plan: &IterationPlan,
        bb: usize,
        tb: usize,
        idxs: &[usize],
        preemptible: bool,
        global_layer: &mut usize,
        checks: &mut usize,
        safepoint: &mut dyn FnMut(crate::TimeUs) -> SafepointAction,
    ) -> Result<Option<Vec<(usize, TokenId, Vec<xla::Literal>, Vec<xla::Literal>)>>> {
        let dims = self.art.dims;
        let (s, dh, hkv) = (dims.max_seq, dims.head_dim, dims.n_kv_heads);
        let slab_elems = dims.slab_elems();

        // ---- assemble inputs ----
        let mut tokens = vec![0i32; bb * tb];
        let mut ctx = vec![0i32; bb];
        for (row, &i) in idxs.iter().enumerate() {
            let item = &plan.items[i];
            debug_assert!(item.ctx_len + tb <= s, "chunk overruns cache");
            for (j, &t) in plan.tokens_of(item).iter().enumerate() {
                tokens[row * tb + j] = t as i32;
            }
            ctx[row] = item.ctx_len as i32;
        }
        let tokens_lit = i32_literal(&tokens, &[bb, tb])?;
        let ctx_lit = i32_literal(&ctx, &[bb])?;

        // KV gather: rows for real items come from their slabs
        let mut k_batches: Vec<Vec<f32>> = Vec::with_capacity(dims.n_layers);
        let mut v_batches: Vec<Vec<f32>> = Vec::with_capacity(dims.n_layers);
        for l in 0..dims.n_layers {
            let mut kb = vec![0.0f32; bb * slab_elems];
            let mut vb = vec![0.0f32; bb * slab_elems];
            for (row, &i) in idxs.iter().enumerate() {
                let req = plan.items[i].req;
                if let Some(slab) = self.slabs.get(&req) {
                    kb[row * slab_elems..(row + 1) * slab_elems]
                        .copy_from_slice(&slab.k[l]);
                    vb[row * slab_elems..(row + 1) * slab_elems]
                        .copy_from_slice(&slab.v[l]);
                }
            }
            k_batches.push(kb);
            v_batches.push(vb);
        }

        // ---- embed ----
        let embed_key = EntryKey {
            kind: EntryKind::Embed,
            batch: bb,
            chunk: tb,
        };
        let embedding = self.art.weight("embedding").clone();
        let exe = self.art.executable(embed_key)?;
        let out = exe
            .execute::<xla::Literal>(&[tokens_lit, embedding])
            .map_err(|e| anyhow!("embed exec: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("embed fetch: {e}"))?;
        let mut hidden = out.to_tuple1().map_err(|e| anyhow!("embed tuple: {e}"))?;

        // ---- layers with safepoints ----
        let mut new_k: Vec<xla::Literal> = Vec::with_capacity(dims.n_layers);
        let mut new_v: Vec<xla::Literal> = Vec::with_capacity(dims.n_layers);
        for l in 0..dims.n_layers {
            if preemptible && *global_layer > 0 && *global_layer % self.safepoint_layers == 0
            {
                *checks += 1;
                if safepoint(self.clock.now()) == SafepointAction::Abort {
                    return Ok(None);
                }
            }
            *global_layer += 1;

            let kc = f32_literal(&k_batches[l], &[bb, hkv, s, dh])?;
            let vc = f32_literal(&v_batches[l], &[bb, hkv, s, dh])?;
            let weights: Vec<xla::Literal> = self
                .art
                .layer_weights(l)
                .into_iter()
                .cloned()
                .collect();
            let mut args: Vec<xla::Literal> = vec![hidden, kc, vc, ctx_lit.clone()];
            args.extend(weights);

            let key = EntryKey {
                kind: EntryKind::Layer,
                batch: bb,
                chunk: tb,
            };
            let exe = self.art.executable(key)?;
            let out = exe
                .execute::<xla::Literal>(&args)
                .map_err(|e| anyhow!("layer {l} exec: {e}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("layer {l} fetch: {e}"))?;
            let (h, k, v) = out
                .to_tuple3()
                .map_err(|e| anyhow!("layer {l} tuple: {e}"))?;
            hidden = h;
            new_k.push(k);
            new_v.push(v);
        }

        // ---- head + sampling ----
        let head_key = EntryKey {
            kind: EntryKind::Head,
            batch: bb,
            chunk: tb,
        };
        let final_norm = self.art.weight("final_norm").clone();
        let lm_head = self.art.weight("lm_head").clone();
        let exe = self.art.executable(head_key)?;
        let out = exe
            .execute::<xla::Literal>(&[hidden, final_norm, lm_head])
            .map_err(|e| anyhow!("head exec: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("head fetch: {e}"))?;
        let logits_lit = out.to_tuple1().map_err(|e| anyhow!("head tuple: {e}"))?;
        let logits: Vec<f32> = logits_lit
            .to_vec()
            .map_err(|e| anyhow!("logits fetch: {e}"))?;
        let vocab = dims.vocab_size;

        let mut results = Vec::with_capacity(idxs.len());
        for (row, &i) in idxs.iter().enumerate() {
            let item = &plan.items[i];
            let t_idx = item.n_tokens - 1; // last real token position
            let off = (row * tb + t_idx) * vocab;
            // keyed draw: the token for this request position is the same
            // on any shard and under any chunking (migration-safe)
            let tok = self
                .sampler
                .sample_keyed(&logits[off..off + vocab], item.sample_key);
            // split the per-row updated KV out of the batch literals at
            // commit time (cheaper: keep literals, slice in commit)
            results.push((i, tok, Vec::new(), Vec::new()));
        }

        // Commit KV: copy the new token slots back into slabs.
        for l in 0..dims.n_layers {
            let kv: Vec<f32> = new_k[l].to_vec().map_err(|e| anyhow!("k fetch: {e}"))?;
            let vv: Vec<f32> = new_v[l].to_vec().map_err(|e| anyhow!("v fetch: {e}"))?;
            for (row, &i) in idxs.iter().enumerate() {
                let item = &plan.items[i];
                let req = item.req;
                let slab = self
                    .slabs
                    .entry(req)
                    .or_insert_with(|| KvSlab::zeros(dims.n_layers, slab_elems));
                // copy slots [ctx, ctx + n_tokens) per KV head
                for h in 0..hkv {
                    let base = row * slab_elems + h * s * dh;
                    let sbase = h * s * dh;
                    let lo = item.ctx_len * dh;
                    let hi = (item.ctx_len + item.n_tokens) * dh;
                    slab.k[l][sbase + lo..sbase + hi]
                        .copy_from_slice(&kv[base + lo..base + hi]);
                    slab.v[l][sbase + lo..sbase + hi]
                        .copy_from_slice(&vv[base + lo..base + hi]);
                }
            }
        }
        Ok(Some(results))
    }
}

impl ExecBackend for PjrtBackend {
    fn execute(
        &mut self,
        plan: &IterationPlan,
        safepoint: &mut dyn FnMut(crate::TimeUs) -> SafepointAction,
    ) -> Result<ExecOutcome> {
        let start = self.clock.now();
        let subs = self.partition(plan);
        let mut new_tokens: Vec<Option<TokenId>> = vec![None; plan.items.len()];
        let mut checks = 0usize;
        let mut global_layer = 0usize;

        for (bb, tb, idxs) in subs {
            match self.run_sub_batch(
                plan,
                bb,
                tb,
                &idxs,
                plan.preemptible,
                &mut global_layer,
                &mut checks,
                safepoint,
            )? {
                Some(results) => {
                    for (i, tok, _, _) in results {
                        new_tokens[i] = Some(tok);
                    }
                }
                None => {
                    // aborted: partial work discarded. Sub-batches that
                    // already committed keep their KV (their ctx commit is
                    // decided by the engine, which treats the iteration as
                    // aborted and does not advance any request).
                    return Ok(ExecOutcome {
                        completed: false,
                        new_tokens: vec![None; plan.items.len()],
                        elapsed_us: self.clock.now() - start,
                        safepoint_checks: checks + 1,
                    });
                }
            }
        }

        Ok(ExecOutcome {
            completed: true,
            new_tokens,
            elapsed_us: self.clock.now() - start,
            safepoint_checks: checks,
        })
    }

    fn probe_us(&mut self, s: &PlanSummary) -> u64 {
        // Build a synthetic plan matching the summary shape and measure.
        let dims = self.art.dims;
        let mut plan = IterationPlan::default();
        let mut id = self.probe_seq;
        let max_chunk = *self.art.chunk_buckets.last().unwrap();
        let mut rem = s.prefill_tokens;
        let mut toks: Vec<TokenId> = Vec::new();
        while rem > 0 {
            let n = rem.min(max_chunk);
            toks.clear();
            toks.extend((0..n).map(|i| (i % 251) as TokenId));
            plan.push_item(id, Class::Offline, Phase::Prefill, 0, n, &toks);
            id += 1;
            rem -= n;
        }
        let per_ctx = if s.decode_seqs > 0 {
            (s.ctx_tokens / s.decode_seqs).min(dims.max_seq - 1).max(1)
        } else {
            0
        };
        for _ in 0..s.decode_seqs {
            plan.push_item(id, Class::Offline, Phase::Decode, per_ctx, 1, &[7]);
            id += 1;
        }
        let first_probe = self.probe_seq;
        self.probe_seq = id;
        // Warm-up run absorbs lazy HLO compilation (first use of a
        // bucket), then take the min of repeated measurements — CPU
        // timing is noisy and the profiler fit needs clean slopes.
        let _ = self.execute(&plan, &mut |_| SafepointAction::Continue);
        let mut best = u64::MAX;
        for _ in 0..3 {
            for r in first_probe..id {
                self.drop_request(r);
            }
            let t0 = std::time::Instant::now();
            let _ = self.execute(&plan, &mut |_| SafepointAction::Continue);
            best = best.min(t0.elapsed().as_micros() as u64);
        }
        for r in first_probe..id {
            self.drop_request(r);
        }
        best
    }

    fn drop_request(&mut self, req: RequestId) {
        self.slabs.remove(&req);
        self.mirrors.remove(&req);
    }

    fn evict_device(&mut self, req: RequestId) {
        self.slabs.remove(&req);
    }

    fn copy_block_d2h(&mut self, req: RequestId, block_idx: usize, block_tokens: usize) {
        let dims = self.art.dims;
        let elems = dims.slab_elems();
        let Some(slab) = self.slabs.get(&req) else {
            return;
        };
        // split-borrow: temporarily take the mirror out
        let mut mirror = self
            .mirrors
            .remove(&req)
            .unwrap_or_else(|| KvSlab::zeros(dims.n_layers, elems));
        copy_block(slab, &mut mirror, dims, block_idx, block_tokens);
        self.mirrors.insert(req, mirror);
    }

    fn copy_block_h2d(&mut self, req: RequestId, block_idx: usize, block_tokens: usize) {
        let dims = self.art.dims;
        let elems = dims.slab_elems();
        let Some(mirror) = self.mirrors.remove(&req) else {
            return;
        };
        let mut slab = self
            .slabs
            .remove(&req)
            .unwrap_or_else(|| KvSlab::zeros(dims.n_layers, elems));
        copy_block(&mirror, &mut slab, dims, block_idx, block_tokens);
        self.slabs.insert(req, slab);
        self.mirrors.insert(req, mirror);
    }

    fn export_host_kv(&mut self, req: RequestId) -> Option<HostKvBlob> {
        // the mirror *moves* with the migrating request — the donor keeps
        // no copy, exactly like freeing the accounting-side host blocks
        self.mirrors
            .remove(&req)
            .map(|s| HostKvBlob { k: s.k, v: s.v })
    }

    fn import_host_kv(&mut self, req: RequestId, blob: HostKvBlob) {
        self.mirrors.insert(
            req,
            KvSlab {
                k: blob.k,
                v: blob.v,
            },
        );
    }

    fn block_bytes(&self) -> u64 {
        self.art.dims.kv_bytes_per_token() * 16
    }

    fn link_bandwidth(&self) -> u64 {
        self.modeled_link_bw
    }

    fn safepoint_cost_us(&self) -> u64 {
        self.safepoint_surrogate_us
    }

    fn n_layer_groups(&self) -> usize {
        self.art.dims.n_layers.div_ceil(self.safepoint_layers)
    }
}

fn copy_block(
    src: &KvSlab,
    dst: &mut KvSlab,
    dims: crate::runtime::artifacts::ModelDims,
    block_idx: usize,
    block_tokens: usize,
) {
    let (s, dh) = (dims.max_seq, dims.head_dim);
    let lo_slot = (block_idx * block_tokens).min(s);
    let hi_slot = ((block_idx + 1) * block_tokens).min(s);
    if lo_slot >= hi_slot {
        return;
    }
    for l in 0..dims.n_layers {
        for h in 0..dims.n_kv_heads {
            let base = h * s * dh;
            let lo = base + lo_slot * dh;
            let hi = base + hi_slot * dh;
            dst.k[l][lo..hi].copy_from_slice(&src.k[l][lo..hi]);
            dst.v[l][lo..hi].copy_from_slice(&src.v[l][lo..hi]);
        }
    }
}
