//! `bench_harvest` — closed-loop harvest controller frontier bench.
//!
//! Sweeps static offline token budgets against the adaptive controller
//! (`conserve::scheduler::harvest`) on a shared flash-crowd trace:
//! steady online load with one 3x burst mid-run and a deep offline pool
//! submitted at t=0. Layerwise preemption is off, so the offline budget
//! is the lever that bounds how long an online arrival waits behind a
//! running offline batch — the regime the controller exists for.
//!
//! Each point reports two axes:
//!
//! * **online SLO attainment** — `1 - ttft_violation_rate` at the
//!   paper's 1.5s online TTFT SLO;
//! * **offline harvest** — offline processed throughput (tok/s).
//!
//! Acceptance (asserted here):
//!
//! * the controller decided at least once, in both directions
//!   (tighten under the burst, open in the troughs);
//! * **frontier** — no static point strictly dominates the controller:
//!   for every static budget `s`, NOT
//!   (`s.attain > ctl.attain + 0.01` AND
//!   `s.offline_tput > ctl.offline_tput * 1.05`). A static point may
//!   beat the controller on one axis (tight wins attainment, open wins
//!   harvest) but never on both — that trade-off is the controller's
//!   whole job.
//!
//! Results go to `BENCH_harvest.json` (schema: rust/PERF.md §9).
//! Scale with `HARVEST_BENCH_SECS` (trace seconds, default 150).

use conserve::config::EngineConfig;
use conserve::report::{Report, SimExperiment};
use conserve::util::json::{arr, num, obj, Json};
use conserve::workload::{flash_crowd_trace, Lengths};

const SEED: u64 = 0x5B1CE;
const BASE_RATE: f64 = 2.0;
const BURST_MULT: f64 = 3.0;
/// Attainment slack: a static point must beat the controller by more
/// than one percentage point to count as better on the online axis.
const EPS_ATTAIN: f64 = 0.01;
/// Harvest slack: and by more than 5% on the offline axis.
const EPS_TPUT: f64 = 0.05;

/// Base config for every point: simulated A100-7B, layerwise off.
fn base_cfg() -> EngineConfig {
    let mut cfg = EngineConfig::sim_a100_7b();
    cfg.sched.layerwise_preempt = false;
    cfg
}

/// The shared spike workload, scaled to `secs` (burst in the middle,
/// offline pool sized so work outlasts the run).
fn experiment(cfg: EngineConfig, secs: f64) -> SimExperiment {
    let burst_start = 0.5 * secs;
    let burst_len = (0.15 * secs).max(5.0);
    SimExperiment {
        cfg,
        online_arrivals: flash_crowd_trace(
            SEED,
            secs,
            BASE_RATE,
            burst_start,
            burst_len,
            BURST_MULT,
            1.0,
        ),
        online_lengths: Lengths::online_paper(),
        offline_pool: (secs * 4.0 / 3.0).ceil() as usize,
        offline_lengths: Lengths::offline_paper(),
        duration_s: secs,
    }
}

struct Point {
    label: String,
    attain: f64,
    offline_tput: f64,
    report: Report,
}

impl Point {
    fn from_report(label: String, report: Report) -> Self {
        Self {
            label,
            attain: 1.0 - report.ttft_violations,
            offline_tput: report.offline_processed_tput,
            report,
        }
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("slo_attainment", num(self.attain)),
            ("offline_processed_tput", num(self.offline_tput)),
            ("ttft_violation_rate", num(self.report.ttft_violations)),
            ("online_finished", num(self.report.online_finished as f64)),
            ("offline_finished", num(self.report.offline_finished as f64)),
            ("harvest_decisions", num(self.report.harvest_decisions as f64)),
            ("harvest_tightens", num(self.report.harvest_tightens as f64)),
            ("harvest_opens", num(self.report.harvest_opens as f64)),
        ])
    }
}

fn run_static(budget: usize, secs: f64) -> Point {
    let mut cfg = base_cfg();
    cfg.sched.max_batch_tokens = budget;
    let report = experiment(cfg, secs).run();
    Point::from_report(format!("static_{budget}"), report)
}

fn run_controller(secs: f64) -> Point {
    let mut cfg = base_cfg();
    cfg.sched.harvest = true;
    let report = experiment(cfg, secs).run();
    Point::from_report("controller".to_string(), report)
}

fn main() {
    let secs: f64 = std::env::var("HARVEST_BENCH_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(150.0);
    let base = base_cfg();
    let budgets = [base.sched.min_chunk, 1024, base.sched.max_batch_tokens];
    println!(
        "=== bench_harvest ({secs:.0}s flash-crowd trace, {BASE_RATE} req/s x{BURST_MULT} \
         burst, static budgets {budgets:?} vs controller) ==="
    );

    let statics: Vec<Point> = budgets.iter().map(|&b| run_static(b, secs)).collect();
    let ctl = run_controller(secs);
    for p in statics.iter().chain(std::iter::once(&ctl)) {
        println!(
            "{:>14}: attainment {:.4}, offline {:.0} tok/s, {} decisions \
             ({} tighten / {} open)",
            p.label,
            p.attain,
            p.offline_tput,
            p.report.harvest_decisions,
            p.report.harvest_tightens,
            p.report.harvest_opens
        );
    }

    // ---- acceptance ----
    assert!(ctl.report.harvest_decisions > 0, "controller never decided");
    assert!(
        ctl.report.harvest_opens > 0,
        "calm stretches of the trace must open the budget"
    );
    let mut frontier_ok = true;
    for s in &statics {
        let dominates = s.attain > ctl.attain + EPS_ATTAIN
            && s.offline_tput > ctl.offline_tput * (1.0 + EPS_TPUT);
        if dominates {
            frontier_ok = false;
            println!(
                "FRONTIER VIOLATION: {} dominates the controller \
                 (attain {:.4} > {:.4}+{EPS_ATTAIN}, offline {:.0} > {:.0}*{:.2})",
                s.label,
                s.attain,
                ctl.attain,
                s.offline_tput,
                ctl.offline_tput,
                1.0 + EPS_TPUT
            );
        }
    }

    // ---- emit BENCH_harvest.json (schema: rust/PERF.md §9) ----
    let json = obj(vec![
        ("trace_secs", num(secs)),
        ("base_rate", num(BASE_RATE)),
        ("burst_mult", num(BURST_MULT)),
        ("eps_attain", num(EPS_ATTAIN)),
        ("eps_tput", num(EPS_TPUT)),
        ("statics", arr(statics.iter().map(Point::to_json))),
        ("controller", ctl.to_json()),
        ("controller_attainment", num(ctl.attain)),
        ("controller_offline_tput", num(ctl.offline_tput)),
        ("frontier_ok", num(f64::from(u8::from(frontier_ok)))),
    ]);
    let out_path =
        std::env::var("HARVEST_BENCH_OUT").unwrap_or_else(|_| "BENCH_harvest.json".into());
    std::fs::write(&out_path, json.to_string()).expect("write BENCH_harvest.json");
    println!("\nwrote {out_path}");
    let _ = Json::parse(&json.to_string()).expect("self-emitted json parses");
    assert!(
        frontier_ok,
        "a static budget strictly dominates the controller (see above)"
    );
    println!("bench_harvest OK");
}
