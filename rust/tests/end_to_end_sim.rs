//! End-to-end tests of the full serving engine on the simulated testbed:
//! the whole stack (arrivals -> scheduler -> backend -> checkpointing ->
//! metrics) under each policy, with behavioural assertions matching the
//! paper's qualitative claims.

use conserve::config::EngineConfig;
use conserve::report::SimExperiment;
use conserve::scheduler::Policy;
use conserve::workload::trace::onoff_trace;
use conserve::workload::{Lengths, LoadGen};

fn arrivals(seed: u64, rate: f64, cv: f64, dur: f64) -> Vec<u64> {
    LoadGen::new(seed, rate, cv).arrivals_until(dur)
}

fn experiment(policy: Policy, dur: f64, online: Vec<u64>, pool: usize) -> SimExperiment {
    let mut cfg = EngineConfig::sim_a100_7b();
    cfg.sched.policy = policy;
    if policy == Policy::VllmPP {
        cfg.sched.slo_aware = false;
        cfg.sched.incremental_ckpt = false;
        cfg.sched.prefetch = false;
        cfg.sched.layerwise_preempt = false;
    }
    SimExperiment {
        cfg,
        online_arrivals: online,
        online_lengths: Lengths::Fixed {
            input: 1024,
            output: 128,
        },
        offline_pool: pool,
        // shorter outputs than the paper's pool so offline *completions*
        // (not just throughput) are observable within test-scale runs
        offline_lengths: Lengths::OfflineDocs {
            min_input: 1024,
            max_input: 4096,
            max_output: 128,
        },
        duration_s: dur,
    }
}

#[test]
fn online_only_serves_all_online() {
    let online = arrivals(1, 2.0, 1.0, 60.0);
    let n = online.len() as u64;
    let r = experiment(Policy::OnlineOnly, 60.0, online, 0).run();
    assert!(r.online_finished >= n.saturating_sub(3), "{} of {n}", r.online_finished);
    assert_eq!(r.offline_finished, 0);
    assert!(r.online_p99_ttft_ms < 1500.0);
    assert!(r.online_p99_tpot_ms < 110.0);
}

#[test]
fn conserve_harvests_without_breaking_slo() {
    let online = arrivals(2, 2.0, 1.0, 90.0);
    let base = experiment(Policy::OnlineOnly, 90.0, online.clone(), 0).run();
    let cs = experiment(Policy::ConServe, 90.0, online, 400).run();
    // harvest: significantly more total work done
    assert!(
        cs.total_processed_tput > 1.5 * base.total_processed_tput,
        "harvest {:.0} vs base {:.0}",
        cs.total_processed_tput,
        base.total_processed_tput
    );
    // latency preserved near SLO
    assert!(
        cs.online_p99_ttft_ms < 1500.0 * 1.15,
        "p99 TTFT {}",
        cs.online_p99_ttft_ms
    );
    assert!(
        cs.online_p99_tpot_ms < 110.0 * 1.15,
        "p99 TPOT {}",
        cs.online_p99_tpot_ms
    );
    // checkpointing actually ran under pressure
    assert!(cs.ckpt_blocks > 0);
}

#[test]
fn vllmpp_inflates_online_latency() {
    let online = arrivals(3, 2.0, 1.0, 90.0);
    let cs = experiment(Policy::ConServe, 90.0, online.clone(), 400).run();
    let vpp = experiment(Policy::VllmPP, 90.0, online, 400).run();
    assert!(
        vpp.online_p99_ttft_ms > 2.0 * cs.online_p99_ttft_ms,
        "vLLM++ {:.0}ms vs ConServe {:.0}ms",
        vpp.online_p99_ttft_ms,
        cs.online_p99_ttft_ms
    );
    assert!(vpp.blocking_swap_ms > 0.0, "vLLM++ must have blocking swaps");
}

#[test]
fn off_phases_are_harvested() {
    let online = onoff_trace(4, 240.0, 60.0, 3.0, 1.0);
    let r = experiment(Policy::ConServe, 240.0, online, 1500).run();
    // find an OFF window with large offline throughput
    let mut best_off = 0.0f64;
    for (w_on, w_all) in r.online_timeseries.iter().zip(&r.all_timeseries) {
        let on_phase = ((w_on.start_s / 60.0) as u64) % 2 == 0;
        if !on_phase {
            best_off = best_off.max(w_all.processed_per_s - w_on.processed_per_s);
        }
    }
    assert!(best_off > 3000.0, "OFF-phase harvest only {best_off:.0} tok/s");
    assert!(r.online_p99_ttft_ms < 2500.0, "TTFT {}", r.online_p99_ttft_ms);
}

#[test]
fn layer_aborts_fire_under_bursts() {
    // pure-offline periods followed by online bursts => running offline
    // batches must be aborted at safepoints (Alg. 2)
    let online = onoff_trace(5, 180.0, 45.0, 4.0, 2.0);
    let r = experiment(Policy::ConServe, 180.0, online, 1500).run();
    assert!(
        r.layer_aborts > 0,
        "expected layer-granularity aborts during OFF->ON transitions"
    );
}

#[test]
fn prefetch_restores_preempted_requests() {
    let online = onoff_trace(6, 240.0, 60.0, 4.0, 1.0);
    let r = experiment(Policy::ConServe, 240.0, online, 800).run();
    assert!(r.prefetch_blocks > 0, "prefetching must have occurred");
    assert!(r.offline_finished > 0, "preempted offline work must finish");
}

#[test]
fn deterministic_given_seed() {
    let online = arrivals(7, 2.0, 1.0, 45.0);
    let a = experiment(Policy::ConServe, 45.0, online.clone(), 200).run();
    let b = experiment(Policy::ConServe, 45.0, online, 200).run();
    assert_eq!(a.online_finished, b.online_finished);
    assert_eq!(a.offline_finished, b.offline_finished);
    assert_eq!(a.preemptions, b.preemptions);
    assert!((a.online_p99_ttft_ms - b.online_p99_ttft_ms).abs() < 1e-9);
    assert!((a.total_processed_tput - b.total_processed_tput).abs() < 1e-6);
}

#[test]
fn report_json_is_valid() {
    let online = arrivals(8, 1.0, 1.0, 30.0);
    let r = experiment(Policy::ConServe, 30.0, online, 100).run();
    let j = r.to_json().to_string();
    let parsed = conserve::util::json::Json::parse(&j).unwrap();
    assert_eq!(
        parsed.req("policy").as_str(),
        Some("ConServe")
    );
    assert!(parsed.req("online_timeseries").as_arr().unwrap().len() > 0);
}
