//! Property tests on scheduler invariants, driven by randomized request
//! mixes over many seeds:
//!
//! * online work is never starved by offline work (priority);
//! * SLO-aware budgets are respected by offline admission;
//! * pure-offline batches (and only those) are preemptible;
//! * every scheduled item has grown KV capacity (no phantom memory);
//! * victims of a round are not re-admitted in the same round.

use conserve::backend::PlanSummary;
use conserve::config::EngineConfig;
use conserve::kvcache::manager::KvManager;
use conserve::profiler::LatencyProfile;
use conserve::request::{Class, Phase, Request, RequestArena, State};
use conserve::scheduler::{Ctx, Policy, ScheduleOutcome, UnifiedScheduler};
use conserve::util::rng::Rng;

fn profile() -> LatencyProfile {
    LatencyProfile {
        c: [1200.0, 96.0, 40.0, 0.385],
    }
}

struct World {
    sched: UnifiedScheduler,
    table: RequestArena,
    kv: KvManager,
    cfg: EngineConfig,
    now: u64,
}

fn world(policy: Policy, seed: u64, n_online: usize, n_offline: usize) -> World {
    let mut cfg = EngineConfig::sim_a100_7b();
    cfg.sched.policy = policy;
    let mut rng = Rng::new(seed);
    let mut table = RequestArena::new();
    let mut sched = UnifiedScheduler::new(cfg.sched.clone());
    let kv = KvManager::new(256, 1024, cfg.mem.block_tokens); // tight pool
    for _ in 0..n_online {
        let prompt = rng.range_usize(64, 2048);
        let out = rng.range_usize(16, 256);
        let id = table.insert(Request::new(0, Class::Online, vec![], prompt, out, 0));
        sched.enqueue(id, Class::Online);
    }
    for _ in 0..n_offline {
        // docs sized well below the 256-block (4096-token) pool so a
        // single request can always fit (admission of over-pool requests
        // is rejected upstream in a deployment)
        let prompt = rng.range_usize(512, 2048);
        let out = rng.range_usize(64, 256);
        let id = table.insert(Request::new(0, Class::Offline, vec![], prompt, out, 0));
        sched.enqueue(id, Class::Offline);
    }
    World {
        sched,
        table,
        kv,
        cfg,
        now: 0,
    }
}

/// Run one schedule step and commit its plan (simulating execution).
fn step(w: &mut World, prof: &LatencyProfile) -> ScheduleOutcome {
    let mut out = ScheduleOutcome::default();
    let mut ctx = Ctx {
        table: &mut w.table,
        kv: &mut w.kv,
        profile: prof,
        now: w.now,
        max_model_len: 4096,
    };
    w.sched.schedule(&mut ctx, &mut out);
    // invariant: every scheduled item has capacity grown
    for item in &out.plan.items {
        let seq = w.kv.seq(item.req).expect("scheduled item must be registered");
        assert!(
            seq.gpu.len() * w.kv.block_tokens >= item.ctx_len + item.n_tokens,
            "item {} lacks capacity",
            item.req
        );
    }
    // commit
    for item in &out.plan.items {
        w.kv.commit(item.req, item.n_tokens).unwrap();
        let r = w.table.get_mut(item.req).unwrap();
        r.ctx_len += item.n_tokens;
        if r.ctx_len == r.feed_target() {
            r.generated += 1;
            if r.is_done() {
                r.state = State::Finished;
                w.kv.release(item.req, false);
            }
        }
    }
    w.now += prof.estimate_us(&out.plan.summary()).max(1_000);
    out
}

#[test]
fn online_never_starved_and_budget_respected() {
    for seed in 0..8u64 {
        let mut w = world(Policy::ConServe, seed, 6, 30);
        let prof = profile();
        let mut online_done = false;
        for _ in 0..3000 {
            let out = step(&mut w, &prof);
            // budget: offline prefill tokens never exceed the budget
            let offline_prefill: usize = out
                .plan
                .items
                .iter()
                .filter(|i| i.class == Class::Offline && i.phase == Phase::Prefill)
                .map(|i| i.n_tokens)
                .sum();
            let has_online = out.plan.items.iter().any(|i| i.class == Class::Online);
            if has_online {
                assert!(
                    offline_prefill <= out.token_budget,
                    "seed {seed}: offline {offline_prefill} > budget {}",
                    out.token_budget
                );
            }
            // conservation holds throughout
            assert!(w.kv.check_conservation(), "seed {seed}");
            if w.table
                .values()
                .filter(|r| r.class == Class::Online)
                .all(|r| r.state == State::Finished)
            {
                online_done = true;
                break;
            }
        }
        assert!(online_done, "seed {seed}: online requests starved");
    }
}

#[test]
fn offline_eventually_completes_when_alone() {
    for seed in 0..5u64 {
        let mut w = world(Policy::ConServe, seed, 0, 8);
        let prof = profile();
        for _ in 0..5000 {
            let out = step(&mut w, &prof);
            if !out.plan.items.is_empty() {
                // pure offline + layerwise enabled => preemptible
                assert!(out.plan.preemptible, "seed {seed}");
                assert!(out.plan.items.iter().all(|i| i.class == Class::Offline));
            }
            if w.table.values().all(|r| r.state == State::Finished) {
                return;
            }
        }
        panic!("seed {seed}: offline work never completed");
    }
}

#[test]
fn mixed_batches_never_preemptible() {
    for seed in 0..8u64 {
        let mut w = world(Policy::ConServe, seed, 4, 12);
        let prof = profile();
        for _ in 0..500 {
            let out = step(&mut w, &prof);
            let has_online = out.plan.items.iter().any(|i| i.class == Class::Online);
            if has_online {
                assert!(!out.plan.preemptible, "seed {seed}: mixed batch preemptible");
            }
        }
    }
}

#[test]
fn victims_not_readmitted_same_round() {
    for seed in 0..10u64 {
        let mut w = world(Policy::ConServe, seed, 8, 20);
        let prof = profile();
        for _ in 0..800 {
            let out = step(&mut w, &prof);
            for v in out
                .evicted
                .iter()
                .chain(&out.discarded)
                .chain(&out.swapped_out)
            {
                assert!(
                    !out.plan.items.iter().any(|i| i.req == *v),
                    "seed {seed}: victim {v} re-admitted in the same round"
                );
            }
        }
    }
}

#[test]
fn online_only_never_touches_offline() {
    let mut w = world(Policy::OnlineOnly, 3, 5, 50);
    let prof = profile();
    for _ in 0..2000 {
        let out = step(&mut w, &prof);
        assert!(out.plan.items.iter().all(|i| i.class == Class::Online));
        if w.table
            .values()
            .filter(|r| r.class == Class::Online)
            .all(|r| r.state == State::Finished)
        {
            break;
        }
    }
    // offline untouched
    for r in w.table.values().filter(|r| r.class == Class::Offline) {
        assert_eq!(r.ctx_len, 0);
        assert_eq!(r.state, State::Waiting);
    }
}

#[test]
fn vllmpp_uses_blocking_swaps_not_discards() {
    for seed in 0..6u64 {
        let mut w = world(Policy::VllmPP, seed, 6, 24);
        w.cfg.sched.slo_aware = false;
        let prof = profile();
        let mut total_swapped = 0usize;
        for _ in 0..1500 {
            let out = step(&mut w, &prof);
            assert!(out.discarded.is_empty(), "vLLM++ must not discard");
            assert!(!out.plan.preemptible, "vLLM++ has no safepoints");
            total_swapped += out.swapped_out.len();
            if w.table
                .values()
                .filter(|r| r.class == Class::Online)
                .all(|r| r.state == State::Finished)
            {
                break;
            }
        }
        // with a 256-block pool and this load, pressure must have occurred
        let _ = total_swapped;
    }
}

#[test]
fn estimator_plan_consistency() {
    // the scheduler's own plans should estimate within the SLO it used
    let mut w = world(Policy::ConServe, 11, 4, 16);
    let prof = profile();
    for _ in 0..400 {
        let mut out = ScheduleOutcome::default();
        let mut ctx = Ctx {
            table: &mut w.table,
            kv: &mut w.kv,
            profile: &prof,
            now: w.now,
            max_model_len: 4096,
        };
        w.sched.schedule(&mut ctx, &mut out);
        let s: PlanSummary = out.plan.summary();
        let has_decode = s.decode_seqs > 0;
        let has_online = out.plan.items.iter().any(|i| i.class == Class::Online);
        if has_online && has_decode {
            let est = prof.estimate_us(&s);
            // TPOT budget 110 ms + slack for the decode base cost
            assert!(
                est < 250_000,
                "iteration estimate {est}µs far beyond TPOT budget"
            );
        }
        for item in &out.plan.items {
            w.kv.commit(item.req, item.n_tokens).unwrap();
            let r = w.table.get_mut(item.req).unwrap();
            r.ctx_len += item.n_tokens;
            if r.ctx_len == r.feed_target() {
                r.generated += 1;
                if r.is_done() {
                    r.state = State::Finished;
                    w.kv.release(item.req, false);
                }
            }
        }
        w.now += 50_000;
    }
}
