//! Experiment harness shared by the benches, examples and CLI: builds
//! workloads, runs a policy on the simulated A100/Llama-2-7B testbed,
//! and reduces the recorder into the numbers the paper's figures report.

use crate::backend::{CostModel, SimBackend};
use crate::clock::Clock;
use crate::config::EngineConfig;
use crate::metrics::{TenantCounters, WindowStats};
use crate::profiler::LatencyProfile;
use crate::request::{Class, Request};
use crate::scheduler::Policy;
use crate::server::{ArrivalSource, ServingEngine};
use crate::util::json::{arr, num, obj, Json};
use crate::util::rng::Rng;
use crate::workload::{LengthSample, Lengths};
use crate::{TimeUs, US_PER_SEC};

/// A complete co-serving experiment on the simulated testbed.
#[derive(Debug, Clone)]
pub struct SimExperiment {
    pub cfg: EngineConfig,
    /// Online arrival timestamps (µs).
    pub online_arrivals: Vec<TimeUs>,
    pub online_lengths: Lengths,
    /// Size of the offline batch pool submitted at t=0 (0 = none).
    pub offline_pool: usize,
    pub offline_lengths: Lengths,
    pub duration_s: f64,
}

impl SimExperiment {
    /// The request trace this experiment serves: online arrivals with
    /// lengths sampled under the experiment seed, then the offline pool
    /// at t=0. [`run`](Self::run) serves exactly this vector, and
    /// sharded sweeps ([`crate::shard::run_sharded_sim`]) route it
    /// across workers — both paths construct the workload here, so a
    /// 1-shard sweep point and `run` see the identical request set.
    pub fn events(&self) -> Vec<Request> {
        let mut rng = Rng::new(self.cfg.seed);
        let mut events: Vec<Request> = Vec::new();
        let mut next_id = 1u64;
        for &t in &self.online_arrivals {
            let LengthSample { input, output } = self.online_lengths.sample(&mut rng);
            events.push(Request::new(next_id, Class::Online, vec![], input, output, t));
            next_id += 1;
        }
        for _ in 0..self.offline_pool {
            let LengthSample { input, output } = self.offline_lengths.sample(&mut rng);
            events.push(Request::new(next_id, Class::Offline, vec![], input, output, 0));
            next_id += 1;
        }
        events
    }

    pub fn run(&self) -> Report {
        let clock = Clock::virtual_at(0);
        let cost = CostModel::a100_llama2_7b();
        let mut backend = SimBackend::new(
            cost,
            clock.clone(),
            self.cfg.sched.safepoint_layers,
        );
        // Offline profiling pass (§4.5) on a fresh clock so it does not
        // consume experiment time.
        let profile = {
            let pclock = Clock::virtual_at(0);
            let mut pb = SimBackend::new(cost, pclock, self.cfg.sched.safepoint_layers);
            LatencyProfile::profile(&mut pb, 4096, 128, 2048).expect("profiling failed")
        };
        // reset the experiment clock reference (backend shares `clock`)
        let _ = &mut backend;

        let arrivals = ArrivalSource::from_trace(self.events());
        let mut engine =
            ServingEngine::new(self.cfg.clone(), backend, clock, profile, arrivals);
        let until = (self.duration_s * US_PER_SEC as f64) as TimeUs;
        let end = engine.run(until);
        Report::from_engine(&engine.rec, self.cfg.sched.policy, end.min(until))
    }
}

/// Reduced experiment results (one row of a paper table / one series of a
/// figure).
#[derive(Debug, Clone)]
pub struct Report {
    pub policy: Policy,
    pub duration_s: f64,
    pub online_p99_ttft_ms: f64,
    pub online_p99_tpot_ms: f64,
    pub online_mean_ttft_ms: f64,
    pub online_gen_tput: f64,
    pub offline_gen_tput: f64,
    pub total_gen_tput: f64,
    pub online_processed_tput: f64,
    pub offline_processed_tput: f64,
    pub total_processed_tput: f64,
    pub online_finished: u64,
    pub offline_finished: u64,
    pub preemptions: u64,
    pub layer_aborts: u64,
    pub ckpt_blocks: u64,
    pub prefetch_blocks: u64,
    pub blocking_swap_ms: f64,
    /// Offline requests migrated away from / adopted by this engine (or
    /// fleet total, for a merged report) via cross-shard work stealing.
    pub steals_out: u64,
    pub steals_in: u64,
    /// Deadline-carrying job requests finished before / after their
    /// soft deadline, and the derived attainment fraction (1.0 when no
    /// request carried a deadline). See crate::batch.
    pub deadline_met: u64,
    pub deadline_missed: u64,
    pub deadline_attainment: f64,
    /// Batch jobs fully completed, and job-level deadline attainment
    /// (a job meets its deadline iff its *last* request does).
    pub jobs_completed: u64,
    pub jobs_deadline_met: u64,
    pub jobs_deadline_missed: u64,
    /// Records written by the periodic durable store flush (write
    /// amplification of the crash-recovery path; 0 without a sink).
    pub ckpt_flush_records: u64,
    /// Queued-offline urgency values changed by the periodic re-stamp.
    pub urgency_restamps: u64,
    /// Requests aborted by client cancellation (live path disconnects).
    pub cancelled: u64,
    /// Front-door admission outcomes (zero outside `conserve serve`):
    /// structured-429 sheds per class and job verdicts at submit.
    pub shed_online: u64,
    pub shed_offline: u64,
    pub jobs_admitted: u64,
    pub jobs_downtiered: u64,
    pub jobs_rejected: u64,
    /// Closed-loop harvest controller activity (zero with `--harvest`
    /// off): audited decisions and the tighten/open breakdown.
    pub harvest_decisions: u64,
    pub harvest_tightens: u64,
    pub harvest_opens: u64,
    /// Cross-request prefix KV sharing (zero with `--prefix-cache`
    /// off): admissions that attached shared blocks, the prompt tokens
    /// whose prefill they skipped, and the peak shared-block residency
    /// (Σ per-shard peaks in a merged report).
    pub prefix_hits: u64,
    pub prefill_tokens_skipped: u64,
    pub shared_block_residency: u64,
    /// Per-tenant completion counters for job-tagged requests.
    pub per_tenant: Vec<TenantCounters>,
    pub ttft_violations: f64,
    pub online_timeseries: Vec<WindowStats>,
    pub all_timeseries: Vec<WindowStats>,
}

impl Report {
    pub fn from_engine(
        rec: &crate::metrics::Recorder,
        policy: Policy,
        end: TimeUs,
    ) -> Self {
        let dur = end.max(1);
        Report {
            policy,
            duration_s: dur as f64 / US_PER_SEC as f64,
            online_p99_ttft_ms: rec.p99_ttft_ms(Class::Online),
            online_p99_tpot_ms: rec.p99_tpot_ms(Class::Online),
            online_mean_ttft_ms: rec.mean_ttft_ms(Class::Online),
            online_gen_tput: rec.throughput(Some(Class::Online), 0, dur),
            offline_gen_tput: rec.throughput(Some(Class::Offline), 0, dur),
            total_gen_tput: rec.throughput(None, 0, dur),
            online_processed_tput: rec.processed_throughput(Some(Class::Online), 0, dur),
            offline_processed_tput: rec.processed_throughput(Some(Class::Offline), 0, dur),
            total_processed_tput: rec.processed_throughput(None, 0, dur),
            online_finished: rec.finished[0],
            offline_finished: rec.finished[1],
            preemptions: rec.preemptions,
            layer_aborts: rec.layer_aborts,
            ckpt_blocks: rec.ckpt_blocks,
            prefetch_blocks: rec.prefetch_blocks,
            blocking_swap_ms: rec.blocking_swap_us as f64 / 1000.0,
            steals_out: rec.steals_out,
            steals_in: rec.steals_in,
            deadline_met: rec.deadline_met,
            deadline_missed: rec.deadline_missed,
            deadline_attainment: rec.deadline_attainment(),
            jobs_completed: rec.jobs_completed,
            jobs_deadline_met: rec.jobs_deadline_met,
            jobs_deadline_missed: rec.jobs_deadline_missed,
            ckpt_flush_records: rec.ckpt_flush_records,
            urgency_restamps: rec.urgency_restamps,
            cancelled: rec.cancelled,
            shed_online: rec.shed_online,
            shed_offline: rec.shed_offline,
            jobs_admitted: rec.jobs_admitted,
            jobs_downtiered: rec.jobs_downtiered,
            jobs_rejected: rec.jobs_rejected,
            harvest_decisions: rec.harvest_decisions,
            harvest_tightens: rec.harvest_tightens,
            harvest_opens: rec.harvest_opens,
            prefix_hits: rec.prefix_hits,
            prefill_tokens_skipped: rec.prefill_tokens_skipped,
            shared_block_residency: rec.shared_block_residency,
            per_tenant: rec.tenants.clone(),
            ttft_violations: rec.ttft_violation_rate(Class::Online, 1500.0),
            online_timeseries: rec.timeseries(Some(Class::Online), 15 * US_PER_SEC, dur),
            all_timeseries: rec.timeseries(None, 15 * US_PER_SEC, dur),
        }
    }

    /// One-line summary row (figure tables in the benches).
    pub fn row(&self) -> String {
        format!(
            "{:<12} p99TTFT={:>9.1}ms p99TPOT={:>8.1}ms tput(gen)={:>7.0} tok/s tput(proc)={:>8.0} tok/s online_fin={:<5} offline_fin={:<5} preempt={:<4} viol={:.1}%",
            self.policy.to_string(),
            self.online_p99_ttft_ms,
            self.online_p99_tpot_ms,
            self.total_gen_tput,
            self.total_processed_tput,
            self.online_finished,
            self.offline_finished,
            self.preemptions,
            self.ttft_violations * 100.0
        )
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("policy", Json::Str(self.policy.to_string())),
            ("duration_s", num(self.duration_s)),
            ("online_p99_ttft_ms", num(self.online_p99_ttft_ms)),
            ("online_p99_tpot_ms", num(self.online_p99_tpot_ms)),
            ("online_mean_ttft_ms", num(self.online_mean_ttft_ms)),
            ("total_gen_tput", num(self.total_gen_tput)),
            ("total_processed_tput", num(self.total_processed_tput)),
            ("offline_processed_tput", num(self.offline_processed_tput)),
            ("online_finished", num(self.online_finished as f64)),
            ("offline_finished", num(self.offline_finished as f64)),
            ("preemptions", num(self.preemptions as f64)),
            ("layer_aborts", num(self.layer_aborts as f64)),
            ("ckpt_blocks", num(self.ckpt_blocks as f64)),
            ("prefetch_blocks", num(self.prefetch_blocks as f64)),
            ("blocking_swap_ms", num(self.blocking_swap_ms)),
            ("steals_out", num(self.steals_out as f64)),
            ("steals_in", num(self.steals_in as f64)),
            ("deadline_met", num(self.deadline_met as f64)),
            ("deadline_missed", num(self.deadline_missed as f64)),
            ("deadline_attainment", num(self.deadline_attainment)),
            ("jobs_completed", num(self.jobs_completed as f64)),
            ("jobs_deadline_met", num(self.jobs_deadline_met as f64)),
            ("jobs_deadline_missed", num(self.jobs_deadline_missed as f64)),
            ("ckpt_flush_records", num(self.ckpt_flush_records as f64)),
            ("urgency_restamps", num(self.urgency_restamps as f64)),
            ("cancelled", num(self.cancelled as f64)),
            ("shed_online", num(self.shed_online as f64)),
            ("shed_offline", num(self.shed_offline as f64)),
            ("jobs_admitted", num(self.jobs_admitted as f64)),
            ("jobs_downtiered", num(self.jobs_downtiered as f64)),
            ("jobs_rejected", num(self.jobs_rejected as f64)),
            ("harvest_decisions", num(self.harvest_decisions as f64)),
            ("harvest_tightens", num(self.harvest_tightens as f64)),
            ("harvest_opens", num(self.harvest_opens as f64)),
            ("prefix_hits", num(self.prefix_hits as f64)),
            (
                "prefill_tokens_skipped",
                num(self.prefill_tokens_skipped as f64),
            ),
            (
                "shared_block_residency",
                num(self.shared_block_residency as f64),
            ),
            (
                "per_tenant",
                arr(self.per_tenant.iter().map(TenantCounters::to_json)),
            ),
            ("ttft_violation_rate", num(self.ttft_violations)),
            (
                "online_timeseries",
                arr(self.online_timeseries.iter().map(|w| {
                    obj(vec![
                        ("t_s", num(w.start_s)),
                        ("p99_ttft_ms", num(w.p99_ttft_ms)),
                        ("p99_tpot_ms", num(w.p99_tpot_ms)),
                        ("tok_s", num(w.tokens_per_s)),
                        ("proc_s", num(w.processed_per_s)),
                    ])
                })),
            ),
        ])
    }
}

/// Standard three-system comparison used by Figures 2/5/6/7/8.
pub fn compare_policies(
    base_cfg: &EngineConfig,
    policies: &[Policy],
    online_arrivals: &[TimeUs],
    online_lengths: Lengths,
    offline_pool_for: impl Fn(Policy) -> usize,
    offline_lengths: Lengths,
    duration_s: f64,
) -> Vec<Report> {
    policies
        .iter()
        .map(|&p| {
            let mut cfg = base_cfg.clone();
            cfg.sched.policy = p;
            if p == Policy::VllmPP {
                cfg.sched.slo_aware = false;
                cfg.sched.incremental_ckpt = false;
                cfg.sched.prefetch = false;
                cfg.sched.layerwise_preempt = false;
            }
            SimExperiment {
                cfg,
                online_arrivals: online_arrivals.to_vec(),
                online_lengths,
                offline_pool: offline_pool_for(p),
                offline_lengths,
                duration_s,
            }
            .run()
        })
        .collect()
}
