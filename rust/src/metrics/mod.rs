//! Serving metrics: per-request TTFT, per-token TPOT, throughput, and the
//! windowed-percentile timeseries the paper's figures plot.
//!
//! Online quality is P99 TTFT (prefill latency incl. queueing) and P99
//! TPOT (inter-token latency, paper footnote 2: per *decode step*, not
//! per-request average). Offline quality is generated tokens/second.
//!
//! Recording is O(1) per event: latency samples stream into fixed-bucket
//! log-scale histograms ([`hist::LogHistogram`]), so quantile queries are
//! O(buckets) instead of copy+sort over the sample set, and the windowed
//! timeseries is built in one pass over the event log instead of
//! re-filtering it per window. Raw event capture can be disabled
//! ([`Recorder::set_capture_events`]) for million-request traces where
//! only the streaming aggregates are needed.

pub mod hist;

use crate::request::Class;
use crate::{TimeUs, US_PER_SEC};

pub use hist::LogHistogram;

/// Percentile over a sample set (nearest-rank via quickselect — O(n),
/// no full sort). NaN-safe: total order per `f64::total_cmp`, so NaNs
/// sort last instead of panicking. Ad-hoc fallback for callers that
/// don't go through the streaming histograms.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = samples.to_vec();
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    let k = rank.clamp(1, v.len()) - 1;
    let (_, kth, _) = v.select_nth_unstable_by(k, f64::total_cmp);
    *kth
}

#[derive(Debug, Clone, Copy)]
pub struct TokenEvent {
    pub t: TimeUs,
    pub class: Class,
    /// Inter-token gap for decode tokens (None for the first token).
    pub tpot_us: Option<u64>,
}

#[derive(Debug, Clone, Copy)]
pub struct TtftEvent {
    pub t: TimeUs,
    pub class: Class,
    pub ttft_us: u64,
}

/// Tokens *processed* (prefill chunk + decode) in one iteration — the
/// utilization-style throughput the harvest figures report alongside
/// generation throughput.
#[derive(Debug, Clone, Copy)]
pub struct ProcessedEvent {
    pub t: TimeUs,
    pub class: Class,
    pub n: usize,
}

#[inline]
fn cidx(class: Class) -> usize {
    match class {
        Class::Online => 0,
        Class::Offline => 1,
    }
}

/// Default streaming-window width: matches the 15 s windows
/// `Report::from_engine` plots (Figures 5/6).
pub const DEFAULT_WINDOW_US: TimeUs = 15 * US_PER_SEC;

/// Cap on streaming-window slots (~11 days at the default width):
/// a bogus far-future timestamp must not balloon the ring.
const MAX_WINDOW_SLOTS: usize = 65_536;

/// Per-window streaming aggregates, indexed `[online, offline]`.
/// Histograms are lazily allocated, so silent windows cost a few
/// pointers.
#[derive(Debug, Clone)]
struct WindowSlot {
    ttft: [LogHistogram; 2],
    tpot: [LogHistogram; 2],
    gen: [u64; 2],
    proc: [u64; 2],
}

impl Default for WindowSlot {
    fn default() -> Self {
        Self {
            ttft: [LogHistogram::new(), LogHistogram::new()],
            tpot: [LogHistogram::new(), LogHistogram::new()],
            gen: [0, 0],
            proc: [0, 0],
        }
    }
}

/// Record-time per-window aggregation: the windowed Fig. 5/6 series
/// without the raw event log. Each sample lands in the histogram of its
/// fixed-width window as it is recorded, so
/// [`Recorder::set_capture_events`]`(false)` runs still produce windowed
/// timeseries (any query window that is a multiple of the ring width is
/// served by merging slots).
#[derive(Debug)]
struct WindowRing {
    window: TimeUs,
    slots: Vec<WindowSlot>,
}

impl WindowRing {
    fn new(window: TimeUs) -> Self {
        Self {
            window: window.max(1),
            slots: Vec::new(),
        }
    }

    fn slot_mut(&mut self, t: TimeUs) -> &mut WindowSlot {
        let w = ((t / self.window) as usize).min(MAX_WINDOW_SLOTS - 1);
        if self.slots.len() <= w {
            self.slots.resize_with(w + 1, WindowSlot::default);
        }
        &mut self.slots[w]
    }

    fn merge(&mut self, other: &WindowRing) {
        if self.slots.len() < other.slots.len() {
            self.slots
                .resize_with(other.slots.len(), WindowSlot::default);
        }
        for (a, b) in self.slots.iter_mut().zip(&other.slots) {
            for i in 0..2 {
                a.ttft[i].merge(&b.ttft[i]);
                a.tpot[i].merge(&b.tpot[i]);
                a.gen[i] += b.gen[i];
                a.proc[i] += b.proc[i];
            }
        }
    }
}

/// Per-tenant completion counters (batch jobs, crate::batch): who got
/// served how much, and how their deadline-carrying requests fared.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantCounters {
    pub tenant: u32,
    /// Job-tagged requests finished for this tenant.
    pub finished: u64,
    /// Output tokens generated for this tenant's job requests.
    pub gen_tokens: u64,
    pub deadline_met: u64,
    pub deadline_missed: u64,
}

impl TenantCounters {
    /// Fraction of this tenant's deadline-carrying requests that met
    /// their deadline (1.0 when none carried one).
    pub fn attainment(&self) -> f64 {
        let total = self.deadline_met + self.deadline_missed;
        if total == 0 {
            1.0
        } else {
            self.deadline_met as f64 / total as f64
        }
    }

    /// The per-tenant JSON row shared by `Report::to_json` and the
    /// bench emitters — one place to extend when a counter is added.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj};
        obj(vec![
            ("tenant", num(self.tenant as f64)),
            ("finished", num(self.finished as f64)),
            ("gen_tokens", num(self.gen_tokens as f64)),
            ("deadline_met", num(self.deadline_met as f64)),
            ("deadline_missed", num(self.deadline_missed as f64)),
        ])
    }
}

/// Streaming metrics recorder. Aggregates (histograms, totals) are
/// maintained on record; the raw event log feeds post-run timeseries
/// analysis and can be switched off for long traces (windowed series
/// then come from the streaming window ring).
#[derive(Debug)]
pub struct Recorder {
    pub ttfts: Vec<TtftEvent>,
    pub tokens: Vec<TokenEvent>,
    pub processed: Vec<ProcessedEvent>,
    pub preemptions: u64,
    pub layer_aborts: u64,
    pub recomputed_tokens: u64,
    pub ckpt_blocks: u64,
    pub prefetch_blocks: u64,
    pub blocking_swap_us: u64,
    pub finished: [u64; 2], // [online, offline]
    /// Engine loop iterations (scheduling steps) — hot-path throughput
    /// denominator for `bench_sched_loop`.
    pub engine_iters: u64,
    /// Offline requests this shard migrated away / adopted via
    /// cross-shard work stealing.
    pub steals_out: u64,
    pub steals_in: u64,
    /// Committed tokens whose host checkpoints travelled with stolen
    /// requests (0 for cold steals).
    pub stolen_ckpt_tokens: u64,
    /// Deadline-carrying requests finished at/after their soft deadline
    /// (crate::batch; requests without a deadline count in neither).
    pub deadline_met: u64,
    pub deadline_missed: u64,
    /// Batch jobs whose last request finished on this shard, and how
    /// many of those with deadlines made/missed them (job-level
    /// attainment; the fleet aggregate comes from `merge`).
    pub jobs_completed: u64,
    pub jobs_deadline_met: u64,
    pub jobs_deadline_missed: u64,
    /// Records written by the periodic durable store flush (checkpoint
    /// lines + finished outputs) — the write-amplification counter of
    /// the crash-recovery path.
    pub ckpt_flush_records: u64,
    /// Queued-offline urgency values changed by the periodic deadline
    /// re-stamp.
    pub urgency_restamps: u64,
    /// Requests aborted before completion by client cancellation
    /// (disconnect mid-stream on the live path).
    pub cancelled: u64,
    /// Front-door admission decisions (stamped onto the merged recorder
    /// by the serve loop; per-shard recorders leave these zero):
    /// requests shed with a structured 429 per class, and job verdicts
    /// at submit.
    pub shed_online: u64,
    pub shed_offline: u64,
    pub jobs_admitted: u64,
    pub jobs_downtiered: u64,
    pub jobs_rejected: u64,
    /// Closed-loop harvest controller decisions
    /// ([`crate::scheduler::harvest`]): total audited decisions and the
    /// tighten/open breakdown (holds = decisions - tightens - opens).
    pub harvest_decisions: u64,
    pub harvest_tightens: u64,
    pub harvest_opens: u64,
    /// Cross-request prefix KV sharing ([`crate::kvcache::prefix`]):
    /// admissions that attached ≥1 shared block, and the prompt tokens
    /// whose prefill those attachments skipped.
    pub prefix_hits: u64,
    pub prefill_tokens_skipped: u64,
    /// Peak GPU blocks simultaneously shared (refcount > 1) on this
    /// shard; `merge` sums per-shard peaks (Σ per-shard peaks, not a
    /// fleet-instant peak — the shards don't share a clock).
    pub shared_block_residency: u64,
    /// Per-tenant completion counters for job-tagged requests (short
    /// linear list — a handful of tenants per shard).
    pub tenants: Vec<TenantCounters>,
    capture_events: bool,
    ring: Option<WindowRing>,
    ttft_hist: [LogHistogram; 2],
    tpot_hist: [LogHistogram; 2],
    gen_tokens: [u64; 2],
    processed_tokens: [u64; 2],
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

impl Recorder {
    pub fn new() -> Self {
        Self {
            ttfts: Vec::new(),
            tokens: Vec::new(),
            processed: Vec::new(),
            preemptions: 0,
            layer_aborts: 0,
            recomputed_tokens: 0,
            ckpt_blocks: 0,
            prefetch_blocks: 0,
            blocking_swap_us: 0,
            finished: [0, 0],
            engine_iters: 0,
            steals_out: 0,
            steals_in: 0,
            stolen_ckpt_tokens: 0,
            deadline_met: 0,
            deadline_missed: 0,
            jobs_completed: 0,
            jobs_deadline_met: 0,
            jobs_deadline_missed: 0,
            ckpt_flush_records: 0,
            urgency_restamps: 0,
            cancelled: 0,
            shed_online: 0,
            shed_offline: 0,
            jobs_admitted: 0,
            jobs_downtiered: 0,
            jobs_rejected: 0,
            harvest_decisions: 0,
            harvest_tightens: 0,
            harvest_opens: 0,
            prefix_hits: 0,
            prefill_tokens_skipped: 0,
            shared_block_residency: 0,
            tenants: Vec::new(),
            capture_events: true,
            ring: None,
            ttft_hist: [LogHistogram::new(), LogHistogram::new()],
            tpot_hist: [LogHistogram::new(), LogHistogram::new()],
            gen_tokens: [0, 0],
            processed_tokens: [0, 0],
        }
    }

    /// Disable raw event capture (streaming aggregates only). Turning
    /// capture off auto-enables the streaming window ring (at
    /// [`DEFAULT_WINDOW_US`] unless [`set_streaming_windows`] chose a
    /// width already), so windowed timeseries keep working; overall
    /// percentiles, means, counts and violation rates never needed the
    /// event log.
    ///
    /// [`set_streaming_windows`]: Self::set_streaming_windows
    pub fn set_capture_events(&mut self, on: bool) {
        self.capture_events = on;
        if !on && self.ring.is_none() {
            self.ring = Some(WindowRing::new(DEFAULT_WINDOW_US));
        }
    }

    /// Enable (or re-size) record-time window aggregation: every later
    /// sample also lands in a fixed-`window` streaming histogram, and
    /// [`timeseries`](Self::timeseries) queries whose window is a
    /// multiple of `window` are served from the ring when the event log
    /// is off. Existing ring contents are dropped on a re-size, and
    /// [`merge`](Self::merge) drops a source ring whose width differs
    /// from this one's — keep one width (the default) across a fleet.
    pub fn set_streaming_windows(&mut self, window: TimeUs) {
        self.ring = Some(WindowRing::new(window));
    }

    pub fn record_first_token(&mut self, t: TimeUs, class: Class, ttft_us: u64) {
        self.ttft_hist[cidx(class)].record(ttft_us);
        self.gen_tokens[cidx(class)] += 1;
        if let Some(ring) = &mut self.ring {
            let slot = ring.slot_mut(t);
            slot.ttft[cidx(class)].record(ttft_us);
            slot.gen[cidx(class)] += 1;
        }
        if self.capture_events {
            self.ttfts.push(TtftEvent { t, class, ttft_us });
            self.tokens.push(TokenEvent {
                t,
                class,
                tpot_us: None,
            });
        }
    }

    pub fn record_token(&mut self, t: TimeUs, class: Class, gap_us: u64) {
        self.tpot_hist[cidx(class)].record(gap_us);
        self.gen_tokens[cidx(class)] += 1;
        if let Some(ring) = &mut self.ring {
            let slot = ring.slot_mut(t);
            slot.tpot[cidx(class)].record(gap_us);
            slot.gen[cidx(class)] += 1;
        }
        if self.capture_events {
            self.tokens.push(TokenEvent {
                t,
                class,
                tpot_us: Some(gap_us),
            });
        }
    }

    pub fn record_processed(&mut self, t: TimeUs, class: Class, n: usize) {
        if n > 0 {
            self.processed_tokens[cidx(class)] += n as u64;
            if let Some(ring) = &mut self.ring {
                ring.slot_mut(t).proc[cidx(class)] += n as u64;
            }
            if self.capture_events {
                self.processed.push(ProcessedEvent { t, class, n });
            }
        }
    }

    pub fn record_finished(&mut self, class: Class) {
        self.finished[cidx(class)] += 1;
    }

    /// One job-tagged request finished for `tenant`; `deadline_met` is
    /// `None` when the request carried no deadline.
    pub fn note_tenant_finished(
        &mut self,
        tenant: u32,
        gen_tokens: u64,
        deadline_met: Option<bool>,
    ) {
        let idx = match self.tenants.iter().position(|t| t.tenant == tenant) {
            Some(i) => i,
            None => {
                self.tenants.push(TenantCounters {
                    tenant,
                    ..TenantCounters::default()
                });
                self.tenants.len() - 1
            }
        };
        let cell = &mut self.tenants[idx];
        cell.finished += 1;
        cell.gen_tokens += gen_tokens;
        match deadline_met {
            Some(true) => cell.deadline_met += 1,
            Some(false) => cell.deadline_missed += 1,
            None => {}
        }
    }

    /// Fraction of deadline-carrying requests that met their deadline
    /// (1.0 when none carried one — nothing was late).
    pub fn deadline_attainment(&self) -> f64 {
        let total = self.deadline_met + self.deadline_missed;
        if total == 0 {
            1.0
        } else {
            self.deadline_met as f64 / total as f64
        }
    }

    /// Fold another recorder into this one (sharded runs: one recorder
    /// per worker shard, merged for the aggregate report). Event logs
    /// append, histograms merge bucket-wise, streaming totals add — so
    /// merged percentiles are computed over the *union* of all shards'
    /// samples, not an average of per-shard percentiles.
    pub fn merge(&mut self, other: &Recorder) {
        // ---- streaming window rings first (event logs are not yet
        // extended, so each side's samples replay exactly once) ----
        let self_had_ring = self.ring.is_some();
        match (&mut self.ring, &other.ring) {
            (Some(a), Some(b)) if a.window == b.window => a.merge(b),
            (None, Some(b)) => {
                let mut ring = WindowRing::new(b.window);
                ring.merge(b);
                self.ring = Some(ring);
            }
            // mismatched widths: keep self's ring; all in-tree recorders
            // use DEFAULT_WINDOW_US, so this only drops a caller's
            // custom-width ring (documented on set_streaming_windows)
            _ => {}
        }
        if let Some(ring) = &mut self.ring {
            if other.ring.is_none() {
                // the source captured raw events instead of a ring:
                // replay them so the merged ring misses nothing
                Self::replay_into_ring(ring, &other.ttfts, &other.tokens, &other.processed);
            }
            if !self_had_ring {
                // the ring was adopted from `other`: backfill this
                // side's own previously event-logged samples
                Self::replay_into_ring(ring, &self.ttfts, &self.tokens, &self.processed);
            }
        }
        // a recorder that absorbed a capture-off source has an
        // incomplete event log: windowed queries must use the ring
        self.capture_events = self.capture_events && other.capture_events;

        self.ttfts.extend_from_slice(&other.ttfts);
        self.tokens.extend_from_slice(&other.tokens);
        self.processed.extend_from_slice(&other.processed);
        self.preemptions += other.preemptions;
        self.layer_aborts += other.layer_aborts;
        self.recomputed_tokens += other.recomputed_tokens;
        self.ckpt_blocks += other.ckpt_blocks;
        self.prefetch_blocks += other.prefetch_blocks;
        self.blocking_swap_us += other.blocking_swap_us;
        self.engine_iters += other.engine_iters;
        self.steals_out += other.steals_out;
        self.steals_in += other.steals_in;
        self.stolen_ckpt_tokens += other.stolen_ckpt_tokens;
        self.deadline_met += other.deadline_met;
        self.deadline_missed += other.deadline_missed;
        self.jobs_completed += other.jobs_completed;
        self.jobs_deadline_met += other.jobs_deadline_met;
        self.jobs_deadline_missed += other.jobs_deadline_missed;
        self.ckpt_flush_records += other.ckpt_flush_records;
        self.urgency_restamps += other.urgency_restamps;
        self.cancelled += other.cancelled;
        self.shed_online += other.shed_online;
        self.shed_offline += other.shed_offline;
        self.jobs_admitted += other.jobs_admitted;
        self.jobs_downtiered += other.jobs_downtiered;
        self.jobs_rejected += other.jobs_rejected;
        self.harvest_decisions += other.harvest_decisions;
        self.harvest_tightens += other.harvest_tightens;
        self.harvest_opens += other.harvest_opens;
        self.prefix_hits += other.prefix_hits;
        self.prefill_tokens_skipped += other.prefill_tokens_skipped;
        self.shared_block_residency += other.shared_block_residency;
        for t in &other.tenants {
            match self.tenants.iter_mut().find(|c| c.tenant == t.tenant) {
                Some(c) => {
                    c.finished += t.finished;
                    c.gen_tokens += t.gen_tokens;
                    c.deadline_met += t.deadline_met;
                    c.deadline_missed += t.deadline_missed;
                }
                None => self.tenants.push(*t),
            }
        }
        for i in 0..2 {
            self.finished[i] += other.finished[i];
            self.gen_tokens[i] += other.gen_tokens[i];
            self.processed_tokens[i] += other.processed_tokens[i];
            self.ttft_hist[i].merge(&other.ttft_hist[i]);
            self.tpot_hist[i].merge(&other.tpot_hist[i]);
        }
    }

    /// Re-record raw events into a window ring (merge-time backfill for
    /// recorders that logged events instead of maintaining a ring).
    fn replay_into_ring(
        ring: &mut WindowRing,
        ttfts: &[TtftEvent],
        tokens: &[TokenEvent],
        processed: &[ProcessedEvent],
    ) {
        for e in ttfts {
            ring.slot_mut(e.t).ttft[cidx(e.class)].record(e.ttft_us);
        }
        for e in tokens {
            let slot = ring.slot_mut(e.t);
            slot.gen[cidx(e.class)] += 1;
            if let Some(gap) = e.tpot_us {
                slot.tpot[cidx(e.class)].record(gap);
            }
        }
        for e in processed {
            ring.slot_mut(e.t).proc[cidx(e.class)] += e.n as u64;
        }
    }

    // ------------------------------------------------------------ queries

    fn class_total(totals: &[u64; 2], class: Option<Class>) -> u64 {
        match class {
            Some(c) => totals[cidx(c)],
            None => totals[0] + totals[1],
        }
    }

    /// Generated tokens recorded for a class (streaming total — exact
    /// even with event capture off).
    pub fn gen_token_count(&self, class: Option<Class>) -> u64 {
        Self::class_total(&self.gen_tokens, class)
    }

    /// Processed tokens recorded for a class (streaming total).
    pub fn processed_token_count(&self, class: Option<Class>) -> u64 {
        Self::class_total(&self.processed_tokens, class)
    }

    /// Processed tokens/second over [from, to) (prefill + decode), the
    /// "overall serving throughput" of Figures 5-8. Scans the event log.
    pub fn processed_throughput(
        &self,
        class: Option<Class>,
        from: TimeUs,
        to: TimeUs,
    ) -> f64 {
        if to <= from {
            return 0.0;
        }
        let n: usize = self
            .processed
            .iter()
            .filter(|e| e.t >= from && e.t < to)
            .filter(|e| class.is_none_or(|c| e.class == c))
            .map(|e| e.n)
            .sum();
        n as f64 * US_PER_SEC as f64 / (to - from) as f64
    }

    /// P99 TTFT in ms (streaming histogram; ≤1.6 % bucket error).
    pub fn p99_ttft_ms(&self, class: Class) -> f64 {
        self.ttft_hist[cidx(class)].quantile(99.0) as f64 / 1000.0
    }

    /// P99 TPOT in ms (streaming histogram; ≤1.6 % bucket error).
    pub fn p99_tpot_ms(&self, class: Class) -> f64 {
        self.tpot_hist[cidx(class)].quantile(99.0) as f64 / 1000.0
    }

    /// Mean TTFT in ms (exact: histograms keep an exact running sum).
    pub fn mean_ttft_ms(&self, class: Class) -> f64 {
        self.ttft_hist[cidx(class)].mean() / 1000.0
    }

    /// Generated tokens per second over [from, to) for a class (or both).
    /// Scans the event log.
    pub fn throughput(&self, class: Option<Class>, from: TimeUs, to: TimeUs) -> f64 {
        if to <= from {
            return 0.0;
        }
        let n = self
            .tokens
            .iter()
            .filter(|e| e.t >= from && e.t < to)
            .filter(|e| class.is_none_or(|c| e.class == c))
            .count();
        n as f64 * US_PER_SEC as f64 / (to - from) as f64
    }

    /// Windowed timeseries of (window_start_s, p99 TTFT ms, p99 TPOT ms,
    /// tokens/s) — the series Figures 5/6 plot.
    ///
    /// Single pass over the event log: events are binned into per-window
    /// histograms/counters, then each window's quantiles are read out.
    /// O(n + windows·buckets), vs. the previous
    /// O(windows·n + n·log n per window) filter-and-sort.
    pub fn timeseries(
        &self,
        class: Option<Class>,
        window: TimeUs,
        until: TimeUs,
    ) -> Vec<WindowStats> {
        let window = window.max(1);
        // With the event log off, serve from the streaming window ring.
        // Query windows that are a multiple of the ring width are exact
        // (bucket-wise histogram merges); any other width is rounded up
        // to the next multiple — a coarser series (self-describing via
        // `start_s`) beats silently returning zeros from the empty log.
        if !self.capture_events {
            if let Some(ring) = &self.ring {
                let effective = window.div_ceil(ring.window) * ring.window;
                return self.ring_timeseries(ring, class, effective, until);
            }
        }
        let n_windows = (until.div_ceil(window)) as usize;
        let mut ttft_h = vec![LogHistogram::default(); n_windows];
        let mut tpot_h = vec![LogHistogram::default(); n_windows];
        let mut gen_count = vec![0u64; n_windows];
        let mut proc_count = vec![0u64; n_windows];

        let widx = |t: TimeUs| (t / window) as usize;
        for e in &self.ttfts {
            if e.t < until && class.is_none_or(|c| e.class == c) {
                ttft_h[widx(e.t)].record(e.ttft_us);
            }
        }
        for e in &self.tokens {
            if e.t < until && class.is_none_or(|c| e.class == c) {
                let w = widx(e.t);
                gen_count[w] += 1;
                if let Some(gap) = e.tpot_us {
                    tpot_h[w].record(gap);
                }
            }
        }
        for e in &self.processed {
            if e.t < until && class.is_none_or(|c| e.class == c) {
                proc_count[widx(e.t)] += e.n as u64;
            }
        }

        let per_sec = US_PER_SEC as f64 / window as f64;
        (0..n_windows)
            .map(|w| WindowStats {
                start_s: (w as u64 * window) as f64 / US_PER_SEC as f64,
                p99_ttft_ms: ttft_h[w].quantile(99.0) as f64 / 1000.0,
                p99_tpot_ms: tpot_h[w].quantile(99.0) as f64 / 1000.0,
                tokens_per_s: gen_count[w] as f64 * per_sec,
                processed_per_s: proc_count[w] as f64 * per_sec,
                n_ttft: ttft_h[w].count() as usize,
            })
            .collect()
    }

    /// Windowed series from the streaming ring: each output window
    /// merges `window / ring.window` slots (and both classes, for a
    /// `None` filter) bucket-wise. O(windows · buckets), no event log.
    /// Whole slots are merged, so samples recorded past a non-aligned
    /// `until` within the final slot are included (bounded by one ring
    /// width — the event path clips exactly).
    fn ring_timeseries(
        &self,
        ring: &WindowRing,
        class: Option<Class>,
        window: TimeUs,
        until: TimeUs,
    ) -> Vec<WindowStats> {
        let n_windows = (until.div_ceil(window)) as usize;
        let per = (window / ring.window).max(1) as usize;
        let per_sec = US_PER_SEC as f64 / window as f64;
        (0..n_windows)
            .map(|w| {
                let mut ttft = LogHistogram::new();
                let mut tpot = LogHistogram::new();
                let mut gen = 0u64;
                let mut proc = 0u64;
                for slot in ring.slots.iter().skip(w * per).take(per) {
                    for ci in 0..2 {
                        if class.is_none_or(|c| cidx(c) == ci) {
                            ttft.merge(&slot.ttft[ci]);
                            tpot.merge(&slot.tpot[ci]);
                            gen += slot.gen[ci];
                            proc += slot.proc[ci];
                        }
                    }
                }
                WindowStats {
                    start_s: (w as u64 * window) as f64 / US_PER_SEC as f64,
                    p99_ttft_ms: ttft.quantile(99.0) as f64 / 1000.0,
                    p99_tpot_ms: tpot.quantile(99.0) as f64 / 1000.0,
                    tokens_per_s: gen as f64 * per_sec,
                    processed_per_s: proc as f64 * per_sec,
                    n_ttft: ttft.count() as usize,
                }
            })
            .collect()
    }

    /// Fraction of online TTFTs above the SLO (streaming histogram;
    /// boundary-bucket samples resolve as "within SLO").
    pub fn ttft_violation_rate(&self, class: Class, slo_ms: f64) -> f64 {
        let h = &self.ttft_hist[cidx(class)];
        if h.is_empty() {
            return 0.0;
        }
        h.count_above((slo_ms * 1000.0) as u64) as f64 / h.count() as f64
    }
}

#[derive(Debug, Clone, Copy)]
pub struct WindowStats {
    pub start_s: f64,
    pub p99_ttft_ms: f64,
    pub p99_tpot_ms: f64,
    pub tokens_per_s: f64,
    pub processed_per_s: f64,
    pub n_ttft: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * b.abs().max(1.0)
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert_eq!(percentile(&[], 99.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn percentile_tolerates_nan() {
        // NaNs order last under total_cmp instead of panicking
        let v = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&v, 50.0), 2.0);
        assert_eq!(percentile(&v, 1.0), 1.0);
        assert!(percentile(&v, 100.0).is_nan());
    }

    #[test]
    fn ttft_and_tpot_split_by_class() {
        let mut r = Recorder::new();
        r.record_first_token(1_000_000, Class::Online, 200_000);
        r.record_first_token(2_000_000, Class::Offline, 9_000_000);
        r.record_token(2_100_000, Class::Online, 50_000);
        r.record_token(2_200_000, Class::Online, 60_000);
        // histogram quantiles are within 1/64 of the true value
        assert!(close(r.p99_ttft_ms(Class::Online), 200.0, 0.016));
        assert!(close(r.p99_ttft_ms(Class::Offline), 9000.0, 0.016));
        assert!(close(r.p99_tpot_ms(Class::Online), 60.0, 0.016));
        assert_eq!(r.p99_tpot_ms(Class::Offline), 0.0);
        assert_eq!(r.gen_token_count(Some(Class::Online)), 3);
        assert_eq!(r.gen_token_count(None), 4);
    }

    #[test]
    fn throughput_counts_all_tokens_in_window() {
        let mut r = Recorder::new();
        for i in 0..100 {
            r.record_token(i * 10_000, Class::Offline, 10_000); // 100 tokens in 1s
        }
        let tput = r.throughput(None, 0, US_PER_SEC);
        assert!((tput - 100.0).abs() < 1.0, "tput={tput}");
        assert_eq!(r.throughput(Some(Class::Online), 0, US_PER_SEC), 0.0);
    }

    #[test]
    fn timeseries_windows() {
        let mut r = Recorder::new();
        r.record_first_token(500_000, Class::Online, 100_000);
        r.record_first_token(1_500_000, Class::Online, 300_000);
        let ts = r.timeseries(Some(Class::Online), US_PER_SEC, 2 * US_PER_SEC);
        assert_eq!(ts.len(), 2);
        assert!(close(ts[0].p99_ttft_ms, 100.0, 0.016));
        assert!(close(ts[1].p99_ttft_ms, 300.0, 0.016));
        assert_eq!(ts[0].n_ttft, 1);
    }

    #[test]
    fn streaming_matches_event_scan() {
        // the single-pass timeseries must agree with a per-window
        // filter of the raw events on counts and (approximately) on p99
        let mut r = Recorder::new();
        let mut state = 12345u64;
        let mut rng = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            state >> 33
        };
        for _ in 0..5000 {
            let t = rng() % 60_000_000;
            let ttft = 1_000 + rng() % 2_000_000;
            r.record_first_token(t, Class::Online, ttft);
        }
        let ts = r.timeseries(Some(Class::Online), 15_000_000, 60_000_000);
        assert_eq!(ts.len(), 4);
        for (w, s) in ts.iter().enumerate() {
            let lo = w as u64 * 15_000_000;
            let hi = lo + 15_000_000;
            let samples: Vec<f64> = r
                .ttfts
                .iter()
                .filter(|e| e.t >= lo && e.t < hi)
                .map(|e| e.ttft_us as f64 / 1000.0)
                .collect();
            assert_eq!(s.n_ttft, samples.len());
            let exact = percentile(&samples, 99.0);
            assert!(
                close(s.p99_ttft_ms, exact, 0.016),
                "window {w}: {} vs {exact}",
                s.p99_ttft_ms
            );
        }
    }

    #[test]
    fn capture_off_keeps_streaming_aggregates() {
        let mut r = Recorder::new();
        r.set_capture_events(false);
        r.record_first_token(1_000, Class::Online, 200_000);
        r.record_token(2_000, Class::Online, 50_000);
        r.record_processed(2_000, Class::Online, 512);
        assert!(r.ttfts.is_empty() && r.tokens.is_empty() && r.processed.is_empty());
        assert!(close(r.p99_ttft_ms(Class::Online), 200.0, 0.016));
        assert!(close(r.mean_ttft_ms(Class::Online), 200.0, 1e-9));
        assert_eq!(r.gen_token_count(None), 2);
        assert_eq!(r.processed_token_count(None), 512);
    }

    #[test]
    fn capture_off_still_produces_windowed_timeseries() {
        // the ROADMAP item: Fig. 5/6 series without the raw event log
        let mut r = Recorder::new();
        r.set_capture_events(false);
        // window 0: one 100 ms TTFT; window 2: one 300 ms TTFT + decode
        r.record_first_token(500_000, Class::Online, 100_000);
        r.record_first_token(31_000_000, Class::Online, 300_000);
        r.record_token(32_000_000, Class::Online, 50_000);
        r.record_processed(32_000_000, Class::Online, 640);
        r.record_first_token(31_500_000, Class::Offline, 9_000_000);

        let ts = r.timeseries(Some(Class::Online), DEFAULT_WINDOW_US, 45_000_000);
        assert_eq!(ts.len(), 3);
        assert!(close(ts[0].p99_ttft_ms, 100.0, 0.016));
        assert_eq!(ts[0].n_ttft, 1);
        assert_eq!(ts[1].n_ttft, 0);
        assert!(close(ts[2].p99_ttft_ms, 300.0, 0.016));
        assert!(close(ts[2].p99_tpot_ms, 50.0, 0.016));
        assert!(ts[2].processed_per_s > 0.0);
        // class filter: offline sample invisible above, visible to None
        let all = r.timeseries(None, DEFAULT_WINDOW_US, 45_000_000);
        assert_eq!(all[2].n_ttft, 2);
        // a query window that is a multiple of the ring width merges slots
        let wide = r.timeseries(Some(Class::Online), 2 * DEFAULT_WINDOW_US, 45_000_000);
        assert_eq!(wide.len(), 2);
        assert_eq!(wide[0].n_ttft, 1);
        assert_eq!(wide[1].n_ttft, 1);
    }

    #[test]
    fn ring_and_event_paths_agree() {
        let mut with_events = Recorder::new();
        let mut ring_only = Recorder::new();
        ring_only.set_capture_events(false);
        let mut state = 777u64;
        let mut rng = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            state >> 33
        };
        for _ in 0..3000 {
            // keep samples off the final partial slot so both paths
            // clip identically
            let t = rng() % 60_000_000;
            let ttft = 1_000 + rng() % 2_000_000;
            with_events.record_first_token(t, Class::Online, ttft);
            ring_only.record_first_token(t, Class::Online, ttft);
        }
        let a = with_events.timeseries(Some(Class::Online), DEFAULT_WINDOW_US, 60_000_000);
        let b = ring_only.timeseries(Some(Class::Online), DEFAULT_WINDOW_US, 60_000_000);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.n_ttft, y.n_ttft);
            assert!(close(x.p99_ttft_ms, y.p99_ttft_ms, 1e-9), "{x:?} vs {y:?}");
            assert!(close(x.tokens_per_s, y.tokens_per_s, 1e-9));
        }
    }

    #[test]
    fn merging_capture_off_shards_into_fresh_recorder_keeps_timeseries() {
        // the sharded-report path: per-shard recorders run capture-off
        // (ring only) and fold into a fresh Recorder::new() — the
        // merged recorder must serve windowed series from the adopted
        // ring, not the (empty) event log
        let mut a = Recorder::new();
        a.set_capture_events(false);
        let mut b = Recorder::new();
        b.set_capture_events(false);
        a.record_first_token(1_000_000, Class::Online, 100_000);
        b.record_first_token(2_000_000, Class::Online, 300_000);
        let mut merged = Recorder::new(); // capture on, no ring
        merged.merge(&a);
        merged.merge(&b);
        let ts = merged.timeseries(Some(Class::Online), DEFAULT_WINDOW_US, DEFAULT_WINDOW_US);
        assert_eq!(ts.len(), 1);
        assert_eq!(ts[0].n_ttft, 2, "merged ring must serve the series");
        // mixed fleet: a capture-on shard's events replay into the ring
        let mut c = Recorder::new();
        c.record_first_token(3_000_000, Class::Online, 500_000);
        c.record_token(3_500_000, Class::Online, 40_000);
        c.record_processed(3_500_000, Class::Online, 64);
        merged.merge(&c);
        let ts = merged.timeseries(Some(Class::Online), DEFAULT_WINDOW_US, DEFAULT_WINDOW_US);
        assert_eq!(ts[0].n_ttft, 3);
        assert!(close(ts[0].p99_tpot_ms, 40.0, 0.016));
        assert!(ts[0].processed_per_s > 0.0);
    }

    #[test]
    fn merge_folds_window_rings_and_steal_counters() {
        let mut a = Recorder::new();
        a.set_capture_events(false);
        let mut b = Recorder::new();
        b.set_capture_events(false);
        a.record_first_token(1_000_000, Class::Online, 100_000);
        b.record_first_token(2_000_000, Class::Online, 900_000);
        b.steals_out = 3;
        b.steals_in = 1;
        b.stolen_ckpt_tokens = 640;
        a.merge(&b);
        assert_eq!(a.steals_out, 3);
        assert_eq!(a.steals_in, 1);
        assert_eq!(a.stolen_ckpt_tokens, 640);
        let ts = a.timeseries(Some(Class::Online), DEFAULT_WINDOW_US, DEFAULT_WINDOW_US);
        assert_eq!(ts.len(), 1);
        assert_eq!(ts[0].n_ttft, 2, "merged ring holds both shards' samples");
        assert!(close(ts[0].p99_ttft_ms, 900.0, 0.016));
    }

    #[test]
    fn merge_unions_samples_and_totals() {
        let mut a = Recorder::new();
        let mut b = Recorder::new();
        a.record_first_token(1_000, Class::Online, 100_000);
        a.record_processed(1_000, Class::Online, 64);
        a.record_finished(Class::Online);
        for _ in 0..99 {
            b.record_first_token(2_000, Class::Online, 100_000);
        }
        b.record_first_token(3_000, Class::Online, 4_000_000);
        b.record_processed(3_000, Class::Offline, 32);
        b.record_finished(Class::Offline);
        b.preemptions = 3;
        a.merge(&b);
        assert_eq!(a.gen_token_count(Some(Class::Online)), 101);
        assert_eq!(a.processed_token_count(None), 96);
        assert_eq!(a.finished, [1, 1]);
        assert_eq!(a.preemptions, 3);
        assert_eq!(a.ttfts.len(), 101);
        // p99 over the union: rank 100 of 101 samples is still 100ms
        assert!(close(a.p99_ttft_ms(Class::Online), 100.0, 0.016));
        // merging an empty recorder changes nothing
        let snapshot = a.gen_token_count(None);
        a.merge(&Recorder::new());
        assert_eq!(a.gen_token_count(None), snapshot);
    }

    #[test]
    fn violation_rate() {
        let mut r = Recorder::new();
        for ttft in [100_000u64, 200_000, 2_000_000, 90_000] {
            r.record_first_token(0, Class::Online, ttft);
        }
        assert_eq!(r.ttft_violation_rate(Class::Online, 1500.0), 0.25);
    }

    #[test]
    fn tenant_and_deadline_counters_accumulate_and_merge() {
        let mut a = Recorder::new();
        assert_eq!(a.deadline_attainment(), 1.0, "no deadlines => nothing late");
        a.deadline_met = 3;
        a.deadline_missed = 1;
        a.note_tenant_finished(7, 100, Some(true));
        a.note_tenant_finished(7, 50, Some(false));
        a.note_tenant_finished(9, 10, None);
        assert_eq!(a.deadline_attainment(), 0.75);
        assert_eq!(a.tenants.len(), 2);
        let t7 = a.tenants.iter().find(|t| t.tenant == 7).unwrap();
        assert_eq!((t7.finished, t7.gen_tokens), (2, 150));
        assert_eq!((t7.deadline_met, t7.deadline_missed), (1, 1));

        let mut b = Recorder::new();
        b.note_tenant_finished(7, 5, Some(true));
        b.note_tenant_finished(11, 1, None);
        b.jobs_completed = 2;
        b.jobs_deadline_met = 1;
        b.jobs_deadline_missed = 1;
        a.merge(&b);
        assert_eq!(a.tenants.len(), 3, "new tenant appended on merge");
        let t7 = a.tenants.iter().find(|t| t.tenant == 7).unwrap();
        assert_eq!((t7.finished, t7.gen_tokens, t7.deadline_met), (3, 155, 2));
        assert_eq!(a.jobs_completed, 2);
        assert_eq!((a.jobs_deadline_met, a.jobs_deadline_missed), (1, 1));
    }
}
