//! `bench_fault` — fault-tolerance acceptance bench.
//!
//! Serves one job workload (a spread of short-decode jobs plus a
//! long-decode straggler) with bursty online background traffic on a
//! 4-shard fleet, twice:
//!
//! * **baseline** — crash-free, no store, no faults;
//! * **faulted** — the full failure menu from one deterministic
//!   [`FaultPlan`]: shard 1 is killed mid-run, its first durable
//!   checkpoint write is torn, steal polls are delayed and the first
//!   deliveries dropped — then the crash-recovery driver
//!   ([`run_jobs_with_recovery`]) rebuilds the dead shard's work from
//!   the durable store on the 3 survivors under degraded offline
//!   budgets.
//!
//! Acceptance (asserted here):
//!
//! * exactly the planned shard dies, with the injected panic payload;
//! * the durable store ends with the **same completed set and
//!   byte-identical token streams** as the crash-free run;
//! * online requests routed to the dead shard surface in the
//!   fail-fast set (never silently dropped);
//! * the survivors' online TTFT-violation rate stays within 5 points
//!   of the baseline — recovery sheds offline throughput, not online
//!   latency.
//!
//! Results go to `BENCH_fault.json` (schema: rust/PERF.md §7). Scale
//! with `FAULT_BENCH_JOBS` (short jobs, default 16; CI smoke uses 8)
//! and `FAULT_BENCH_KILL_ITER` (default 30 — early enough that every
//! shard is still busy, so the kill lands deterministically).

use conserve::batch::{
    run_jobs, run_jobs_with_recovery, FinishedOutput, JobInput, JobManager, JobRequest,
    JobRunOpts, JobStore, NOMINAL_TOK_PER_S,
};
use conserve::config::EngineConfig;
use conserve::request::{Class, Request, TokenId};
use conserve::util::fault::{silence_injected_panics, FaultPlan, INJECTED_PANIC_MARKER};
use conserve::util::json::{num, obj, Json};
use conserve::util::rng::Rng;
use conserve::workload::trace::onoff_trace;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

const N_SHARDS: usize = 4;
const ONLINE_SPAN_S: f64 = 30.0;

fn job_inputs(n_jobs: usize) -> Vec<JobInput> {
    let mut rng = Rng::new(0xFA17);
    let mut jobs = Vec::new();
    for _ in 0..n_jobs {
        jobs.push(JobInput {
            tenant: 1 + (jobs.len() % 5) as u32,
            tier: (jobs.len() % 3) as u8,
            submitted_at: 0,
            deadline: 0,
            requests: (0..3)
                .map(|_| JobRequest {
                    prompt: Vec::new(),
                    prompt_len: rng.range_usize(128, 1024),
                    max_new_tokens: 32,
                })
                .collect(),
        });
    }
    // one long-decode straggler so the fleet stays busy and steals
    jobs.push(JobInput {
        tenant: 9,
        tier: 2,
        submitted_at: 0,
        deadline: 0,
        requests: (0..3)
            .map(|_| JobRequest {
                prompt: Vec::new(),
                prompt_len: rng.range_usize(1536, 2560),
                max_new_tokens: 256,
            })
            .collect(),
    });
    jobs
}

/// Admit the workload into a fresh manager and append the online
/// background trace (ids 1.. are disjoint from ticket-bit job sids).
fn build_events(jm: &mut JobManager, n_jobs: usize) -> (Vec<Request>, usize) {
    let mut events = Vec::new();
    for input in job_inputs(n_jobs) {
        jm.admit(&input, &mut events);
    }
    let n_job_requests = events.len();
    let mut rng = Rng::new(7);
    for (i, &t) in onoff_trace(42, ONLINE_SPAN_S, 20.0, 6.0, 2.0).iter().enumerate() {
        let input = rng.range_usize(64, 256);
        let output = rng.range_usize(8, 24);
        events.push(Request::new(
            1 + i as u64,
            Class::Online,
            vec![],
            input,
            output,
            t,
        ));
    }
    (events, n_job_requests)
}

fn outputs_by_sid(fins: &[FinishedOutput]) -> BTreeMap<u64, Vec<TokenId>> {
    fins.iter().map(|f| (f.sid, f.output.clone())).collect()
}

fn main() {
    let n_jobs: usize = std::env::var("FAULT_BENCH_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    let kill_iter: u64 = std::env::var("FAULT_BENCH_KILL_ITER")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30);
    silence_injected_panics();
    let cfg = EngineConfig::sim_a100_7b();
    let svc = NOMINAL_TOK_PER_S * N_SHARDS as f64;
    let total_job_tokens: u64 = job_inputs(n_jobs)
        .iter()
        .flat_map(|j| &j.requests)
        .map(|r| (r.prompt_len + r.max_new_tokens) as u64)
        .sum();
    let duration_s = (total_job_tokens as f64 / svc * 6.0).max(60.0);
    let opts = JobRunOpts {
        collect_state: true,
        synth_tokens: true,
        ckpt_every: 10,
        svc_tok_per_s: svc,
        ..JobRunOpts::new(N_SHARDS, duration_s)
    };

    // ---- baseline: crash-free ----
    let mut jm = JobManager::new(svc);
    let (events, n_job_requests) = build_events(&mut jm, n_jobs);
    let n_online = events.len() - n_job_requests;
    println!(
        "=== bench_fault ({} jobs / {n_job_requests} job requests + {n_online} online, {N_SHARDS} shards, kill=1@{kill_iter}) ===",
        n_jobs + 1
    );
    let t0 = Instant::now();
    let base = run_jobs(&cfg, &opts, jm.board().clone(), events);
    let base_wall = t0.elapsed().as_secs_f64();
    assert!(base.deaths.is_empty(), "baseline must be healthy");
    let want = outputs_by_sid(&base.finished);
    assert_eq!(want.len(), n_job_requests, "baseline completes every job request");
    let base_viol = base.run.merged.ttft_violations;
    println!(
        "baseline: wall={base_wall:.2}s makespan={:.1}s viol={:.2}% offline_fin={}",
        base.run.makespan_s,
        base_viol * 100.0,
        base.run.merged.offline_finished,
    );

    // ---- faulted: kill + torn checkpoint + degraded steal channel ----
    let dir = std::env::temp_dir().join(format!("conserve-bench-fault-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let plan = FaultPlan::parse(&format!(
        "kill=1@{kill_iter},delay-steals=3,drop-steals=2,torn-ckpt=1"
    ))
    .unwrap();
    let mut jm2 = JobManager::new(svc);
    let (events2, _) = build_events(&mut jm2, n_jobs);
    let store = {
        let mut s = JobStore::open(&dir).expect("open job store");
        for spec in jm2.specs().to_vec() {
            s.record_spec(&spec, &events2).expect("record spec");
        }
        Arc::new(Mutex::new(s))
    };
    let t1 = Instant::now();
    let rec = run_jobs_with_recovery(
        &cfg,
        &opts,
        jm2.board().clone(),
        events2,
        store.clone(),
        Some(&plan),
    )
    .expect("recovery driver");
    let fault_wall = t1.elapsed().as_secs_f64();
    drop(store);

    // ---- acceptance ----
    assert_eq!(rec.first.deaths.len(), 1, "exactly the planned shard dies");
    assert_eq!(rec.first.deaths[0].shard, 1);
    assert!(rec.first.deaths[0].payload.contains(INJECTED_PANIC_MARKER));
    assert!(rec.recovery.is_some(), "a death must trigger the recovery round");
    let fault_viol = rec.first.run.merged.ttft_violations;
    let got: BTreeMap<u64, Vec<TokenId>> = JobStore::load(&dir)
        .expect("reload store")
        .outputs
        .values()
        .map(|f| (f.sid, f.output.clone()))
        .collect();
    let outputs_match = got == want;
    assert!(
        outputs_match,
        "recovered outputs must match the crash-free run byte for byte \
         ({} recovered vs {} baseline)",
        got.len(),
        want.len()
    );
    assert!(
        fault_viol <= base_viol + 0.05,
        "survivor TTFT-violation rate must stay within 5 points of baseline: \
         {fault_viol:.4} vs {base_viol:.4}"
    );
    let flush_records = rec.first.run.merged.ckpt_flush_records
        + rec.recovery.as_ref().map_or(0, |r| r.run.merged.ckpt_flush_records);
    println!(
        "faulted:  wall={fault_wall:.2}s deaths=1 failed_online={} resumed={} torn_lines={} flush_records={} viol={:.2}%",
        rec.first.failed_online.len(),
        rec.resumed_requests,
        rec.torn_checkpoint_lines,
        flush_records,
        fault_viol * 100.0,
    );
    println!(
        "recovery matched the crash-free run: {} streams byte-identical",
        got.len()
    );

    // ---- emit BENCH_fault.json (schema documented in rust/PERF.md §7) ----
    let json = obj(vec![
        ("jobs", num((n_jobs + 1) as f64)),
        ("job_requests", num(n_job_requests as f64)),
        ("online_requests", num(n_online as f64)),
        ("shards", num(N_SHARDS as f64)),
        ("kill_iter", num(kill_iter as f64)),
        ("plan", Json::Str(plan.to_string())),
        ("baseline_wall_s", num(base_wall)),
        ("faulted_wall_s", num(fault_wall)),
        ("baseline_ttft_violation_rate", num(base_viol)),
        ("survivor_ttft_violation_rate", num(fault_viol)),
        ("outputs_match", num(f64::from(u8::from(outputs_match)))),
        ("deaths", num(rec.first.deaths.len() as f64)),
        ("failed_online", num(rec.first.failed_online.len() as f64)),
        ("resumed_requests", num(rec.resumed_requests as f64)),
        ("torn_checkpoint_lines", num(rec.torn_checkpoint_lines as f64)),
        ("ckpt_flush_records", num(flush_records as f64)),
        (
            "flush_write_amplification",
            num(flush_records as f64 / n_job_requests as f64),
        ),
    ]);
    let out_path =
        std::env::var("FAULT_BENCH_OUT").unwrap_or_else(|_| "BENCH_fault.json".into());
    std::fs::write(&out_path, json.to_string()).expect("write BENCH_fault.json");
    println!("\nwrote {out_path}");
    let _ = Json::parse(&json.to_string()).expect("self-emitted json parses");
    let _ = std::fs::remove_dir_all(&dir);
    println!("bench_fault OK");
}
