//! Small self-contained utilities: deterministic PRNG + distribution
//! sampling, a minimal JSON parser/emitter (the environment vendors no
//! serde), deterministic fault injection ([`fault`]), and shape/bucket
//! helpers shared by the engine.

pub mod fault;
pub mod json;
pub mod rng;

/// Round `n` up to the smallest bucket >= n; returns the largest bucket if
/// none fits (caller clamps).
pub fn bucket_up(buckets: &[usize], n: usize) -> usize {
    for &b in buckets {
        if b >= n {
            return b;
        }
    }
    *buckets.last().expect("empty bucket list")
}

/// Integer ceil-div.
pub fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_up_picks_smallest_fit() {
        let b = [1, 16, 64];
        assert_eq!(bucket_up(&b, 1), 1);
        assert_eq!(bucket_up(&b, 2), 16);
        assert_eq!(bucket_up(&b, 16), 16);
        assert_eq!(bucket_up(&b, 17), 64);
        assert_eq!(bucket_up(&b, 1000), 64); // clamped to largest
    }

    #[test]
    fn ceil_div_works() {
        assert_eq!(ceil_div(0, 16), 0);
        assert_eq!(ceil_div(1, 16), 1);
        assert_eq!(ceil_div(16, 16), 1);
        assert_eq!(ceil_div(17, 16), 2);
    }
}
