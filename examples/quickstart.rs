//! Quickstart: load the AOT artifacts, serve two online requests and a
//! small offline batch end-to-end on the CPU PJRT runtime, and print the
//! streamed tokens.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use conserve::backend::PjrtBackend;
use conserve::config::EngineConfig;
use conserve::profiler::LatencyProfile;
use conserve::request::{Class, Request};
use conserve::runtime::tokenizer::{detokenize, tokenize};
use conserve::server::{ArrivalSource, ServingEngine};

fn main() -> anyhow::Result<()> {
    // 1. load artifacts (manifest + weights + HLO) onto the PJRT client
    let cfg = EngineConfig::real_tiny();
    let mut backend = PjrtBackend::load("artifacts", cfg.seed, cfg.sched.safepoint_layers)?;
    let clock = backend.clock();
    println!(
        "model: {} layers, d={}, vocab={}, max_seq={}",
        backend.dims().n_layers,
        backend.dims().d_model,
        backend.dims().vocab_size,
        backend.dims().max_seq
    );

    // 2. profile once (the SLO-aware scheduler needs the latency model)
    let profile = LatencyProfile::profile(&mut backend, 64, 4, 64)?;
    println!(
        "latency model: t = {:.0} + {:.1}*prefill + {:.0}*decode + {:.2}*ctx  (µs)",
        profile.c[0], profile.c[1], profile.c[2], profile.c[3]
    );

    // 3. submit work: two online chats + three offline summaries
    let mut events = Vec::new();
    for (i, text) in [
        "Hello ConServe, how do you harvest idle GPUs?",
        "Summarize the benefits of co-serving online and offline jobs.",
    ]
    .iter()
    .enumerate()
    {
        let prompt = tokenize(text);
        let plen = prompt.len();
        events.push(Request::new(
            (i + 1) as u64,
            Class::Online,
            prompt,
            plen,
            16,
            (i as u64) * 50_000,
        ));
    }
    for i in 0..3u64 {
        let prompt = tokenize("offline document body: the quarterly report covers serving throughput, cache efficiency and scheduling policy in detail.");
        let plen = prompt.len();
        events.push(Request::new(10 + i, Class::Offline, prompt, plen, 12, 0));
    }

    // 4. run the engine; stream tokens as they are produced
    let mut engine = ServingEngine::new(
        cfg,
        backend,
        clock,
        profile,
        ArrivalSource::from_trace(events),
    );
    engine.set_token_callback(Box::new(|id, tok, t_us| {
        println!(
            "  [t={:>7.3}s] req {id} -> token {tok:?} ({:?})",
            t_us as f64 / 1e6,
            detokenize(&[tok])
        );
    }));
    engine.run(60_000_000);

    // 5. inspect results
    println!("\ncompletions:");
    let mut ids: Vec<_> = engine.table.ids().collect();
    ids.sort_unstable();
    for id in ids {
        let r = &engine.table[&id];
        println!(
            "  req {id} ({:?}): {} prompt tokens -> {:?}",
            r.class,
            r.prompt_len,
            detokenize(&r.output)
        );
    }
    println!(
        "\nonline P99 TTFT: {:.1} ms, P99 TPOT: {:.1} ms",
        engine.rec.p99_ttft_ms(Class::Online),
        engine.rec.p99_tpot_ms(Class::Online)
    );
    Ok(())
}
