//! # ConServe — GPU harvesting for LLM online/offline co-serving
//!
//! A reproduction of *"ConServe: Harvesting GPUs for Low-Latency and
//! High-Throughput Large Language Model Serving"* (Qiao et al., 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time, Python)** — Pallas kernels + a layered JAX
//!   Llama-architecture model, AOT-lowered to HLO text artifacts
//!   (`python/compile/`, `make artifacts`).
//! * **L3 (this crate)** — the serving system: a unified preemptive
//!   scheduler (paper Alg. 1/2), an SLO-aware batch-budget policy, a paged
//!   KV-cache manager with incremental checkpointing and background
//!   prefetching, a preemptible layer-stepped execution engine, workload
//!   generation, metrics, and baselines (`Online-Only`, `vLLM++`).
//!
//! Python never runs on the request path: the PJRT backend (cargo
//! feature `pjrt`, requires the `xla` crate) loads the AOT artifacts
//! through the PJRT C API and serves requests end-to-end from Rust. A
//! calibrated discrete-event backend ([`backend::SimBackend`]) models
//! the paper's A100/Llama-2-7B testbed and regenerates every evaluation
//! figure (see `rust/benches/`) — the simulator and all policy machinery
//! build dependency-light (`anyhow` only) with default features.
//!
//! Quickstart: `examples/quickstart.rs`; architecture: `DESIGN.md`;
//! hot-path design (slab arenas, scratch buffers, streaming metrics):
//! `rust/PERF.md`.

pub mod backend;
pub mod clock;
pub mod config;
pub mod kvcache;
pub mod metrics;
pub mod profiler;
pub mod report;
pub mod request;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod util;
pub mod workload;

/// Microsecond timestamps; all scheduling math is integer µs to keep the
/// discrete-event simulation deterministic.
pub type TimeUs = u64;

pub const US_PER_SEC: u64 = 1_000_000;
pub const US_PER_MS: u64 = 1_000;
