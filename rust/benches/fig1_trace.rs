//! Figure 1 — "User traffic to ChatGPT ... exposes high load variability
//! at various time scales."
//!
//! Regenerates the workload-characterization figure from the synthetic
//! BurstGPT-like trace generator: a 15-minute window (Fig. 1b) with the
//! published statistics — avg ~1050 tok/s, peak ~3743 tok/s, and a 3x
//! minute-scale burst — and a 24-hour diurnal view (Fig. 1a) rendered at
//! a compressed timescale.

use conserve::workload::trace::{burstgpt_like_arrivals, burstgpt_like_rate, rate_series};

fn main() {
    println!("=== Figure 1(b): 15-minute window, 30 s bins ===");
    // 1152 tokens per request (input 1024 + output 128); base rate chosen
    // so the average lands near the published 1050 tok/s.
    let tokens_per_req = 1152;
    let duration = 900.0;
    let base_rate = 0.95; // req/s before envelope shaping
    let arrivals = burstgpt_like_arrivals(42, duration, base_rate, 1.0);
    let series = rate_series(&arrivals, tokens_per_req, 30.0, duration);

    println!("{:>6} {:>9} {:>14}", "t_s", "requests", "tokens_per_s");
    for (t, n, toks) in &series {
        let bar = "#".repeat((toks / 150.0) as usize);
        println!("{t:>6.0} {n:>9} {toks:>14.0}  {bar}");
    }

    let rates: Vec<f64> = series.iter().map(|(_, _, r)| *r).collect();
    let avg = rates.iter().sum::<f64>() / rates.len() as f64;
    let peak = rates.iter().cloned().fold(0.0, f64::max);
    let early_avg = rates[..6].iter().sum::<f64>() / 6.0;
    println!("\navg  load: {avg:>6.0} tok/s   (paper: 1050)");
    println!("peak load: {peak:>6.0} tok/s   (paper: 3743)");
    println!("peak/avg : {:>6.2}x       (paper: ~3.6x)", peak / avg);
    println!("burst vs early window: {:.2}x", peak / early_avg.max(1.0));

    assert!(peak / avg > 2.0, "burstiness must be visible");

    println!("\n=== Figure 1(a): 24-hour diurnal envelope (1 h bins) ===");
    println!("{:>5} {:>14}", "hour", "tokens_per_s");
    for h in 0..24 {
        // diurnal view: rate envelope sampled across a compressed day
        let x = (h as f64 + 0.5) / 24.0;
        let r = burstgpt_like_rate(x * duration, duration, base_rate) * tokens_per_req as f64;
        let bar = "#".repeat((r / 150.0) as usize);
        println!("{h:>5} {r:>14.0}  {bar}");
    }
}
