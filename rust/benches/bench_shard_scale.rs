//! `bench_shard_scale` — multi-worker scaling acceptance bench.
//!
//! Routes one ~100k-request on/off co-serving trace (80% online with
//! gamma on/off arrivals, 20% offline pool at t=0 — heavier offline
//! share than `bench_sched_loop` so a single worker is clearly
//! saturated and scaling is measurable) across 1 / 2 / 4 / 8 worker
//! shards under the affinity placement policy, at **equal total load**:
//! every sweep point serves the identical request set. Each shard is an
//! independent simulated A100 (own virtual clock, arena, KV pool,
//! scheduler) on its own OS thread.
//!
//! Reported per sweep point, from the merged cross-shard recorder:
//!
//! * aggregate generation and processed tokens/sec over the fleet
//!   makespan (the slowest shard's finish time);
//! * online P99 TTFT / TPOT and the TTFT SLO-violation rate;
//! * wall-clock time for the whole fleet run (thread-parallel).
//!
//! Acceptance (asserted here): every >= 2-shard point beats the 1-shard
//! baseline on aggregate generation throughput with no SLO-violation
//! regression. Throughput plateaus once the makespan is bounded by the
//! trace span rather than compute — expected, and visible in the
//! ratios. Results go to `BENCH_shard.json` (schema: rust/PERF.md).
//! Scale with `SHARD_BENCH_REQS` (default 100_000; CI smoke uses a
//! small value).

use conserve::config::EngineConfig;
use conserve::report::Report;
use conserve::request::{Class, Request};
use conserve::shard::{run_sharded_sim, Placement, ShardedRun};
use conserve::util::json::{arr, num, obj, Json};
use conserve::util::rng::Rng;
use conserve::workload::trace::onoff_trace;
use std::time::Instant;

struct Row {
    shards: usize,
    wall_s: f64,
    run: ShardedRun,
}

fn main() {
    let n_reqs: usize = std::env::var("SHARD_BENCH_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100_000);
    let n_online = n_reqs * 8 / 10;
    let n_offline = n_reqs - n_online;

    // ---- one trace, served at every sweep point ----
    let on_rate = 60.0;
    let phase_s = 30.0;
    let duration_s = 2.0 * n_online as f64 / on_rate;
    let arrivals = onoff_trace(42, duration_s, phase_s, on_rate, 2.0);
    let mut rng = Rng::new(7);
    let mut events: Vec<Request> = arrivals
        .iter()
        .take(n_online)
        .map(|&t| {
            let input = rng.range_usize(64, 256);
            let output = rng.range_usize(8, 24);
            Request::new(0, Class::Online, vec![], input, output, t)
        })
        .collect();
    for _ in 0..n_offline {
        let input = rng.range_usize(512, 2048);
        let output = rng.range_usize(32, 96);
        events.push(Request::new(0, Class::Offline, vec![], input, output, 0));
    }
    let n_events = events.len();
    let cfg = EngineConfig::sim_a100_7b();
    let placement = Placement::affinity();

    println!("=== bench_shard_scale ({n_events} requests, placement {placement}) ===");
    let sweep = [1usize, 2, 4, 8];
    let mut rows: Vec<Row> = Vec::new();
    for &shards in &sweep {
        let t0 = Instant::now();
        let run = run_sharded_sim(&cfg, shards, placement, events.clone(), duration_s * 4.0);
        let wall_s = t0.elapsed().as_secs_f64();
        let m = &run.merged;
        println!(
            "shards={shards}: wall={wall_s:>7.2}s makespan={:>8.1}s gen={:>7.0} tok/s proc={:>8.0} tok/s p99TTFT={:>9.1}ms viol={:>5.2}% finished={} shard_reqs={:?}",
            run.makespan_s,
            m.total_gen_tput,
            m.total_processed_tput,
            m.online_p99_ttft_ms,
            m.ttft_violations * 100.0,
            m.online_finished + m.offline_finished,
            run.shard_requests,
        );
        rows.push(Row { shards, wall_s, run });
    }

    // ---- acceptance: >= 2 shards beats the 1-shard baseline at equal
    // total load, with no online SLO-violation regression ----
    let base = &rows[0].run.merged;
    for row in &rows[1..] {
        let m = &row.run.merged;
        assert!(
            m.total_gen_tput > base.total_gen_tput,
            "{} shards must out-generate 1 shard: {:.0} vs {:.0} tok/s",
            row.shards,
            m.total_gen_tput,
            base.total_gen_tput
        );
        assert!(
            m.ttft_violations <= base.ttft_violations + 0.005,
            "{} shards must not regress SLO violations: {:.4} vs {:.4}",
            row.shards,
            m.ttft_violations,
            base.ttft_violations
        );
    }
    for row in &rows[1..] {
        println!(
            "scaling {}x shards: gen tput {:.2}x, p99 TTFT {:.2}x",
            row.shards,
            row.run.merged.total_gen_tput / base.total_gen_tput,
            row.run.merged.online_p99_ttft_ms / base.online_p99_ttft_ms.max(1e-9),
        );
    }

    // ---- emit BENCH_shard.json (schema documented in rust/PERF.md) ----
    let shard_row = |r: &Report, requests: usize| {
        obj(vec![
            ("requests", num(requests as f64)),
            ("gen_tok_s", num(r.total_gen_tput)),
            ("online_p99_ttft_ms", num(r.online_p99_ttft_ms)),
            ("finished", num((r.online_finished + r.offline_finished) as f64)),
        ])
    };
    let sweep_json = arr(rows.iter().map(|row| {
        let m = &row.run.merged;
        obj(vec![
            ("shards", num(row.shards as f64)),
            ("wall_s", num(row.wall_s)),
            ("makespan_s", num(row.run.makespan_s)),
            ("agg_gen_tok_s", num(m.total_gen_tput)),
            ("agg_processed_tok_s", num(m.total_processed_tput)),
            ("online_p99_ttft_ms", num(m.online_p99_ttft_ms)),
            ("online_p99_tpot_ms", num(m.online_p99_tpot_ms)),
            ("online_mean_ttft_ms", num(m.online_mean_ttft_ms)),
            ("ttft_violation_rate", num(m.ttft_violations)),
            (
                "finished",
                num((m.online_finished + m.offline_finished) as f64),
            ),
            ("preemptions", num(m.preemptions as f64)),
            (
                "per_shard",
                arr(row
                    .run
                    .per_shard
                    .iter()
                    .zip(&row.run.shard_requests)
                    .map(|(r, &n)| shard_row(r, n))),
            ),
        ])
    }));
    let scaling = obj(rows[1..]
        .iter()
        .map(|row| {
            (
                match row.shards {
                    2 => "gen_tput_2_over_1",
                    4 => "gen_tput_4_over_1",
                    _ => "gen_tput_8_over_1",
                },
                num(row.run.merged.total_gen_tput / base.total_gen_tput),
            )
        })
        .collect());
    let json = obj(vec![
        ("requests", num(n_events as f64)),
        ("online_requests", num(n_online.min(arrivals.len()) as f64)),
        ("offline_requests", num(n_offline as f64)),
        ("placement", Json::Str(placement.to_string())),
        (
            "trace",
            obj(vec![
                ("on_rate", num(on_rate)),
                ("phase_s", num(phase_s)),
                ("duration_s", num(duration_s)),
            ]),
        ),
        ("sweep", sweep_json),
        ("scaling", scaling),
    ]);
    let out_path =
        std::env::var("SHARD_BENCH_OUT").unwrap_or_else(|_| "BENCH_shard.json".into());
    std::fs::write(&out_path, json.to_string()).expect("write BENCH_shard.json");
    println!("\nwrote {out_path}");
    let _ = Json::parse(&json.to_string()).expect("self-emitted json parses");
    println!("bench_shard_scale OK");
}
